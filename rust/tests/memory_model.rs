//! Seeded property test: `SynapticMemory` against a naive HashMap model.
//!
//! The model is the obviously-correct specification: a map from (pre, post)
//! to the last accepted weight, empty outside the topology's α=1 set. The
//! production store (dense / diagonal / banded) must agree with it after
//! arbitrary interleavings of single writes, bulk dense loads, bulk packed
//! loads, and reads — including the failure cases: pruned-write rejection,
//! out-of-range values, bad addresses, wrong payload sizes. `writes()`
//! accounting and the `dense()` / `row_nonzero()` views are cross-checked
//! throughout. Hand-rolled generators over the repo's xorshift PRNG
//! (proptest is unavailable offline); seeds are printed in assertions so
//! failures reproduce.

use std::collections::HashMap;

use quantisenc::config::{MemKind, Topology};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::fixed::{Q3_1, Q5_3, Q9_7};
use quantisenc::hdl::memory::MemError;
use quantisenc::hdl::SynapticMemory;

fn check_views(mem: &SynapticMemory, model: &HashMap<(usize, usize), i32>, mask: &[u8], ctx: &str) {
    let (m, n) = (mem.m(), mem.n());
    // dense() agrees with the model everywhere (zero where unset/pruned).
    let dense = mem.dense();
    assert_eq!(dense.len(), m * n, "{ctx}");
    let mut row_buf = Vec::new(); // one scratch for the whole row sweep
    for pre in 0..m {
        for post in 0..n {
            let want = model.get(&(pre, post)).copied().unwrap_or(0);
            assert_eq!(dense[pre * n + post], want, "{ctx}: dense ({pre},{post})");
            assert_eq!(mem.read(pre, post).unwrap(), want, "{ctx}: read ({pre},{post})");
        }
        // row_into() (and the allocating row()) is the dense row.
        mem.row_into(pre, &mut row_buf);
        assert_eq!(row_buf, dense[pre * n..(pre + 1) * n], "{ctx}: row {pre}");
        assert_eq!(mem.row(pre), row_buf, "{ctx}: row() twin {pre}");
        // row_nonzero() visits exactly the α=1 positions, ascending, with
        // the model's values.
        let visited: Vec<(usize, i32)> = mem.row_nonzero(pre).collect();
        let expect: Vec<(usize, i32)> = (0..n)
            .filter(|&j| mask[pre * n + j] == 1)
            .map(|j| (j, model.get(&(pre, j)).copied().unwrap_or(0)))
            .collect();
        assert_eq!(visited, expect, "{ctx}: row_nonzero {pre}");
        assert_eq!(mem.row_synapses(pre), expect.len(), "{ctx}: row_synapses {pre}");
    }
    // synapses() is the α=1 count.
    let nnz: usize = mask.iter().map(|&a| a as usize).sum();
    assert_eq!(mem.synapses(), nnz, "{ctx}");
    assert_eq!(mem.packed().len(), nnz, "{ctx}");
}

#[test]
fn memory_agrees_with_hashmap_model() {
    let topologies = [
        Topology::AllToAll,
        Topology::OneToOne,
        Topology::Gaussian { radius: 1 },
        Topology::Gaussian { radius: 2 },
    ];
    let qspecs = [Q9_7, Q5_3, Q3_1];
    let mut rng = XorShift64Star::new(0x3E3E_0001);

    for (case, (&topo, &qs)) in topologies
        .iter()
        .flat_map(|t| qspecs.iter().map(move |q| (t, q)))
        .enumerate()
    {
        // One-to-one needs square layers; vary shapes for the others.
        let (m, n) = match topo {
            Topology::OneToOne => (9usize, 9usize),
            _ => (6 + (case % 5), 5 + (case % 7)),
        };
        let ctx = format!("case {case} {topo:?} {} {m}x{n}", qs.name());
        let mask = topo.mask(m, n).unwrap();
        let mut mem = SynapticMemory::new(m, n, topo, qs, MemKind::Bram);
        let mut model: HashMap<(usize, usize), i32> = HashMap::new();
        let mut accepted_writes = 0u64;
        let lim = qs.max_raw();

        for step in 0..400 {
            let op = rng.below(100);
            if op < 70 {
                // Single wt_in write; addresses/values sometimes invalid.
                let pre = rng.below(m as u64 + 2) as usize;
                let post = rng.below(n as u64 + 2) as usize;
                // Range [-2*lim, 2*lim]: roughly half out of range.
                let val = (rng.below(4 * lim as u64 + 1) as i32) - 2 * lim;
                let before = mem.dense();
                let result = mem.write(pre, post, val);
                if pre >= m || post >= n {
                    assert_eq!(
                        result,
                        Err(MemError::BadAddress { pre, post, m, n }),
                        "{ctx} step {step}"
                    );
                } else if !qs.in_range(val) {
                    assert!(
                        matches!(&result, Err(MemError::OutOfRange { .. })),
                        "{ctx} step {step}: write({pre},{post},{val}) -> {result:?}"
                    );
                } else if mask[pre * n + post] == 0 {
                    assert!(
                        matches!(&result, Err(MemError::Pruned { .. })),
                        "{ctx} step {step}: write({pre},{post},{val}) -> {result:?}"
                    );
                } else {
                    assert_eq!(result, Ok(()), "{ctx} step {step}");
                    model.insert((pre, post), val);
                    accepted_writes += 1;
                }
                if result.is_err() {
                    // Failed transactions must not mutate the store.
                    assert_eq!(mem.dense(), before, "{ctx} step {step}: failed write mutated");
                }
            } else if op < 80 {
                // Bulk dense load: valid masked matrix, or (sometimes) a
                // corrupted one that must be rejected without mutating.
                let corrupt = rng.below(3) == 0;
                let mut dense: Vec<i32> = mask
                    .iter()
                    .map(|&a| {
                        if a == 0 {
                            0
                        } else {
                            (rng.below(2 * lim as u64 + 1) as i32) - lim
                        }
                    })
                    .collect();
                if corrupt {
                    let before = mem.dense();
                    let w_before = mem.writes();
                    // Either a pruned-position violation (if any pruned
                    // slot exists) or an out-of-range value.
                    if rng.below(2) == 0 && mask.iter().any(|&a| a == 0) {
                        let idx = (0..mask.len()).find(|&i| mask[i] == 0).unwrap();
                        dense[idx] = 1;
                        assert!(
                            matches!(mem.load_dense(&dense), Err(MemError::Pruned { .. })),
                            "{ctx} step {step}"
                        );
                    } else {
                        let idx = (0..mask.len()).find(|&i| mask[i] == 1).unwrap();
                        dense[idx] = 2 * lim + 1;
                        assert!(
                            matches!(mem.load_dense(&dense), Err(MemError::OutOfRange { .. })),
                            "{ctx} step {step}"
                        );
                    }
                    assert_eq!(mem.dense(), before, "{ctx} step {step}: failed load mutated");
                    assert_eq!(mem.writes(), w_before, "{ctx} step {step}");
                } else {
                    mem.load_dense(&dense).unwrap();
                    model.clear();
                    for (idx, &w) in dense.iter().enumerate() {
                        if mask[idx] == 1 {
                            model.insert((idx / n, idx % n), w);
                        }
                    }
                    accepted_writes += mem.synapses() as u64;
                }
            } else if op < 90 {
                // Bulk packed load of the per-topology payload.
                let nnz = mem.synapses();
                if rng.below(3) == 0 {
                    let bad_len = if rng.below(2) == 0 { nnz + 1 } else { m * n + 1 };
                    assert_eq!(
                        mem.load_packed(&vec![0; bad_len]),
                        Err(MemError::BulkSize { expect: nnz, got: bad_len }),
                        "{ctx} step {step}"
                    );
                } else {
                    let packed: Vec<i32> = (0..nnz)
                        .map(|_| (rng.below(2 * lim as u64 + 1) as i32) - lim)
                        .collect();
                    mem.load_packed(&packed).unwrap();
                    // Rebuild the model by walking the sparse view itself —
                    // then check_views verifies it against dense()/read().
                    model.clear();
                    let mut k = 0usize;
                    for pre in 0..m {
                        for j in 0..n {
                            if mask[pre * n + j] == 1 {
                                model.insert((pre, j), packed[k]);
                                k += 1;
                            }
                        }
                    }
                    assert_eq!(k, nnz, "{ctx} step {step}");
                    accepted_writes += nnz as u64;
                }
            } else {
                // Reads of arbitrary (possibly bad) addresses.
                let pre = rng.below(m as u64 + 2) as usize;
                let post = rng.below(n as u64 + 2) as usize;
                match mem.read(pre, post) {
                    Ok(v) => {
                        assert!(pre < m && post < n, "{ctx} step {step}");
                        assert_eq!(v, model.get(&(pre, post)).copied().unwrap_or(0));
                    }
                    Err(e) => {
                        assert!(pre >= m || post >= n, "{ctx} step {step}: {e}");
                    }
                }
            }

            if step % 97 == 0 {
                check_views(&mem, &model, &mask, &ctx);
            }
        }

        assert_eq!(mem.writes(), accepted_writes, "{ctx}: writes() accounting");
        check_views(&mem, &model, &mask, &ctx);
    }
}

/// `dense()` round-trips through `load_dense` into a fresh store, and
/// `packed()` through `load_packed`, for every topology × quantization.
#[test]
fn bulk_roundtrips_preserve_contents() {
    let mut rng = XorShift64Star::new(0x3E3E_0002);
    for topo in [
        Topology::AllToAll,
        Topology::OneToOne,
        Topology::Gaussian { radius: 1 },
        Topology::Gaussian { radius: 3 },
    ] {
        for qs in [Q9_7, Q5_3, Q3_1] {
            let (m, n) = (11usize, 11usize);
            let mask = topo.mask(m, n).unwrap();
            let lim = qs.max_raw();
            let mut a = SynapticMemory::new(m, n, topo, qs, MemKind::Bram);
            let dense: Vec<i32> = mask
                .iter()
                .map(|&x| if x == 0 { 0 } else { (rng.below(2 * lim as u64 + 1) as i32) - lim })
                .collect();
            a.load_dense(&dense).unwrap();
            assert_eq!(a.dense(), dense);

            let mut b = SynapticMemory::new(m, n, topo, qs, MemKind::Bram);
            b.load_packed(a.packed()).unwrap();
            assert_eq!(b.dense(), dense, "{topo:?} {} packed roundtrip", qs.name());
            for pre in 0..m {
                assert!(b.row_nonzero(pre).eq(a.row_nonzero(pre)), "{topo:?} row {pre}");
            }
        }
    }
}
