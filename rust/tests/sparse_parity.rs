//! Differential dense-vs-sparse conformance suite.
//!
//! The topology-aware synaptic stores (diagonal for one-to-one, banded for
//! Gaussian) must be *bit-identical* in behaviour to a dense reference: a
//! twin all-to-all layer programmed with the same weights as a dense
//! matrix (zeros at pruned positions). Adding a stored zero is the
//! identity under the hardware's wrapping Qn.q accumulate, so the dense
//! twin computes exactly the same activations — if the sparse walk ever
//! skips a live synapse, touches a pruned one, or misindexes a band
//! window, the vmem traces and spike outputs diverge and these tests trip.
//!
//! The ActivityStats ledger is checked against an independent mask-derived
//! oracle: per step, `synaptic_ops` must equal the α=1 count of the active
//! rows and `gated_ops` the α=1 count of the gated rows (the sparse store
//! charges physical slots only), while the dense twin charges full N-wide
//! rows. Neuron-side counters (spikes, vmem toggles, neuron updates,
//! mem cycles) must agree exactly between the pair.
//!
//! A second differential axis runs **packed-vs-scalar twins**
//! (`assert_packed_scalar_parity`): the event-driven bit-packed datapath
//! (`Layer::step_plane` — trailing_zeros row iteration, bulk gated-ops
//! charge from the per-row synapse prefix sum, SoA quiescence skip) against
//! the retained dense scalar reference (`Layer::step_scalar`), across all
//! three topologies and Q9.7/Q5.3/Q3.1 — bit-identical vmem, spikes, and
//! activity ledgers required every step. Note the dense-vs-sparse suite
//! above *also* exercises the packed path (the byte `step_regs` API is an
//! adapter over it), so the two axes compose.
//!
//! A third axis runs the **lane-exactness twin gate**
//! (`assert_lane_parity`): the 64-sample lane-batched datapath
//! (`Layer::step_lanes` — one synaptic-row fetch per firing line scattered
//! across all active lanes, lane-major SoA neuron bank) against per-lane
//! single-sample packed twins, across all three topologies and
//! Q9.7/Q5.3/Q3.1 at 0/2/35/90% firing, including ragged batches (lane
//! counts 3/37/64 and per-lane unequal stream lengths with masked-out
//! finished lanes).

use quantisenc::config::registers::{RegisterFile, REG_REFRACTORY, REG_RESET_MODE};
use quantisenc::config::{LayerConfig, MemKind, Topology};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::fixed::{QSpec, Q3_1, Q5_3, Q9_7};
use quantisenc::hdl::{ActivityStats, Layer, SpikeMatrix, SpikePlane};

const T_STEPS: usize = 220;

/// Dense [M × N] matrix with random in-range weights at α=1 positions and
/// zeros at pruned positions.
fn masked_random_weights(
    topo: Topology,
    m: usize,
    n: usize,
    qs: QSpec,
    rng: &mut XorShift64Star,
) -> Vec<i32> {
    let mask = topo.mask(m, n).unwrap();
    let lim = qs.max_raw().min(127) as u64;
    mask.iter()
        .map(|&a| if a == 0 { 0 } else { (rng.below(2 * lim + 1) as i32) - lim as i32 })
        .collect()
}

/// Drive a sparse layer and its dense all-to-all twin with the same seeded
/// spike stream for `T_STEPS` timesteps, asserting bit-identical vmem
/// traces, spike outputs, and a mask-consistent activity ledger each step.
fn assert_sparse_dense_parity(topo: Topology, m: usize, n: usize, qs: QSpec, seed: u64) {
    let mut rng = XorShift64Star::new(seed);
    let weights = masked_random_weights(topo, m, n, qs, &mut rng);

    let sparse_cfg = LayerConfig { fan_in: m, neurons: n, topology: topo };
    let dense_cfg = LayerConfig { fan_in: m, neurons: n, topology: Topology::AllToAll };
    let mut sparse = Layer::new(&sparse_cfg, qs, MemKind::Bram);
    let mut dense = Layer::new(&dense_cfg, qs, MemKind::Bram);
    sparse.memory_mut().load_dense(&weights).unwrap();
    dense.memory_mut().load_dense(&weights).unwrap();

    // The sparse store must hold exactly the topology's synapse count and
    // reproduce the dense matrix through its materialized view.
    let mask = topo.mask(m, n).unwrap();
    let nnz_total: u64 = mask.iter().map(|&a| a as u64).sum();
    assert_eq!(sparse.memory().synapses() as u64, nnz_total, "{topo:?} storage words");
    assert_eq!(sparse.memory().dense(), weights, "{topo:?} dense view");
    let row_nnz: Vec<u64> = (0..m)
        .map(|i| mask[i * n..(i + 1) * n].iter().map(|&a| a as u64).sum())
        .collect();

    // Exercise the neuron datapath beyond defaults: subtractive reset with
    // a refractory period on half the cases.
    let mut regs = RegisterFile::new(qs);
    if seed % 2 == 1 {
        regs.write(REG_RESET_MODE, 2).unwrap(); // by-subtraction
        regs.write(REG_REFRACTORY, 1).unwrap();
    }

    let mut sparse_out = Vec::new();
    let mut dense_out = Vec::new();
    for t in 0..T_STEPS {
        let spikes: Vec<u8> = (0..m).map(|_| (rng.uniform() < 0.35) as u8).collect();
        let s_stats = sparse.step_regs(&spikes, &mut sparse_out, &regs);
        let d_stats = dense.step_regs(&spikes, &mut dense_out, &regs);

        // Bit-identical dynamics.
        assert_eq!(sparse_out, dense_out, "{topo:?} {} t={t} spikes", qs.name());
        assert_eq!(sparse.vmem_slice(), dense.vmem_slice(), "{topo:?} {} t={t} vmem", qs.name());

        // Neuron-side ledger entries agree exactly.
        assert_eq!(s_stats.spikes, d_stats.spikes, "t={t}");
        assert_eq!(s_stats.vmem_toggles, d_stats.vmem_toggles, "t={t}");
        assert_eq!(s_stats.neuron_updates, d_stats.neuron_updates, "t={t}");
        assert_eq!(s_stats.mem_cycles, d_stats.mem_cycles, "t={t}");
        assert_eq!(s_stats.spk_steps, d_stats.spk_steps, "t={t}");

        // Synaptic ledger: the sparse layer charges exactly the physical
        // (α=1) slots, split between active and gated rows.
        let want_syn: u64 = spikes
            .iter()
            .enumerate()
            .filter(|(_, &s)| s == 1)
            .map(|(i, _)| row_nnz[i])
            .sum();
        assert_eq!(s_stats.synaptic_ops, want_syn, "{topo:?} t={t} synaptic ops");
        assert_eq!(s_stats.gated_ops, nnz_total - want_syn, "{topo:?} t={t} gated ops");

        // The dense twin charges full N-wide rows; for an all-to-all
        // "sparse" layer the two ledgers coincide entirely.
        assert_eq!(d_stats.synaptic_ops + d_stats.gated_ops, (m * n) as u64, "t={t}");
        if topo == Topology::AllToAll {
            assert_eq!(s_stats, d_stats, "t={t}");
        }
    }
}

#[test]
fn all_to_all_parity_all_qspecs() {
    for (k, qs) in [Q9_7, Q5_3, Q3_1].into_iter().enumerate() {
        assert_sparse_dense_parity(Topology::AllToAll, 24, 18, qs, 0xA11_0 + k as u64);
    }
}

#[test]
fn one_to_one_parity_all_qspecs() {
    for (k, qs) in [Q9_7, Q5_3, Q3_1].into_iter().enumerate() {
        assert_sparse_dense_parity(Topology::OneToOne, 20, 20, qs, 0x121_0 + k as u64);
    }
}

#[test]
fn gaussian_r1_parity_all_qspecs() {
    for (k, qs) in [Q9_7, Q5_3, Q3_1].into_iter().enumerate() {
        assert_sparse_dense_parity(Topology::Gaussian { radius: 1 }, 24, 24, qs, 0x6A1 + k as u64);
    }
}

#[test]
fn gaussian_r2_parity_all_qspecs() {
    for (k, qs) in [Q9_7, Q5_3, Q3_1].into_iter().enumerate() {
        assert_sparse_dense_parity(Topology::Gaussian { radius: 2 }, 24, 24, qs, 0x6A2 + k as u64);
    }
}

#[test]
fn gaussian_rectangular_parity() {
    // Unequal layer widths exercise the rescaled receptive-field centring
    // and edge-clipped (variable-width) band windows.
    for (m, n, seed) in [(32usize, 8usize, 0xEC7_1u64), (8, 32, 0xEC7_2), (30, 7, 0xEC7_3)] {
        assert_sparse_dense_parity(Topology::Gaussian { radius: 1 }, m, n, Q5_3, seed);
        assert_sparse_dense_parity(Topology::Gaussian { radius: 2 }, m, n, Q5_3, seed + 16);
    }
}

/// Packed-vs-scalar differential gate: drive one layer through the
/// event-driven packed-plane datapath (`step_plane`: trailing_zeros row
/// iteration, bulk gating charge, SoA quiescence skip) and a twin through
/// the retained dense scalar reference (`step_scalar`: branch per row,
/// full LIF update per neuron). Every step must be **bit-identical** in
/// spike output, membrane trace, and the complete activity ledger
/// (synaptic/gated ops, toggles, neuron updates, mem cycles, spk steps).
/// The spike stream sweeps firing densities 0 / 2% / 35% / 90% so the
/// quiescence fast path, the zero-spike shortcut, and dense saturation are
/// all exercised.
fn assert_packed_scalar_parity(topo: Topology, m: usize, n: usize, qs: QSpec, seed: u64) {
    let mut rng = XorShift64Star::new(seed);
    let weights = masked_random_weights(topo, m, n, qs, &mut rng);

    let cfg = LayerConfig { fan_in: m, neurons: n, topology: topo };
    let mut scalar = Layer::new(&cfg, qs, MemKind::Bram);
    let mut packed = Layer::new(&cfg, qs, MemKind::Bram);
    scalar.memory_mut().load_dense(&weights).unwrap();
    packed.memory_mut().load_dense(&weights).unwrap();

    // Exercise the neuron datapath beyond defaults on half the cases.
    let mut regs = RegisterFile::new(qs);
    if seed % 2 == 1 {
        regs.write(REG_RESET_MODE, 2).unwrap(); // by-subtraction
        regs.write(REG_REFRACTORY, 1).unwrap();
    }

    let mut scalar_out = Vec::new();
    let mut plane_in = SpikePlane::default();
    let mut plane_out = SpikePlane::default();
    for t in 0..T_STEPS {
        let density = [0.0, 0.02, 0.35, 0.9][t % 4];
        let spikes: Vec<u8> = (0..m).map(|_| (rng.uniform() < density) as u8).collect();

        let s_stats = scalar.step_scalar(&spikes, &mut scalar_out, &regs);
        plane_in.load_bytes(&spikes);
        let p_stats = packed.step_plane(&plane_in, &mut plane_out, &regs);

        assert_eq!(plane_out.len(), n, "t={t} output plane arity");
        assert_eq!(plane_out.to_bytes(), scalar_out, "{topo:?} {} t={t} spikes", qs.name());
        assert_eq!(
            packed.vmem_slice(),
            scalar.vmem_slice(),
            "{topo:?} {} t={t} vmem",
            qs.name()
        );
        assert_eq!(p_stats, s_stats, "{topo:?} {} t={t} activity ledger", qs.name());
        // Ledger invariant: per step the two op classes partition the
        // layer's physical (α=1) words, on both paths.
        let words = packed.memory().synapses() as u64;
        assert_eq!(p_stats.synaptic_ops + p_stats.gated_ops, words, "t={t}");
    }
}

#[test]
fn packed_vs_scalar_all_to_all_all_qspecs() {
    for (k, qs) in [Q9_7, Q5_3, Q3_1].into_iter().enumerate() {
        assert_packed_scalar_parity(Topology::AllToAll, 80, 64, qs, 0x9AC_0 + k as u64);
    }
}

#[test]
fn packed_vs_scalar_one_to_one_all_qspecs() {
    for (k, qs) in [Q9_7, Q5_3, Q3_1].into_iter().enumerate() {
        assert_packed_scalar_parity(Topology::OneToOne, 70, 70, qs, 0x9AC_1 + k as u64);
    }
}

#[test]
fn packed_vs_scalar_gaussian_all_qspecs() {
    for (k, qs) in [Q9_7, Q5_3, Q3_1].into_iter().enumerate() {
        let g1 = Topology::Gaussian { radius: 1 };
        let g2 = Topology::Gaussian { radius: 2 };
        assert_packed_scalar_parity(g1, 66, 66, qs, 0x9AC_2 + k as u64);
        assert_packed_scalar_parity(g2, 66, 40, qs, 0x9AC_3 + k as u64);
    }
}

/// Lane-exactness twin gate: drive one lane-batched layer
/// (`Layer::step_lanes`, `lanes` concurrent streams in one `SpikeMatrix`)
/// against `lanes` independent single-sample packed twins
/// (`Layer::step_plane` — the PR 4 hot path). Every lane must be
/// **bit-identical** every step: spike output, membrane trace, and the
/// complete per-lane activity ledger. Streams are ragged — lane `l` ends
/// after `T_STEPS - (l % 9)` steps and is masked out of `active` from then
/// on (its twin stops stepping), so finished lanes must freeze exactly.
/// Firing density sweeps 0 / 2% / 35% / 90% per step, per lane.
fn assert_lane_parity(topo: Topology, m: usize, n: usize, qs: QSpec, seed: u64, lanes: usize) {
    let mut rng = XorShift64Star::new(seed);
    let weights = masked_random_weights(topo, m, n, qs, &mut rng);

    let cfg = LayerConfig { fan_in: m, neurons: n, topology: topo };
    let mut batched = Layer::new(&cfg, qs, MemKind::Bram);
    batched.memory_mut().load_dense(&weights).unwrap();
    let mut twins: Vec<Layer> = (0..lanes).map(|_| batched.clone()).collect();

    let mut regs = RegisterFile::new(qs);
    if seed % 2 == 1 {
        regs.write(REG_RESET_MODE, 2).unwrap(); // by-subtraction
        regs.write(REG_REFRACTORY, 1).unwrap();
    }

    let lens: Vec<usize> = (0..lanes).map(|l| T_STEPS - (l % 9)).collect();
    let mut mat_in = SpikeMatrix::default();
    let mut mat_out = SpikeMatrix::default();
    let mut stats = vec![ActivityStats::default(); lanes];
    let mut plane_in = SpikePlane::default();
    let mut plane_out = SpikePlane::default();
    let mut gather = SpikePlane::default();
    let mut frozen: Vec<Vec<i32>> = vec![Vec::new(); lanes];
    for t in 0..T_STEPS {
        mat_in.resize_clear(m, lanes);
        let mut active = 0u64;
        let mut streams: Vec<Vec<u8>> = Vec::with_capacity(lanes);
        for (l, &len) in lens.iter().enumerate() {
            let density = [0.0, 0.02, 0.35, 0.9][(t + l) % 4];
            let spikes: Vec<u8> = (0..m).map(|_| (rng.uniform() < density) as u8).collect();
            if t < len {
                mat_in.load_lane_bytes(l, &spikes);
                active |= 1 << l;
            }
            streams.push(spikes);
        }
        batched.step_lanes(&mat_in, &mut mat_out, &regs, active, &mut stats);
        assert_eq!((mat_out.lines(), mat_out.lanes()), (n, lanes), "t={t}");
        for (l, twin) in twins.iter_mut().enumerate() {
            let ctx = || format!("{topo:?} {} lanes={lanes} t={t} lane {l}", qs.name());
            if t >= lens[l] {
                // Finished lane: no ledger charge, state frozen at its
                // last stepped value.
                assert_eq!(stats[l], ActivityStats::default(), "{} masked ledger", ctx());
                assert_eq!(batched.lane_vmem(l), frozen[l], "{} frozen vmem", ctx());
                assert!(
                    mat_out.words().iter().all(|&w| (w >> l) & 1 == 0),
                    "{} masked lane spiked",
                    ctx()
                );
                continue;
            }
            plane_in.load_bytes(&streams[l]);
            let want = twin.step_plane(&plane_in, &mut plane_out, &regs);
            mat_out.lane_plane_into(l, &mut gather);
            assert_eq!(gather, plane_out, "{} spikes", ctx());
            assert_eq!(batched.lane_vmem(l), twin.vmem_slice(), "{} vmem", ctx());
            assert_eq!(stats[l], want, "{} activity ledger", ctx());
            if t + 1 == lens[l] {
                frozen[l] = twin.vmem_slice().to_vec();
            }
        }
    }
}

#[test]
fn lane64_vs_single_sample_all_to_all_all_qspecs() {
    for (k, qs) in [Q9_7, Q5_3, Q3_1].into_iter().enumerate() {
        assert_lane_parity(Topology::AllToAll, 48, 40, qs, 0x1A4E_0 + k as u64, 64);
    }
}

#[test]
fn lane64_vs_single_sample_one_to_one_all_qspecs() {
    for (k, qs) in [Q9_7, Q5_3, Q3_1].into_iter().enumerate() {
        assert_lane_parity(Topology::OneToOne, 44, 44, qs, 0x1A4E_1 + k as u64, 64);
    }
}

#[test]
fn lane64_vs_single_sample_gaussian_all_qspecs() {
    for (k, qs) in [Q9_7, Q5_3, Q3_1].into_iter().enumerate() {
        assert_lane_parity(Topology::Gaussian { radius: 1 }, 48, 48, qs, 0x1A4E_2 + k as u64, 64);
        assert_lane_parity(Topology::Gaussian { radius: 2 }, 48, 32, qs, 0x1A4E_3 + k as u64, 64);
    }
}

#[test]
fn ragged_lane_batches_stay_exact() {
    // Lane counts that are not a multiple of 64 (a ragged final group) on
    // every topology — combined with the per-lane unequal stream lengths
    // assert_lane_parity always applies.
    for (k, (topo, m, n)) in [
        (Topology::AllToAll, 40usize, 36usize),
        (Topology::OneToOne, 40, 40),
        (Topology::Gaussian { radius: 1 }, 40, 40),
    ]
    .into_iter()
    .enumerate()
    {
        assert_lane_parity(topo, m, n, Q5_3, 0x8A66_0 + k as u64, 37);
        assert_lane_parity(topo, m, n, Q9_7, 0x8A66_4 + k as u64, 3);
    }
}

/// Acceptance gate: at N = 400, a Gaussian radius-1 layer performs ≥ 5×
/// fewer synaptic accumulates than the all-to-all layer on the same spike
/// stream (it is ~133× here: ≤ 3 vs 400 accumulates per active row).
#[test]
fn gaussian_r1_400_does_5x_fewer_synaptic_ops_than_all_to_all() {
    let n = 400usize;
    let mut rng = XorShift64Star::new(0x400_0E5);
    let spikes: Vec<u8> = (0..n).map(|_| (rng.uniform() < 0.3) as u8).collect();

    let mut ops = Vec::new();
    for topo in [Topology::Gaussian { radius: 1 }, Topology::AllToAll] {
        let cfg = LayerConfig { fan_in: n, neurons: n, topology: topo };
        let mut layer = Layer::new(&cfg, Q5_3, MemKind::Bram);
        let mut out = Vec::new();
        let stats = layer.step(&spikes, &mut out);
        ops.push(stats.synaptic_ops);
    }
    let (gauss, full) = (ops[0], ops[1]);
    assert!(gauss > 0 && full > 0);
    assert!(
        full >= 5 * gauss,
        "expected ≥5× reduction: gaussian r1 {gauss} ops vs all-to-all {full} ops"
    );
    // And the storage shrinks accordingly: 3N-2 vs N².
    let g = quantisenc::hdl::SynapticMemory::new(
        n,
        n,
        Topology::Gaussian { radius: 1 },
        Q5_3,
        MemKind::Bram,
    );
    assert_eq!(g.synapses(), 3 * n - 2);
}
