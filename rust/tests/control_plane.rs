//! Epoch-consistency suite for the live control plane: reconfiguring a
//! serving engine mid-stream must yield results that are, per epoch,
//! bit-identical to a freshly built engine with that epoch's configuration
//! — across topologies, for cfg_in register programs and wt_in weight
//! swaps, delivered in-band and asynchronously.

use quantisenc::config::registers::{RegisterFile, ResetMode, REG_VTH};
use quantisenc::config::{ModelConfig, Topology};
use quantisenc::coordinator::control::{ControlError, ReconfigProgram};
use quantisenc::coordinator::serving::{ServingEngine, ServingOptions, SessionOp};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::datasets::Sample;
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::Core;

fn topology_configs() -> Vec<ModelConfig> {
    vec![
        ModelConfig::parse_arch("24x16x8", Q5_3).unwrap(),
        ModelConfig::with_topologies(&[20, 20, 20], &[Topology::OneToOne, Topology::OneToOne], Q5_3)
            .unwrap(),
        ModelConfig::with_topologies(
            &[24, 24, 8],
            &[Topology::Gaussian { radius: 2 }, Topology::AllToAll],
            Q5_3,
        )
        .unwrap(),
    ]
}

fn mask_weights(cfg: &ModelConfig, rng: &mut XorShift64Star) -> Vec<Vec<i32>> {
    cfg.layers()
        .iter()
        .map(|l| {
            let mask = l.topology.mask(l.fan_in, l.neurons).unwrap();
            mask.iter()
                .map(|&a| if a == 0 { 0 } else { rng.below(15) as i32 - 7 })
                .collect()
        })
        .collect()
}

fn rand_samples(cfg: &ModelConfig, rng: &mut XorShift64Star, count: usize) -> Vec<Sample> {
    (0..count)
        .map(|_| {
            let t_steps = 2 + rng.below(8) as usize;
            let inputs = cfg.inputs();
            let spikes = (0..t_steps * inputs).map(|_| (rng.uniform() < 0.3) as u8).collect();
            Sample { spikes, t_steps, inputs, label: 0 }
        })
        .collect()
}

/// The acceptance property: ≥ 2 reconfig epochs × 3 topologies, interleaved
/// with streaming samples in one live session, compared per epoch against a
/// *freshly built* engine with that epoch's exact configuration.
#[test]
fn prop_live_reconfig_is_bitexact_per_epoch() {
    let mut rng = XorShift64Star::new(0xC0117401);
    for (case, cfg) in topology_configs().into_iter().enumerate() {
        let weights = mask_weights(&cfg, &mut rng);
        let samples = rand_samples(&cfg, &mut rng, 9);
        let regs0 = RegisterFile::new(Q5_3);

        // Epoch 1: raise vth + change reset mode. Epoch 2: swap the last
        // layer's weights (packed payload) on top of epoch 1's registers.
        let mut regs1 = regs0.clone();
        regs1.set_vth(2.0).unwrap();
        regs1.set_reset_mode(ResetMode::ToZero).unwrap();
        let swapped: Vec<Vec<i32>> = {
            let mut w = weights.clone();
            let last = w.len() - 1;
            w[last] = mask_weights(&cfg, &mut rng)[last].clone();
            w
        };
        // Packed payload for the last layer, derived via a scratch core so
        // the test exercises the same packed layout the stages load.
        let mut scratch = Core::new(cfg.clone());
        scratch.load_weights(&swapped).unwrap();
        let packed_last = scratch.layers().last().unwrap().memory().packed().to_vec();

        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs0, ServingOptions::with_cores(2)).unwrap();
        let ops: Vec<SessionOp> = samples[..3]
            .iter()
            .map(SessionOp::Submit)
            .chain([SessionOp::Reconfig(ReconfigProgram::from_registers(&regs1))])
            .chain(samples[3..6].iter().map(SessionOp::Submit))
            .chain([SessionOp::Reconfig(
                ReconfigProgram::new().swap_weights(cfg.num_layers() - 1, packed_last),
            )])
            .chain(samples[6..9].iter().map(SessionOp::Submit))
            .collect();
        let live = engine.run_session(&ops).unwrap();
        assert_eq!(live.len(), 9, "case {case}");
        for (i, r) in live.iter().enumerate() {
            assert_eq!(r.stream_id, i, "case {case}: order preserved across reconfigs");
            assert_eq!(r.epoch, (i / 3) as u64, "case {case} sample {i}: wrong epoch");
        }

        // Reference: a freshly built engine per epoch, never reconfigured.
        let epochs: [(&RegisterFile, &Vec<Vec<i32>>); 3] =
            [(&regs0, &weights), (&regs1, &weights), (&regs1, &swapped)];
        for (e, &(regs, w)) in epochs.iter().enumerate() {
            let mut fresh =
                ServingEngine::new(&cfg, w, regs, ServingOptions::with_cores(1)).unwrap();
            let want = fresh.run_batch(&samples[e * 3..(e + 1) * 3]).unwrap();
            for (i, (lr, fr)) in live[e * 3..(e + 1) * 3].iter().zip(&want).enumerate() {
                assert_eq!(
                    lr.counts, fr.counts,
                    "case {case} epoch {e} sample {i}: live engine diverged from fresh build"
                );
                assert_eq!(lr.prediction, fr.prediction, "case {case} epoch {e} sample {i}");
                assert_eq!(
                    lr.stats, fr.stats,
                    "case {case} epoch {e} sample {i}: activity ledger diverged"
                );
            }
            // And against the sequential core, closing the loop to the
            // cycle-accurate reference.
            let mut core = Core::new(cfg.clone());
            core.load_weights(w).unwrap();
            core.registers = (*regs).clone();
            for (i, s) in samples[e * 3..(e + 1) * 3].iter().enumerate() {
                let seq = core.run(s);
                assert_eq!(live[e * 3 + i].counts, seq.counts, "case {case} epoch {e} vs core");
                assert_eq!(live[e * 3 + i].stats, seq.stats, "case {case} epoch {e} vs core");
            }
        }
    }
}

/// Asynchronous applies through a cloned handle on another thread: whatever
/// epoch each result reports, it must match a fresh engine built with that
/// epoch's config (the grouping is timing-dependent, the bit-exactness is
/// not).
#[test]
fn async_reconfig_results_match_their_reported_epoch() {
    let cfg = ModelConfig::parse_arch("24x16x8", Q5_3).unwrap();
    let mut rng = XorShift64Star::new(0xA57C);
    let weights = mask_weights(&cfg, &mut rng);
    let samples = rand_samples(&cfg, &mut rng, 12);
    let regs0 = RegisterFile::new(Q5_3);
    let mut regs1 = regs0.clone();
    regs1.set_vth(3.0).unwrap();

    let mut engine =
        ServingEngine::new(&cfg, &weights, &regs0, ServingOptions::with_cores(2)).unwrap();
    let control = engine.control_plane();
    let applier = std::thread::spawn(move || {
        control.apply(ReconfigProgram::from_registers(&regs1)).unwrap()
    });
    let first = engine.run_batch(&samples[..6]).unwrap();
    let epoch = applier.join().unwrap();
    assert_eq!(epoch, 1);
    let second = engine.run_batch(&samples[6..]).unwrap();
    assert!(second.iter().all(|r| r.epoch == 1), "pending program must land by next batch");

    let mut regs1 = regs0.clone();
    regs1.set_vth(3.0).unwrap();
    let per_epoch = [&regs0, &regs1];
    let mut core = Core::new(cfg.clone());
    core.load_weights(&weights).unwrap();
    for (batch, offset) in [(&first, 0usize), (&second, 6)] {
        for r in batch.iter() {
            core.registers = per_epoch[r.epoch as usize].clone();
            let seq = core.run(&samples[offset + r.stream_id]);
            assert_eq!(r.counts, seq.counts, "stream {} epoch {}", r.stream_id, r.epoch);
        }
    }
}

/// Typed rejection: a malformed program never changes the engine, its
/// epoch, or its ledger — and the live path keeps serving afterwards.
#[test]
fn rejected_programs_leave_engine_serving() {
    let cfg = ModelConfig::parse_arch("16x8x4", Q5_3).unwrap();
    let mut rng = XorShift64Star::new(0xBAD);
    let weights = mask_weights(&cfg, &mut rng);
    let samples = rand_samples(&cfg, &mut rng, 4);
    let regs = RegisterFile::new(Q5_3);
    let mut engine =
        ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
    let control = engine.control_plane();
    let bus0 = control.bus();

    assert!(matches!(
        control.apply(ReconfigProgram::new().write(6, 0)),
        Err(ControlError::Register(_))
    ));
    assert!(matches!(
        control.apply(ReconfigProgram::new().write(REG_VTH, 30_000)),
        Err(ControlError::Register(_))
    ));
    assert!(matches!(
        control.apply(ReconfigProgram::new().swap_weights(5, vec![])),
        Err(ControlError::BadLayer { .. })
    ));
    assert_eq!(control.epoch(), 0);
    assert_eq!(control.bus(), bus0);

    let out = engine.run_batch(&samples).unwrap();
    assert_eq!(out.len(), 4);
    assert!(out.iter().all(|r| r.epoch == 0));
}
