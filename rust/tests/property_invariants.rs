//! Property tests over coordinator/core invariants (hand-rolled generators
//! driven by the repo's xorshift PRNG — proptest is unavailable offline;
//! each property runs across many randomized cases with printed seeds so
//! failures are reproducible).

mod common;

use quantisenc::config::registers::{RegisterFile, ResetMode, NUM_REGS, REG_REFRACTORY, REG_RESET_MODE};
use quantisenc::hdl::neuron::{step_soa, RegSnapshot};
use quantisenc::config::{ModelConfig, Topology};
use quantisenc::coordinator::multicore::MultiCore;
use quantisenc::coordinator::pipeline::run_pipelined;
use quantisenc::coordinator::serving::{ServingEngine, ServingOptions};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::datasets::Sample;
use quantisenc::fixed::{QSpec, Q17_15, Q2_2, Q3_1, Q5_3, Q9_7};
use quantisenc::hdl::{aer, Core, PlanePool, SpikeMatrix, SpikePlane};

/// Random architecture over all three connection topologies (Eq. 9): every
/// layer independently draws all-to-all, one-to-one (forcing equal widths),
/// or a Gaussian receptive field of radius 1–3 — so every property below
/// covers the sparse (diagonal/banded) synaptic stores, not just the dense
/// one.
fn random_config(rng: &mut XorShift64Star) -> ModelConfig {
    let qs = [Q2_2, Q5_3, Q9_7][rng.below(3) as usize];
    let n_layers = 1 + rng.below(3) as usize;
    let mut sizes = vec![4 + rng.below(28) as usize];
    let mut topos = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let prev = *sizes.last().unwrap();
        match rng.below(4) {
            0 => {
                sizes.push(prev);
                topos.push(Topology::OneToOne);
            }
            1 => {
                sizes.push(2 + rng.below(24) as usize);
                topos.push(Topology::Gaussian { radius: 1 + rng.below(3) as u32 });
            }
            _ => {
                sizes.push(2 + rng.below(24) as usize);
                topos.push(Topology::AllToAll);
            }
        }
    }
    ModelConfig::with_topologies(&sizes, &topos, qs).unwrap()
}

/// Dense per-layer matrices with random weights at α=1 positions and zeros
/// at pruned positions (the artifact-file contract for sparse topologies).
fn random_weights(cfg: &ModelConfig, rng: &mut XorShift64Star) -> Vec<Vec<i32>> {
    cfg.layers()
        .iter()
        .map(|l| {
            let lim = cfg.qspec.max_raw().min(127) as u64;
            let mask = l.topology.mask(l.fan_in, l.neurons).unwrap();
            mask.iter()
                .map(|&a| if a == 0 { 0 } else { (rng.below(2 * lim + 1) as i32) - lim as i32 })
                .collect()
        })
        .collect()
}

fn random_samples(cfg: &ModelConfig, rng: &mut XorShift64Star, count: usize) -> Vec<Sample> {
    (0..count)
        .map(|_| {
            let t_steps = 1 + rng.below(12) as usize;
            let inputs = cfg.inputs();
            let spikes = (0..t_steps * inputs).map(|_| (rng.uniform() < 0.3) as u8).collect();
            Sample { spikes, t_steps, inputs, label: 0 }
        })
        .collect()
}

/// Pipelined scheduling must never change results, for any topology/shape.
#[test]
fn prop_pipeline_equals_sequential() {
    let mut rng = XorShift64Star::new(0x5EED_01);
    for case in 0..15 {
        let cfg = random_config(&mut rng);
        let weights = random_weights(&cfg, &mut rng);
        let n_samples = 1 + rng.below(5) as usize;
        let samples = random_samples(&cfg, &mut rng, n_samples);
        let mut regs = RegisterFile::new(cfg.qspec);
        regs.write(REG_RESET_MODE, rng.below(4) as i32).unwrap();
        regs.write(REG_REFRACTORY, rng.below(4) as i32).unwrap();

        let piped = run_pipelined(&cfg, &weights, &regs, &samples).unwrap();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs;
        for (i, s) in samples.iter().enumerate() {
            let seq = core.run(s);
            assert_eq!(piped[i].counts, seq.counts, "case {case} ({}) stream {i}", cfg.arch_name());
        }
    }
}

/// Multicore batch sharding must be order- and core-count-invariant.
#[test]
fn prop_multicore_core_count_invariant() {
    let mut rng = XorShift64Star::new(0x5EED_02);
    for case in 0..8 {
        let cfg = random_config(&mut rng);
        let weights = random_weights(&cfg, &mut rng);
        let samples = random_samples(&cfg, &mut rng, 6);
        let regs = RegisterFile::new(cfg.qspec);
        let base = MultiCore::new(&cfg, &weights, &regs, 1).unwrap().run_batch(&samples);
        for cores in [2usize, 3, 5] {
            let out = MultiCore::new(&cfg, &weights, &regs, cores).unwrap().run_batch(&samples);
            for (a, b) in base.iter().zip(&out) {
                assert_eq!(a.counts, b.counts, "case {case} cores {cores}");
            }
        }
    }
}

/// AER encode/decode round-trips any binary spike matrix.
#[test]
fn prop_aer_roundtrip() {
    let mut rng = XorShift64Star::new(0x5EED_03);
    for _ in 0..50 {
        let t = 1 + rng.below(20) as usize;
        let w = 1 + rng.below(60) as usize;
        let spikes: Vec<u8> = (0..t * w).map(|_| (rng.uniform() < 0.25) as u8).collect();
        let events = aer::encode(&spikes, t, w);
        assert_eq!(aer::decode(&events, t, w).unwrap(), spikes);
    }
}

/// Core state is fully reset between runs: repeated runs are idempotent,
/// for every reset mode and refractory setting.
#[test]
fn prop_run_idempotent_across_register_settings() {
    let mut rng = XorShift64Star::new(0x5EED_04);
    for _ in 0..10 {
        let cfg = random_config(&mut rng);
        let weights = random_weights(&cfg, &mut rng);
        let samples = random_samples(&cfg, &mut rng, 1);
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        for mode in ResetMode::all() {
            core.registers.set_reset_mode(mode).unwrap();
            let a = core.run(&samples[0]);
            let b = core.run(&samples[0]);
            assert_eq!(a.counts, b.counts, "{mode:?}");
            assert_eq!(a.stats, b.stats, "{mode:?}");
        }
    }
}

/// Raising Vth can only reduce (or keep) total spikes; zero input ⇒ silence.
#[test]
fn prop_vth_monotone_and_silence() {
    let mut rng = XorShift64Star::new(0x5EED_05);
    for _ in 0..10 {
        let cfg = random_config(&mut rng);
        let weights = random_weights(&cfg, &mut rng);
        let sample = &random_samples(&cfg, &mut rng, 1)[0];
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        let mut prev = u64::MAX;
        let max_v = cfg.qspec.to_float(cfg.qspec.max_raw());
        for frac in [0.1, 0.4, 0.9] {
            core.registers.set_vth(max_v * frac).unwrap();
            let r = core.run(sample);
            assert!(r.stats.spikes <= prev, "spikes must fall as Vth rises");
            prev = r.stats.spikes;
        }
        let silent = Sample {
            spikes: vec![0; sample.spikes.len()],
            t_steps: sample.t_steps,
            inputs: sample.inputs,
            label: 0,
        };
        assert_eq!(core.run(&silent).stats.spikes, 0);
    }
}

/// Activity accounting is conserved: gated + active synaptic slots equal
/// (physical α=1 synapses per step) × steps, for every topology — the
/// sparse stores only ever charge the slots they actually instantiate.
#[test]
fn prop_activity_conservation() {
    let mut rng = XorShift64Star::new(0x5EED_06);
    for _ in 0..10 {
        let cfg = random_config(&mut rng);
        let weights = random_weights(&cfg, &mut rng);
        let sample = &random_samples(&cfg, &mut rng, 1)[0];
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        let r = core.run(sample);
        let slots_per_step = cfg.total_synapses() as u64;
        assert_eq!(slots_per_step, core.synapse_words() as u64);
        assert_eq!(
            r.stats.synaptic_ops + r.stats.gated_ops,
            slots_per_step * sample.t_steps as u64
        );
        assert_eq!(r.stats.neuron_updates, cfg.compute_neurons() as u64 * sample.t_steps as u64);
    }
}

/// Register file rejects every out-of-domain write and never partially
/// applies one (failure injection across the whole address space).
#[test]
fn prop_register_file_rejects_cleanly() {
    let mut rng = XorShift64Star::new(0x5EED_07);
    for qs in [Q2_2, Q5_3, Q9_7] {
        let mut rf = RegisterFile::new(qs);
        let snapshot = rf.vector();
        let mut rejected = 0;
        for _ in 0..200 {
            let addr = rng.below(10) as usize;
            let val = (rng.next_u64() as i32) % 100_000;
            let before = rf.vector();
            if rf.write(addr, val).is_err() {
                rejected += 1;
                assert_eq!(rf.vector(), before, "failed write must not mutate");
            }
        }
        assert!(rejected > 0, "generator never produced an invalid write");
        // defaults still parseable as a valid configuration
        assert!(ResetMode::from_i32(snapshot[4]).is_some());
        let _ = snapshot;
    }
}

/// One-to-one and gaussian cores never spike wider than their connectivity
/// allows: a one-to-one layer's output spikes are bounded by its input's.
#[test]
fn prop_one_to_one_locality() {
    let mut rng = XorShift64Star::new(0x5EED_08);
    for _ in 0..10 {
        let n = 4 + rng.below(20) as usize;
        let cfg = ModelConfig::with_topologies(&[n, n], &[Topology::OneToOne], Q5_3).unwrap();
        let mut core = Core::new(cfg.clone());
        // Strong positive diagonal weights.
        for i in 0..n {
            core.layer_mut(0)
                .memory_mut()
                .write(i, i, Q5_3.from_float(2.0))
                .unwrap();
        }
        let t_steps = 5;
        let spikes: Vec<u8> = (0..t_steps * n).map(|_| (rng.uniform() < 0.5) as u8).collect();
        let sample = Sample { spikes: spikes.clone(), t_steps, inputs: n, label: 0 };
        let r = core.run(&sample);
        // Neuron j can only spike if input j ever spiked.
        for j in 0..n {
            let input_ever: bool = (0..t_steps).any(|t| spikes[t * n + j] != 0);
            if !input_ever {
                // count output spikes of neuron j by rerunning trace
                assert_eq!(r.counts[j] == 0, true, "neuron {j} spiked without input");
            }
        }
    }
}

/// Fixed-point saturation: `from_float` clamps to [min_raw, max_raw] for
/// arbitrary (including non-finite-free extreme) floats, and the clamped
/// value round-trips through `to_float`/`from_float`.
#[test]
fn prop_from_float_saturates_to_bounds() {
    let mut rng = XorShift64Star::new(0x5EED_10);
    for qs in [Q2_2, Q3_1, Q5_3, Q9_7, Q17_15] {
        let max_v = qs.to_float(qs.max_raw());
        let min_v = qs.to_float(qs.min_raw());
        for _ in 0..300 {
            let x = (rng.uniform() - 0.5) * 1e7;
            let raw = qs.from_float(x);
            assert!(qs.in_range(raw), "{qs}: from_float({x}) -> {raw} out of range");
            if x >= max_v {
                assert_eq!(raw, qs.max_raw(), "{qs}: {x} must saturate high");
            }
            if x <= min_v {
                assert_eq!(raw, qs.min_raw(), "{qs}: {x} must saturate low");
            }
            // Representable values are fixed points of the conversion.
            assert_eq!(qs.from_float(qs.to_float(raw)), raw, "{qs} round-trip of {raw}");
        }
    }
}

/// Sign-extension round-trips: any in-range raw value is a fixed point of
/// `wrap`, and wrapping is periodic with period 2^W (the silicon register
/// semantics).
#[test]
fn prop_wrap_sign_extension_roundtrip() {
    let mut rng = XorShift64Star::new(0x5EED_11);
    for qs in [Q2_2, Q3_1, Q5_3, Q9_7, Q17_15] {
        let period = 1i128 << qs.width();
        for _ in 0..300 {
            let raw = qs.wrap(rng.next_u64() as i64);
            assert_eq!(qs.wrap(raw as i64), raw, "{qs}: wrap must fix in-range values");
            // Shift by a few whole periods (stay inside i64).
            let k = (rng.below(7) as i128) - 3;
            let shifted = raw as i128 + k * period;
            if shifted >= i64::MIN as i128 && shifted <= i64::MAX as i128 {
                assert_eq!(qs.wrap(shifted as i64), raw, "{qs}: wrap must be mod-2^W");
            }
        }
    }
}

/// The unified ServingEngine must equal the sequential core bit-for-bit for
/// random topologies, register files (all reset modes / refractory values),
/// and core counts — and must agree with MultiCore on the same batch.
#[test]
fn prop_serving_engine_equals_sequential_core() {
    let mut rng = XorShift64Star::new(0x5EED_12);
    for case in 0..8 {
        let cfg = random_config(&mut rng);
        let weights = random_weights(&cfg, &mut rng);
        let samples = random_samples(&cfg, &mut rng, 5);
        let mut regs = RegisterFile::new(cfg.qspec);
        regs.write(REG_RESET_MODE, rng.below(4) as i32).unwrap();
        regs.write(REG_REFRACTORY, rng.below(4) as i32).unwrap();

        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        let reference: Vec<_> = samples.iter().map(|s| core.run(s)).collect();

        for (cores, lane_width) in [(1usize, 1usize), (3, 1), (2, 4), (1, 64)] {
            let mut engine = ServingEngine::new(
                &cfg,
                &weights,
                &regs,
                ServingOptions::with_lanes(cores, lane_width),
            )
            .unwrap();
            let out = engine.run_batch(&samples).unwrap();
            assert_eq!(out.len(), samples.len());
            for (i, (r, want)) in out.iter().zip(&reference).enumerate() {
                assert_eq!(
                    r.counts, want.counts,
                    "case {case} cores {cores} lanes {lane_width} sample {i} ({})",
                    cfg.arch_name()
                );
                assert_eq!(
                    r.prediction, want.prediction,
                    "case {case} cores {cores} lanes {lane_width} sample {i}"
                );
                assert_eq!(r.stats, want.stats, "case {case} cores {cores} lanes {lane_width}");
            }
        }

        let mc = MultiCore::new(&cfg, &weights, &regs, 2).unwrap().run_batch(&samples);
        for (r, want) in mc.iter().zip(&reference) {
            assert_eq!(r.counts, want.counts, "case {case}: MultiCore diverged");
        }
    }
}

/// SpikePlane properties over random bitmaps: `iter_ones` yields exactly
/// the firing indices in ascending order, popcount equals the byte nnz,
/// the byte round-trip is lossless, and `get` agrees with the source bytes
/// — across lengths straddling the u64 word boundaries, including a
/// recycled (previously wider, all-ones) buffer that must not leak ghost
/// tail bits.
#[test]
fn prop_spike_plane_random_bitmaps() {
    let mut rng = XorShift64Star::new(0x5B17_B175);
    let mut recycled = SpikePlane::from_bytes(&vec![1u8; 321]);
    for case in 0..300 {
        let len = match case % 5 {
            0 => rng.below(4) as usize,          // degenerate: 0..3 lines
            1 => 63 + rng.below(3) as usize,     // word boundary 63/64/65
            2 => 127 + rng.below(3) as usize,    // boundary 127/128/129
            _ => rng.below(320) as usize,
        };
        let density = [0.0, 0.02, 0.5, 1.0][rng.below(4) as usize];
        let bytes: Vec<u8> = (0..len).map(|_| (rng.uniform() < density) as u8).collect();

        let fresh = SpikePlane::from_bytes(&bytes);
        recycled.load_bytes(&bytes); // reuses the 321-line allocation
        for plane in [&fresh, &recycled] {
            assert_eq!(plane.len(), len, "case {case}");
            let ones: Vec<usize> = plane.iter_ones().collect();
            let want: Vec<usize> = bytes
                .iter()
                .enumerate()
                .filter(|(_, &b)| b != 0)
                .map(|(i, _)| i)
                .collect();
            assert_eq!(ones, want, "case {case} len {len}: iteration order/content");
            assert_eq!(plane.count_ones(), want.len(), "case {case} popcount");
            assert_eq!(plane.to_bytes(), bytes, "case {case} byte round-trip");
            for (i, &b) in bytes.iter().enumerate() {
                assert_eq!(plane.get(i), b != 0, "case {case} line {i}");
            }
            // Tail invariant: no ghost bits beyond len in the last word.
            assert_eq!(
                plane.words().iter().map(|w| w.count_ones() as usize).sum::<usize>(),
                want.len(),
                "case {case} tail bits"
            );
        }
        assert_eq!(fresh, recycled, "case {case} equality across allocations");
    }
}

/// A pre-filled [`PlanePool`] must never miss under recycle churn from
/// multiple threads: as long as each thread holds at most one plane at a
/// time and the pool is pre-filled with one plane per thread, every `take`
/// finds a recycled buffer — the multi-threaded statement of the serving
/// engine's zero-alloc invariant.
#[test]
fn prop_plane_pool_zero_misses_under_concurrent_churn() {
    use std::sync::Arc;
    for threads in [2usize, 4, 8] {
        let pool = Arc::new(PlanePool::prefilled(threads, 256));
        std::thread::scope(|scope| {
            for tid in 0..threads {
                let pool = pool.clone();
                scope.spawn(move || {
                    let mut rng = XorShift64Star::new(0xC0_11 + tid as u64);
                    for _ in 0..500 {
                        let mut plane = pool.take();
                        let len = 1 + rng.below(256) as usize;
                        plane.resize_clear(len);
                        plane.set(len - 1);
                        assert_eq!(plane.count_ones(), 1);
                        pool.put(plane);
                    }
                });
            }
        });
        assert_eq!(pool.misses(), 0, "{threads} churning threads drained a full pool");
        assert_eq!(pool.available(), threads);
    }
}

/// `Topology::row_windows` band edges: radii at or beyond the layer width
/// degenerate to full rows, single-column/single-row layers clip to the
/// grid, and every window — first and last rows especially — agrees with
/// an independent mask scan on non-square shapes.
#[test]
fn prop_row_windows_band_edges() {
    // Saturated radius: once r covers the whole pre-index range (r >= n
    // suffices for square layers, r >= m + n for any shape), every row's
    // window degenerates to the full [0, n-1] span.
    for (m, n, radius) in [
        (6usize, 6usize, 6u32), // square: r == n already saturates
        (6, 6, 1000),
        (4, 9, 13),
        (9, 4, 13),
        (9, 4, 1000),
    ] {
        let topo = Topology::Gaussian { radius };
        let windows = topo.row_windows(m, n).unwrap();
        assert_eq!(windows.len(), m);
        for (i, win) in windows.iter().enumerate() {
            assert_eq!(*win, Some((0, n - 1)), "r={radius} {m}x{n} row {i} not full");
        }
    }
    // n = 1 (single post neuron): the window is column 0 for rows inside
    // the receptive field and None (fully pruned) outside it — the
    // first/last rows of a tall layer are exactly where clipping bites.
    for (m, radius) in [(1usize, 0u32), (7, 0), (7, 1), (12, 2)] {
        let topo = Topology::Gaussian { radius };
        let mask = topo.mask(m, 1).unwrap();
        let windows = topo.row_windows(m, 1).unwrap();
        for (i, win) in windows.iter().enumerate() {
            match *win {
                None => assert_eq!(mask[i], 0, "m={m} r={radius} row {i}"),
                Some((lo, hi)) => {
                    assert_eq!((lo, hi), (0, 0), "m={m} r={radius} row {i}");
                    assert_eq!(mask[i], 1, "m={m} r={radius} row {i}");
                }
            }
        }
        // Centre row is always connected; fully-pruned rows only at edges.
        assert!(windows[(m - 1) / 2].is_some(), "m={m} r={radius} centre row pruned");
    }
    // Non-square M×N sweeps: first/last-row windows and every in-between
    // row must match the mask's first/last α=1 columns exactly.
    let mut rng = XorShift64Star::new(0x8A2D_0);
    for _ in 0..40 {
        let m = 1 + rng.below(24) as usize;
        let n = 1 + rng.below(24) as usize;
        let radius = rng.below(6) as u32;
        let topo = Topology::Gaussian { radius };
        let mask = topo.mask(m, n).unwrap();
        let windows = topo.row_windows(m, n).unwrap();
        for (i, win) in windows.iter().enumerate() {
            let row = &mask[i * n..(i + 1) * n];
            let first = row.iter().position(|&x| x == 1);
            let last = row.iter().rposition(|&x| x == 1);
            assert_eq!(
                *win,
                first.map(|lo| (lo, last.unwrap())),
                "{m}x{n} r={radius} row {i} (first/last rows included)"
            );
        }
    }
}

/// SpikeMatrix transpose round-trip: L random planes in, lane-words out,
/// each lane gathered back must equal its source plane, and a recycled
/// (previously wider, denser) matrix must not leak ghost lane bits.
#[test]
fn prop_spike_matrix_transpose_roundtrip() {
    let mut rng = XorShift64Star::new(0x7A05_B);
    let mut recycled = SpikeMatrix::new(300, 64);
    for case in 0..60 {
        recycled.resize_clear(300, 64);
        for i in 0..300 {
            recycled.set_line_word(i, u64::MAX); // dirty it
        }
        let lines = 1 + rng.below(280) as usize;
        let lanes = 1 + rng.below(64) as usize;
        let density = [0.0, 0.05, 0.5, 1.0][rng.below(4) as usize];
        let planes: Vec<SpikePlane> = (0..lanes)
            .map(|_| {
                let bytes: Vec<u8> =
                    (0..lines).map(|_| (rng.uniform() < density) as u8).collect();
                SpikePlane::from_bytes(&bytes)
            })
            .collect();
        recycled.resize_clear(lines, lanes);
        for (l, p) in planes.iter().enumerate() {
            recycled.set_lane_from_plane(l, p);
        }
        let want: usize = planes.iter().map(|p| p.count_ones()).sum();
        assert_eq!(recycled.count_ones(), want, "case {case} ghost lane bits");
        let mut back = SpikePlane::default();
        for (l, p) in planes.iter().enumerate() {
            recycled.lane_plane_into(l, &mut back);
            assert_eq!(&back, p, "case {case} lane {l}");
        }
        assert_eq!(
            recycled.words().iter().map(|w| (w & !recycled.lane_mask())).sum::<u64>(),
            0,
            "case {case}: bits beyond lane {lanes}"
        );
    }
}

/// The documented LIF step semantics (DESIGN.md §2: refractory hold, then
/// Eq. 3 VmemDyn, Eq. 7 reset mux, refractory arm), restated from the
/// public [`QSpec`] primitives — the specification `step_soa` is pinned to
/// at every saturation corner below.
fn spec_step(qs: QSpec, regs: &RegSnapshot, vmem: i32, refcnt: i32, act: i32) -> (i32, i32, bool) {
    if refcnt > 0 {
        return (vmem, refcnt - 1, false);
    }
    let v_new = qs.add(qs.sub(vmem, qs.mul(regs.decay, vmem)), qs.mul(regs.growth, act));
    let spike = v_new >= regs.vth;
    let v = if spike {
        match regs.mode {
            ResetMode::Default => qs.sub(v_new, qs.mul(regs.decay, v_new)),
            ResetMode::ToZero => 0,
            ResetMode::BySubtraction => qs.sub(v_new, regs.vth),
            ResetMode::ToConstant => regs.vreset,
        }
    } else {
        v_new
    };
    (v, if spike { regs.refractory } else { refcnt }, spike)
}

/// `neuron::step_soa` pinned to the documented step semantics at every
/// saturation boundary of the three shipped QSpecs — vmem at ±max and one
/// ulp inside, activations at both raw extremes, thresholds at both
/// extremes, zero decay, refractory corners — plus seeded perturbations
/// within ±2 ulps of each corner. This corner corpus (`tests/common`) is
/// the exact set the SIMD differential suite replays through the vector
/// kernels.
#[test]
fn prop_step_soa_saturation_corners() {
    let mut rng = XorShift64Star::new(0x5EED_20);
    for qs in [Q9_7, Q5_3, Q3_1] {
        for (tag, regs) in common::corner_reg_sets(qs) {
            for corner in common::corner_states(qs) {
                let mut starts = vec![(corner.vmem, corner.act)];
                for _ in 0..4 {
                    let dv = (rng.below(5) as i64) - 2;
                    let da = (rng.below(5) as i64) - 2;
                    starts.push((
                        qs.wrap(corner.vmem as i64 + dv),
                        qs.wrap(corner.act as i64 + da),
                    ));
                }
                for (v0, act) in starts {
                    let (mut v, mut r) = (v0, corner.refcnt);
                    let out = step_soa(&mut v, &mut r, act, &regs, qs);
                    let (want_v, want_r, want_spike) = spec_step(qs, &regs, v0, corner.refcnt, act);
                    let ctx = format!("{tag} / {} v0={v0} act={act}", corner.name);
                    assert_eq!(v, want_v, "{ctx}: vmem");
                    assert_eq!(r, want_r, "{ctx}: refcnt");
                    assert_eq!(out.spike, want_spike, "{ctx}: spike");
                    assert_eq!(out.vmem_toggled, v != v0, "{ctx}: toggle flag");
                    assert!(qs.in_range(v), "{ctx}: vmem {v} left the Qn.q range");
                    assert!(r >= 0, "{ctx}: refcnt went negative");
                }
            }
        }
    }
}

/// Zero decay with silent input is an *exact* hold: from any
/// sub-threshold corner state, vmem is bit-frozen across 220 steps with no
/// spikes and no register toggles — the invariant both the layer's
/// quiescence fast path and the SIMD kernels' full-datapath no-op proof
/// rest on.
#[test]
fn prop_step_soa_zero_decay_holds_exactly() {
    for qs in [Q9_7, Q5_3, Q3_1] {
        let regs = RegSnapshot {
            decay: 0,
            vth: qs.max_raw(),
            ..RegSnapshot::from(&RegisterFile::new(qs))
        };
        for corner in common::corner_states(qs) {
            if corner.vmem >= regs.vth || corner.refcnt > 0 {
                continue;
            }
            let (mut v, mut r) = (corner.vmem, 0);
            for step in 0..220 {
                let out = step_soa(&mut v, &mut r, 0, &regs, qs);
                assert!(
                    !out.spike && !out.vmem_toggled,
                    "{qs} {} step {step}: zero-decay hold emitted activity",
                    corner.name
                );
                assert_eq!(v, corner.vmem, "{qs} {} step {step}: hold broke", corner.name);
            }
        }
    }
}

/// Refractory arming and countdown: with `vth = min_raw` every
/// non-refractory update spikes, so the spike train must have exact period
/// `refractory + 1` — spike (re-arming the counter), `refractory` hold
/// steps with vmem frozen and the counter stepping down by exactly one,
/// release, spike again — for every reset mode, including a 250-cycle
/// period that rolls the counter far beyond any sweep in the SIMD suite.
#[test]
fn prop_step_soa_refcnt_rollover_period() {
    for qs in [Q9_7, Q5_3, Q3_1] {
        for refractory in [1i32, 3, 250] {
            for mode in ResetMode::all() {
                let regs = RegSnapshot {
                    vth: qs.min_raw(),
                    refractory,
                    mode,
                    ..RegSnapshot::from(&RegisterFile::new(qs))
                };
                let (mut v, mut r) = (0i32, 0i32);
                let period = refractory as usize + 1;
                for step in 0..3 * period {
                    let held = v;
                    let out = step_soa(&mut v, &mut r, 1, &regs, qs);
                    let ctx = format!("{qs} {mode:?} refractory={refractory} step {step}");
                    if step % period == 0 {
                        assert!(out.spike, "{ctx}: release step must spike");
                        assert_eq!(r, refractory, "{ctx}: counter must re-arm");
                    } else {
                        assert!(!out.spike, "{ctx}: hold step spiked");
                        assert_eq!(v, held, "{ctx}: vmem moved during hold");
                        assert_eq!(
                            r,
                            refractory - (step % period) as i32,
                            "{ctx}: countdown must step by exactly one"
                        );
                    }
                }
            }
        }
    }
}

/// QSpec parse/name round-trips for every legal (n, q).
#[test]
fn prop_qspec_roundtrip_exhaustive() {
    for n in 1u8..=32 {
        for q in 0u8..=31 {
            if (n as u32 + q as u32) <= 32 {
                let qs = QSpec::new(n, q).unwrap();
                assert_eq!(QSpec::parse(&qs.name()).unwrap(), qs);
                assert_eq!(NUM_REGS, 6);
            }
        }
    }
}
