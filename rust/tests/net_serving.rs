//! End-to-end suite for the network front door: concurrent TCP clients
//! must get results bit-identical to the sequential `Core::run` oracle —
//! including across an in-band per-tenant reconfiguration — and every
//! failure mode (overload, bad session, bad program, bad sample, garbage
//! bytes) must come back as a typed per-request error that leaves the
//! server and every other tenant serving.

use std::net::TcpStream;
use std::time::Duration;

use quantisenc::config::registers::{RegisterFile, REG_VTH};
use quantisenc::config::ModelConfig;
use quantisenc::coordinator::client::{self, LoadgenOptions, RetryPolicy, WireClient};
use quantisenc::coordinator::connectome::Connectome;
use quantisenc::coordinator::control::ReconfigProgram;
use quantisenc::coordinator::server::{ServerOptions, ServerStats, SpikeServer};
use quantisenc::coordinator::serving::chaos::{ChaosEvent, ChaosKind, ChaosSchedule};
use quantisenc::coordinator::serving::{ServingEngine, ServingOptions};
use quantisenc::coordinator::wire::{self, ErrorCode, Frame, DEFAULT_MAX_FRAME_LEN};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::datasets::{Dataset, Sample, Split};
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::Core;

/// The shared fixture: a 256x24x10 core with seeded random weights (the
/// same construction as the serving-engine unit suite).
fn fixture() -> (ModelConfig, Vec<Vec<i32>>, RegisterFile) {
    let cfg = ModelConfig::parse_arch("256x24x10", Q5_3).unwrap();
    let mut rng = XorShift64Star::new(0x5E21);
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(15) as i32 - 7).collect())
        .collect();
    let regs = RegisterFile::new(Q5_3);
    (cfg, weights, regs)
}

fn spawn_server(cores: usize, lanes: usize, options: ServerOptions) -> SpikeServer {
    let (cfg, weights, regs) = fixture();
    let engine =
        ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_lanes(cores, lanes))
            .unwrap();
    SpikeServer::bind(engine, "127.0.0.1:0", options).unwrap()
}

/// Bounded poll for a server-side counter condition. Handlers bump their
/// counters before queueing the reply frame, so asserting right after the
/// client observes the reply happens to be ordered today — but that is an
/// internal ordering the tests must not depend on. Polling with a hard
/// deadline keeps the assertions exact (the awaited value, not `>=`)
/// without a fixed hope-sized sleep.
fn wait_for_stats(
    server: &SpikeServer,
    what: &str,
    cond: impl Fn(&ServerStats) -> bool,
) -> ServerStats {
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let stats = server.stats();
        if cond(&stats) {
            return stats;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "timed out waiting for {what}; last stats: {stats:?}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn hello_reports_engine_geometry() {
    let server = spawn_server(2, 4, ServerOptions::default());
    let addr = server.local_addr().to_string();
    let client = WireClient::connect(&addr).unwrap();
    assert_eq!(client.hello.inputs, 256);
    assert_eq!(client.hello.outputs, 10);
    assert_eq!(client.hello.cores, 2);
    assert_eq!(client.hello.lane_width, 4);
}

#[test]
fn concurrent_sessions_bitexact_with_inband_reconfig() {
    let (cfg, weights, regs) = fixture();
    // Per-epoch oracles: epoch 0 is the construction registers; epoch 1 is
    // the raised threshold the reconfig below programs.
    let raised_vth = regs.vth() + 8; // +1.0 in Q5.3
    let samples: Vec<Sample> = (0..6).map(|i| Dataset::Smnist.sample(i, Split::Test, 6)).collect();
    let mut core = Core::new(cfg.clone());
    core.load_weights(&weights).unwrap();
    core.registers = regs.clone();
    let base: Vec<Vec<u32>> = samples.iter().map(|s| core.run(s).counts).collect();
    core.registers.apply_program(&[(REG_VTH, raised_vth)]).unwrap();
    let raised: Vec<Vec<u32>> = samples.iter().map(|s| core.run(s).counts).collect();

    let engine =
        ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_lanes(2, 4)).unwrap();
    let mut server = SpikeServer::bind(engine, "127.0.0.1:0", ServerOptions::default()).unwrap();
    let addr = server.local_addr().to_string();

    // Three concurrent sessions; session 0 reprograms the core in-band
    // after its third sample. The shared engine serves everyone, so every
    // result is checked against the oracle its epoch tag selects.
    let verify = |epoch: u64, i: usize, counts: &[u32], who: &str| {
        let expect = if epoch == 0 { &base[i] } else { &raised[i] };
        assert_eq!(counts, expect.as_slice(), "{who}: sample {i} under epoch {epoch}");
    };
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..3usize)
            .map(|c| {
                let samples = &samples;
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut client = WireClient::connect(&addr).unwrap();
                    let (session, granted) = client.open_session(0).unwrap();
                    assert!(granted >= 6, "server default quota covers the test");
                    let reconfigures = c == 0;
                    for (i, s) in samples.iter().enumerate() {
                        client.submit(session, i as u64, s).unwrap();
                        if reconfigures && i == 2 {
                            let program = ReconfigProgram::new().write(REG_VTH, raised_vth);
                            client.reconfig(session, 77, &program).unwrap();
                        }
                    }
                    // Per-session replies preserve submission order, with
                    // the ack interleaved exactly where the reconfig was.
                    let mut acked_epoch = None;
                    for i in 0..samples.len() {
                        match client.recv().unwrap() {
                            Frame::Result { sample, epoch, counts, .. } => {
                                assert_eq!(sample, i as u64, "client {c}: results in order");
                                if reconfigures && i > 2 {
                                    assert!(
                                        epoch >= 1,
                                        "client {c}: in-band reconfig must precede sample {i}"
                                    );
                                }
                                verify(epoch, i, &counts, &format!("client {c}"));
                            }
                            other => panic!("client {c}: expected Result, got {other:?}"),
                        }
                        if reconfigures && i == 2 {
                            match client.recv().unwrap() {
                                Frame::ReconfigAck { request, epoch, .. } => {
                                    assert_eq!(request, 77);
                                    assert!(epoch >= 1);
                                    acked_epoch = Some(epoch);
                                }
                                other => panic!("client 0: expected ReconfigAck, got {other:?}"),
                            }
                        }
                    }
                    acked_epoch
                })
            })
            .collect();
        let acks: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(acks[0].is_some(), "the reconfiguring session got its ack");
    });

    let stats = server.stats();
    assert_eq!(stats.samples_served, 18, "3 sessions x 6 samples");
    assert_eq!(stats.reconfigs_applied, 1);
    assert_eq!(stats.protocol_errors, 0);
    server.shutdown();
}

#[test]
fn overload_is_a_typed_reject_not_a_stall() {
    // Quota of 2 in-flight; six long samples submitted back-to-back. The
    // first two are admitted, and while they run the rest should bounce
    // with Overloaded. Whether a given burst actually overlaps its own
    // service is a race the test must not bet on (a fast engine can drain
    // sample k before submit k+1 is even read), so the burst is repeated
    // under a bounded retry until a reject is observed — every burst
    // still checks the invariants that are *not* timing-dependent: at
    // least the quota's worth served, and exactly one reply per request.
    let server = spawn_server(1, 1, ServerOptions { max_inflight: 2, ..Default::default() });
    let addr = server.local_addr().to_string();
    let mut client = WireClient::connect(&addr).unwrap();
    let (session, granted) = client.open_session(2).unwrap();
    assert_eq!(granted, 2);
    let slow = Dataset::Smnist.sample(0, Split::Test, 400);
    let mut rejects_total = 0u32;
    for burst in 0..20u64 {
        for i in 0..6u64 {
            client.submit(session, burst * 10 + i, &slow).unwrap();
        }
        let (mut oks, mut rejects) = (0u32, 0u32);
        for _ in 0..6 {
            match client.recv().unwrap() {
                Frame::Result { .. } => oks += 1,
                Frame::Error { code: ErrorCode::Overloaded, .. } => rejects += 1,
                other => panic!("expected Result or Overloaded, got {other:?}"),
            }
        }
        assert!(oks >= 2, "burst {burst}: admitted samples are served (oks={oks})");
        assert_eq!(oks + rejects, 6, "burst {burst}: one reply per request");
        rejects_total += rejects;
        if rejects_total >= 1 {
            break;
        }
    }
    assert!(rejects_total >= 1, "no over-quota submit bounced across 20 six-deep bursts");
    // The reject is not sticky: quota freed, the session serves again.
    client.submit(session, 999, &slow).unwrap();
    assert!(matches!(client.recv().unwrap(), Frame::Result { sample: 999, .. }));
}

#[test]
fn bad_requests_get_typed_errors_and_leave_the_session_serving() {
    let server = spawn_server(1, 1, ServerOptions::default());
    let addr = server.local_addr().to_string();
    let mut client = WireClient::connect(&addr).unwrap();
    let (session, _) = client.open_session(0).unwrap();
    let good = Dataset::Smnist.sample(0, Split::Test, 6);

    // Unknown session id.
    client.submit(session + 999, 1, &good).unwrap();
    assert!(matches!(
        client.recv().unwrap(),
        Frame::Error { code: ErrorCode::BadSession, reference: 1, .. }
    ));

    // Sample geometry the engine cannot take (wrong input width).
    let narrow = Sample { spikes: vec![0; 12], t_steps: 3, inputs: 4, label: 0 };
    client.submit(session, 2, &narrow).unwrap();
    assert!(matches!(
        client.recv().unwrap(),
        Frame::Error { code: ErrorCode::BadSample, reference: 2, .. }
    ));

    // A program the control plane rejects (bad register address) burns
    // nothing and fails only this request.
    let bad_program = ReconfigProgram::new().write(99, 0);
    client.reconfig(session, 3, &bad_program).unwrap();
    assert!(matches!(
        client.recv().unwrap(),
        Frame::Error { code: ErrorCode::BadProgram, reference: 3, .. }
    ));

    // The session is untouched: a valid submit still serves at epoch 0.
    client.submit(session, 4, &good).unwrap();
    assert!(matches!(client.recv().unwrap(), Frame::Result { sample: 4, epoch: 0, .. }));

    let stats = server.stats();
    assert_eq!(stats.rejects_bad, 3);
    assert_eq!(stats.samples_served, 1);
}

#[test]
fn garbage_bytes_kill_only_the_offending_connection() {
    let server = spawn_server(1, 1, ServerOptions::default());
    let addr = server.local_addr().to_string();

    // A connection that speaks garbage gets a typed BadFrame error and a
    // close...
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    {
        use std::io::Write;
        // Length prefix 4, then an unknown frame type.
        raw.write_all(&[4, 0, 0, 0, 0xEE, 1, 2, 3]).unwrap();
        raw.flush().unwrap();
    }
    match wire::read_frame(&mut raw, DEFAULT_MAX_FRAME_LEN).unwrap() {
        Some(Frame::Error { code: ErrorCode::BadFrame, .. }) => {}
        other => panic!("expected BadFrame error, got {other:?}"),
    }
    assert!(
        wire::read_frame(&mut raw, DEFAULT_MAX_FRAME_LEN).unwrap().is_none(),
        "server closes the bad connection"
    );

    // ...while a well-behaved connection is unaffected.
    let mut client = WireClient::connect(&addr).unwrap();
    let (session, _) = client.open_session(0).unwrap();
    let good = Dataset::Smnist.sample(0, Split::Test, 6);
    client.submit(session, 0, &good).unwrap();
    assert!(matches!(client.recv().unwrap(), Frame::Result { .. }));
    wait_for_stats(&server, "the garbage frame to be counted", |s| s.protocol_errors == 1);
}

#[test]
fn stalled_connection_times_out_with_a_typed_error() {
    // Slow-loris defence: a client that completes the handshake and then
    // goes silent must be cut loose with a typed IdleTimeout error — it
    // may not pin a connection slot forever.
    let server = spawn_server(
        1,
        1,
        ServerOptions { idle_timeout: Duration::from_millis(300), ..Default::default() },
    );
    let addr = server.local_addr().to_string();
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    wire::write_frame(&mut raw, &Frame::Hello { version: wire::VERSION }).unwrap();
    match wire::read_frame(&mut raw, DEFAULT_MAX_FRAME_LEN).unwrap() {
        Some(Frame::HelloAck { .. }) => {}
        other => panic!("expected HelloAck, got {other:?}"),
    }
    // Say nothing more. The server announces the timeout, then closes.
    match wire::read_frame(&mut raw, DEFAULT_MAX_FRAME_LEN).unwrap() {
        Some(Frame::Error { code: ErrorCode::IdleTimeout, .. }) => {}
        other => panic!("expected IdleTimeout error, got {other:?}"),
    }
    assert!(
        wire::read_frame(&mut raw, DEFAULT_MAX_FRAME_LEN).unwrap().is_none(),
        "server closes the idle connection"
    );
    // The stall burned nothing shared: a live client on the same server
    // opens a session and serves normally.
    let mut client = WireClient::connect(&addr).unwrap();
    let (session, _) = client.open_session(0).unwrap();
    let good = Dataset::Smnist.sample(0, Split::Test, 6);
    client.submit(session, 0, &good).unwrap();
    assert!(matches!(client.recv().unwrap(), Frame::Result { .. }));
    let stats = wait_for_stats(&server, "the idle expiry to be counted", |s| s.idle_timeouts == 1);
    assert_eq!(stats.protocol_errors, 0, "an idle stall is not a protocol error");
}

#[test]
fn idle_expiry_is_retryable_on_a_fresh_connection() {
    // Companion to the slow-loris defence: when the server expires a
    // connection for idleness, a later submit on that handle must not be
    // a hard failure — the request is idempotent, so submit_with_retry
    // absorbs the typed IdleTimeout (or the already-closed socket behind
    // it) by redialing, opening a replacement session, and resubmitting.
    let server = spawn_server(
        1,
        1,
        ServerOptions { idle_timeout: Duration::from_millis(200), ..Default::default() },
    );
    let addr = server.local_addr().to_string();
    let (cfg, weights, regs) = fixture();
    let mut core = Core::new(cfg);
    core.load_weights(&weights).unwrap();
    core.registers = regs;
    let s0 = Dataset::Smnist.sample(0, Split::Test, 6);

    let mut client = WireClient::connect(&addr).unwrap();
    let (session, _) = client.open_session(0).unwrap();
    let first = client.submit_with_retry(session, 0, &s0, &RetryPolicy::default()).unwrap();
    assert_eq!(first.counts, core.run(&s0).counts);
    assert_eq!(first.reconnects, 0, "a live connection needs no redial");

    // Outlive the server's idle budget, then submit on the expired handle.
    std::thread::sleep(Duration::from_millis(600));
    let retried = client.submit_with_retry(session, 1, &s0, &RetryPolicy::default()).unwrap();
    assert_eq!(retried.counts, core.run(&s0).counts, "served on the fresh connection, bit-exact");
    assert!(retried.reconnects >= 1, "the expiry forced at least one redial: {retried:?}");
    let stats = wait_for_stats(&server, "the idle expiry to be counted", |s| s.idle_timeouts >= 1);
    assert_eq!(stats.protocol_errors, 0, "an idle expiry is not a protocol error");
}

#[test]
fn snapshot_restore_round_trips_over_the_wire() {
    let server = spawn_server(2, 4, ServerOptions::default());
    let addr = server.local_addr().to_string();
    let mut client = WireClient::connect(&addr).unwrap();
    let (session, _) = client.open_session(0).unwrap();
    let samples: Vec<Sample> =
        (0..4).map(|i| Dataset::Smnist.sample(i, Split::Test, 6)).collect();
    for (i, s) in samples.iter().enumerate() {
        client.submit(session, i as u64, s).unwrap();
        assert!(matches!(client.recv().unwrap(), Frame::Result { .. }));
    }

    // Snapshot over the wire: a versioned connectome image of the live
    // engine, taken at a quiesced sample-group boundary.
    let bytes = client.snapshot(session, 7).unwrap();
    let c = Connectome::decode(&bytes).expect("wire snapshot decodes");
    assert_eq!(c.cores, 2);
    assert_eq!((c.submitted, c.completed), (4, 4));

    // A corrupted image is a typed per-request reject, not a dead server.
    let mut bad = bytes.clone();
    let n = bad.len();
    bad[n - 3] ^= 0x40;
    assert!(client.restore(session, 8, bad).is_err(), "CRC flip must be rejected");

    // Restoring the intact image is blue/green migration: exactly one
    // config epoch, no stream drained, no rebuild.
    let epoch = client.restore(session, 9, bytes).unwrap();
    assert_eq!(epoch, 1);
    // The migrated weights/registers are the ones already live, so results
    // are unchanged — just tagged with the new epoch.
    let mut core = {
        let (cfg, weights, regs) = fixture();
        let mut core = Core::new(cfg);
        core.load_weights(&weights).unwrap();
        core.registers = regs;
        core
    };
    client.submit(session, 100, &samples[0]).unwrap();
    match client.recv().unwrap() {
        Frame::Result { sample: 100, epoch, counts, .. } => {
            assert_eq!(epoch, 1);
            assert_eq!(counts, core.run(&samples[0]).counts, "migration preserved weights");
        }
        other => panic!("expected Result, got {other:?}"),
    }
    let stats = server.stats();
    assert_eq!(stats.reconfigs_applied, 1, "restore = one applied program");
    assert_eq!(stats.samples_served, 5);
    assert_eq!(stats.rejects_bad, 1, "the corrupted image was the only reject");
}

#[test]
fn loadgen_verifies_bitexact_against_the_oracle() {
    // The full measurement path: open-loop load generator (unpaced, with
    // in-band reconfigs every 8 samples) against an in-process server,
    // verified result-by-result against the sequential core.
    let (cfg, weights, regs) = fixture();
    let opts = LoadgenOptions {
        sessions: 2,
        samples_per_session: 24,
        rate_hz: 0.0,
        burst_len: 1,
        reconfig_every: 8,
        dataset: Dataset::Smnist,
        t_steps: 6,
        pool: 8,
        max_inflight: 32,
        seed: 0xBEEF,
    };
    let mut core = Core::new(cfg.clone());
    core.load_weights(&weights).unwrap();
    core.registers = regs.clone();
    let oracle: Vec<Vec<u32>> = client::sample_pool(opts.dataset, opts.pool, opts.t_steps)
        .iter()
        .map(|s| core.run(s).counts)
        .collect();
    let engine =
        ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_lanes(2, 4)).unwrap();
    let mut server = SpikeServer::bind(engine, "127.0.0.1:0", ServerOptions::default()).unwrap();
    let report = client::run_loadgen(&server.local_addr().to_string(), &opts, Some(&oracle))
        .expect("loadgen run");
    server.shutdown();

    assert_eq!(report.submitted, 48);
    assert_eq!(report.results_ok, 48, "quota 32 > 24 in flight: nothing may bounce");
    assert_eq!(report.rejects, 0);
    assert_eq!(report.errors, 0);
    assert_eq!(report.reconfig_acks, 6, "2 sessions x (24 / 8) in-band reconfigs");
    assert_eq!(report.result_mismatches, 0, "network results bit-identical to Core::run");
    assert!(report.verified);
    assert!(report.p50_us > 0.0 && report.p99_us >= report.p50_us);
    assert!(report.samples_per_sec > 0.0);
}

#[test]
fn shard_loss_is_typed_on_the_wire_and_health_reports_recovery() {
    // The self-healing path end to end over TCP: a shard death surfaces
    // as exactly one typed ShardLost error frame (reference-preserving,
    // connection stays up), a retrying client absorbs the next one into a
    // served bit-exact result, and the HealthReq/Health probe reports the
    // recoveries with every shard back to Healthy.
    let (cfg, weights, regs) = fixture();
    let mut core = Core::new(cfg.clone());
    core.load_weights(&weights).unwrap();
    core.registers = regs.clone();
    let mut engine =
        ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
    // Admission 0 kills shard 0 under the first stream; admission 2 kills
    // both shards, so the stream is lost no matter where it was dispatched
    // (keeps the retry outcome deterministic).
    engine.install_chaos(ChaosSchedule::new(vec![
        ChaosEvent { at_sample: 0, shard: 0, kind: ChaosKind::StagePanic { stage: 0 } },
        ChaosEvent { at_sample: 2, shard: 0, kind: ChaosKind::StagePanic { stage: 1 } },
        ChaosEvent { at_sample: 2, shard: 1, kind: ChaosKind::ChannelDrop { stage: 0 } },
    ]));
    let mut server = SpikeServer::bind(engine, "127.0.0.1:0", ServerOptions::default()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = WireClient::connect(&addr).unwrap();

    // Pre-traffic probe: healthy, nothing recovered, one status byte per
    // shard — answered without a session.
    let h0 = client.health(1).unwrap();
    assert!(!h0.degraded, "fresh server is healthy: {h0:?}");
    assert_eq!((h0.recoveries, h0.quarantines), (0, 0));
    assert_eq!(h0.shards, vec![0, 0]);

    let (session, _) = client.open_session(0).unwrap();
    let s0 = Dataset::Smnist.sample(0, Split::Test, 6);

    // Bare submit: the loss is one typed, reference-preserving error.
    client.submit(session, 0, &s0).unwrap();
    match client.recv().unwrap() {
        Frame::Error { code: ErrorCode::ShardLost, reference: 0, .. } => {}
        other => panic!("expected a typed ShardLost, got {other:?}"),
    }
    // The session is not burned: the healed engine serves the next submit.
    let r1 = client.submit_with_retry(session, 1, &s0, &RetryPolicy::default()).unwrap();
    assert_eq!(r1.counts, core.run(&s0).counts, "post-recovery result bit-exact");

    // Retrying submit: attempt 1 is admission 2 (both shards die under
    // it), attempt 2 is served by the rebuilt engine.
    let r2 = client.submit_with_retry(session, 2, &s0, &RetryPolicy::default()).unwrap();
    assert_eq!(r2.attempts, 2, "one absorbed loss, then served");
    assert_eq!(r2.shard_losses, 1);
    assert_eq!(r2.counts, core.run(&s0).counts, "retried result bit-exact");

    let stats = wait_for_stats(&server, "the recoveries to be mirrored", |s| s.recoveries == 3);
    assert_eq!(stats.shard_losses, 2, "two streams were settled as ShardLost");
    assert_eq!(stats.quarantines, 3, "every death was quarantined");
    assert_eq!(server.recovery_latencies_ms().len(), 3);
    let h1 = client.health(2).unwrap();
    assert!(!h1.degraded, "supervisor re-admitted every shard: {h1:?}");
    assert_eq!((h1.recoveries, h1.quarantines), (3, 3));
    assert_eq!(h1.shards, vec![0, 0]);
    assert_eq!(
        (h1.scrubbed_blocks, h1.corrected, h1.detected),
        (0, 0, 0),
        "integrity is off on this engine; the wire mirror must say so"
    );
    server.shutdown();
}

#[test]
fn reject_rate_accounts_across_simultaneous_sessions() {
    // Telemetry accounting under admission pressure: three unpaced
    // sessions hammer a server whose per-session quota is 1, so most
    // over-quota submits bounce with Overloaded. The loadgen report folds
    // every session's outcomes into one Telemetry; its reject rate must
    // be exactly rejects / (results_ok + rejects), and the per-request
    // ledger must balance — every submit became exactly one Result or one
    // typed reject, across all sessions. Whether a *specific* submit
    // bounces is a race, so observing at least one reject runs under a
    // bounded retry; the accounting identities are asserted on every
    // attempt unconditionally.
    let server = spawn_server(1, 1, ServerOptions { max_inflight: 1, ..Default::default() });
    let addr = server.local_addr().to_string();
    let opts = LoadgenOptions {
        sessions: 3,
        samples_per_session: 12,
        rate_hz: 0.0,
        burst_len: 1,
        reconfig_every: 0,
        dataset: Dataset::Smnist,
        t_steps: 200,
        pool: 4,
        max_inflight: 32, // requested; the server grants its cap of 1
        seed: 0xAC1D,
    };
    let mut saw_reject = false;
    for attempt in 0..10 {
        let report = client::run_loadgen(&addr, &opts, None).expect("loadgen run");
        assert_eq!(report.submitted, 36, "attempt {attempt}: 3 sessions x 12 samples");
        assert_eq!(report.errors, 0, "attempt {attempt}: rejects are Overloaded, never errors");
        assert_eq!(
            report.results_ok + report.rejects,
            report.submitted,
            "attempt {attempt}: every submit resolved to exactly one Result or one reject"
        );
        let want_rate = report.rejects as f64 / (report.results_ok + report.rejects) as f64;
        assert!(
            (report.reject_rate - want_rate).abs() < 1e-9,
            "attempt {attempt}: reject_rate {} != rejects/(ok+rejects) {want_rate}",
            report.reject_rate
        );
        if report.rejects >= 1 {
            saw_reject = true;
            assert!(report.reject_rate > 0.0 && report.reject_rate <= 1.0);
            break;
        }
    }
    assert!(saw_reject, "quota-1 server never bounced an unpaced 12-deep session in 10 runs");
}
