//! Regression tests for the `repro bench-check` library
//! ([`quantisenc::util::benchcheck`]): a missing report file must be the
//! typed skip-with-warning (not an error), every recognized report kind
//! must validate on a well-formed synthetic body, each acceptance gate
//! must fail closed with the offending path and value in the message,
//! and the SIMD lane-step gate must be enforced for real vector kernels
//! while the scalar fallback keeps non-x86 hosts green.
//!
//! Gates are passed explicitly ([`Gates`] values, not `BENCH_GATE_*`
//! environment variables) so the suite stays deterministic under the
//! parallel test harness.

use quantisenc::util::benchcheck::{check_report, check_report_str, Gates, ReportStatus};

fn topology_report(ratio: f64) -> String {
    format!(
        r#"{{"bench":"bench_layer/topology",
            "ops_ratio_fc400_over_gaussian_r1_400":{ratio},
            "cases":[{{"name":"fc_400"}},{{"name":"gaussian_r1_400"}}]}}"#
    )
}

fn hotpath_report(layer_speedup: f64, kernel: &str, simd_speedup: f64) -> String {
    format!(
        r#"{{"bench":"hotpath",
            "layer_speedup_n400_2pct":{layer_speedup},
            "layer_cases":[{{"name":"gaussian_r1_400_firing_2pct"}}],
            "simd_kernel":"{kernel}",
            "simd_speedup_lane_step":{simd_speedup},
            "simd_cases":[{{"name":"one_to_one_400_firing_35pct",
                            "kernel":"{kernel}","speedup":{simd_speedup}}}],
            "engine":{{"sequential_samples_per_s":120.5,
                       "by_cores":[{{"cores":2,"samples_per_s":200.0}}]}}}}"#
    )
}

fn batched_report(speedup: f64, misses: f64) -> String {
    format!(
        r#"{{"bench":"batched",
            "speedup_lane64_over_lane1":{speedup},
            "matrix_pool_misses":{misses},
            "by_lane_width":[{{"lanes":1,"samples_per_s":50.0}},
                             {{"lanes":64,"samples_per_s":160.0}}]}}"#
    )
}

fn serving_slo_report(p99_us: f64, protocol_errors: f64, reject_rate: f64) -> String {
    format!(
        r#"{{"bench":"serving_slo",
            "results_ok":48,"samples_per_sec":310.0,
            "p50_us":800.0,"p99_us":{p99_us},
            "protocol_errors":{protocol_errors},
            "result_mismatches":0,
            "reject_rate":{reject_rate}}}"#
    )
}

fn chaos_report(mismatches: f64, recoveries: f64, all_healthy: f64, p99_ms: f64) -> String {
    format!(
        r#"{{"bench":"chaos",
            "samples":96,"results_ok":90,"retries":11,
            "shard_losses":6,"recoveries":{recoveries},"quarantines":{recoveries},
            "mismatches":{mismatches},"all_healthy":{all_healthy},
            "recovery_p50_ms":4.2,"recovery_p99_ms":{p99_ms}}}"#
    )
}

fn integrity_report(rate: f64, corrected: f64, mismatches: f64, overhead: f64) -> String {
    format!(
        r#"{{"bench":"integrity",
            "injected_flips":12,"detected":6,"corrected":{corrected},
            "detection_rate":{rate},"mismatches":{mismatches},
            "scrubbed_blocks":40000,"scrub_overhead":{overhead},
            "lane64_sps_off":900.0,"lane64_sps_correct":880.0}}"#
    )
}

fn kind_of(status: &ReportStatus) -> &str {
    match status {
        ReportStatus::Validated { kind, .. } => kind,
        ReportStatus::SkippedMissing { .. } => "skipped",
    }
}

#[test]
fn missing_report_is_a_typed_skip_not_an_error() {
    let path = std::env::temp_dir().join(format!("BENCH_nope_{}.json", std::process::id()));
    let path = path.to_str().unwrap();
    match check_report(path, &Gates::default()) {
        Ok(ReportStatus::SkippedMissing { path: p }) => assert_eq!(p, path),
        other => panic!("missing file must be SkippedMissing, got {other:?}"),
    }
}

#[test]
fn existing_report_files_validate_through_the_fs_path() {
    let path = std::env::temp_dir().join(format!("BENCH_ok_{}.json", std::process::id()));
    std::fs::write(&path, topology_report(9.4)).unwrap();
    let status = check_report(path.to_str().unwrap(), &Gates::default()).unwrap();
    assert_eq!(kind_of(&status), "bench_layer/topology");
    std::fs::remove_file(&path).ok();
}

#[test]
fn every_report_kind_validates_on_a_well_formed_body() {
    let gates = Gates::default();
    let bodies = [
        topology_report(9.4),
        hotpath_report(4.2, "avx2", 2.6),
        batched_report(3.1, 0.0),
        serving_slo_report(1500.0, 0.0, 0.125),
        chaos_report(0.0, 3.0, 1.0, 18.0),
        integrity_report(1.0, 6.0, 0.0, 0.03),
    ];
    let kinds =
        ["bench_layer/topology", "hotpath", "batched", "serving_slo", "chaos", "integrity"];
    for (body, want) in bodies.iter().zip(kinds) {
        match check_report_str("synthetic.json", body, &gates).unwrap() {
            ReportStatus::Validated { kind, summary } => {
                assert_eq!(kind, want);
                assert!(!summary.is_empty(), "{want}: empty summary");
            }
            other => panic!("{want}: expected Validated, got {other:?}"),
        }
    }
}

#[test]
fn simd_gate_is_enforced_for_vector_kernels() {
    let gates = Gates::default();
    for kernel in ["sse2", "avx2"] {
        let err = check_report_str("hp.json", &hotpath_report(4.2, kernel, 1.2), &gates)
            .expect_err("1.2x on a vector kernel must fail the 1.5x gate");
        let msg = format!("{err:#}");
        assert!(msg.contains("SIMD gate"), "message must name the gate: {msg}");
        assert!(msg.contains("hp.json"), "message must name the path: {msg}");
        assert!(msg.contains(kernel), "message must name the kernel: {msg}");
        assert!(
            check_report_str("hp.json", &hotpath_report(4.2, kernel, 1.5), &gates).is_ok(),
            "{kernel}: exactly 1.5x must pass the inclusive gate"
        );
    }
}

#[test]
fn scalar_fallback_keeps_the_simd_gate_green() {
    // On hosts where `LaneKernel::auto` resolves to the scalar fallback
    // the twins run the same kernel: a ~1.0x ratio must validate without
    // any BENCH_GATE override, but a non-positive ratio is still nonsense.
    let gates = Gates::default();
    let ok = check_report_str("hp.json", &hotpath_report(4.2, "scalar", 0.97), &gates);
    assert!(ok.is_ok(), "scalar fallback below 1.5x must pass: {ok:?}");
    assert!(check_report_str("hp.json", &hotpath_report(4.2, "scalar", 0.0), &gates).is_err());
}

#[test]
fn explicit_gates_relax_thresholds_like_the_env_overrides() {
    let relaxed = Gates { min_simd_speedup: 1.1, min_batch_speedup: 1.2, ..Gates::default() };
    assert!(check_report_str("hp.json", &hotpath_report(4.2, "avx2", 1.2), &relaxed).is_ok());
    assert!(check_report_str("b.json", &batched_report(1.3, 0.0), &relaxed).is_ok());
    let strict = Gates { min_speedup: 5.0, ..Gates::default() };
    assert!(check_report_str("hp.json", &hotpath_report(4.2, "avx2", 2.6), &strict).is_err());
}

#[test]
fn gate_failures_name_the_path_and_the_value() {
    let gates = Gates::default();
    let err = check_report_str("BENCH_t.json", &topology_report(3.9), &gates).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("BENCH_t.json") && msg.contains("3.9"), "{msg}");

    let err = check_report_str("BENCH_b.json", &batched_report(1.4, 0.0), &gates).unwrap_err();
    assert!(format!("{err:#}").contains("1.40x"), "{err:#}");
    let err = check_report_str("BENCH_b.json", &batched_report(3.0, 2.0), &gates).unwrap_err();
    assert!(format!("{err:#}").contains("pool"), "{err:#}");

    let err =
        check_report_str("BENCH_s.json", &serving_slo_report(9e9, 0.0, 0.0), &gates).unwrap_err();
    assert!(format!("{err:#}").contains("p99"), "{err:#}");
    let err =
        check_report_str("BENCH_s.json", &serving_slo_report(1e3, 2.0, 0.0), &gates).unwrap_err();
    assert!(format!("{err:#}").contains("protocol errors"), "{err:#}");
    let err =
        check_report_str("BENCH_s.json", &serving_slo_report(1e3, 0.0, 1.5), &gates).unwrap_err();
    assert!(format!("{err:#}").contains("reject_rate"), "{err:#}");
}

#[test]
fn chaos_gates_fail_closed_on_each_axis() {
    let gates = Gates::default();
    // One surviving result diverging from the oracle is a hard failure.
    let err = check_report_str("BENCH_c.json", &chaos_report(1.0, 3.0, 1.0, 18.0), &gates)
        .expect_err("oracle mismatch must fail the chaos gate");
    assert!(format!("{err:#}").contains("diverged"), "{err:#}");
    // A soak that never exercised a recovery proves nothing.
    let err = check_report_str("BENCH_c.json", &chaos_report(0.0, 0.0, 1.0, 18.0), &gates)
        .expect_err("zero recoveries must fail the chaos gate");
    assert!(format!("{err:#}").contains("recovery"), "{err:#}");
    // Ending with a quarantined shard means self-healing did not complete.
    let err = check_report_str("BENCH_c.json", &chaos_report(0.0, 3.0, 0.0, 18.0), &gates)
        .expect_err("unhealthy final state must fail the chaos gate");
    assert!(format!("{err:#}").contains("healthy"), "{err:#}");
    // Recovery latency is wall-clock gated, with the env-style override.
    let err = check_report_str("BENCH_c.json", &chaos_report(0.0, 3.0, 1.0, 9e6), &gates)
        .expect_err("9000s recovery p99 must fail the default 5s gate");
    assert!(format!("{err:#}").contains("recovery p99"), "{err:#}");
    let relaxed = Gates { max_recovery_ms: 1e7, ..Gates::default() };
    assert!(check_report_str("BENCH_c.json", &chaos_report(0.0, 3.0, 1.0, 9e6), &relaxed).is_ok());
}

#[test]
fn integrity_gates_fail_closed_on_each_axis() {
    let gates = Gates::default();
    // Any injected flip slipping past the scrubber is a hard failure.
    let err = check_report_str("BENCH_i.json", &integrity_report(0.9, 6.0, 0.0, 0.03), &gates)
        .expect_err("detection rate below 1.0 must fail the integrity gate");
    assert!(format!("{err:#}").contains("detection rate"), "{err:#}");
    // A soak that never exercised an in-place correction proves nothing
    // about the SECDED repair path.
    let err = check_report_str("BENCH_i.json", &integrity_report(1.0, 0.0, 0.0, 0.03), &gates)
        .expect_err("zero corrections must fail the integrity gate");
    assert!(format!("{err:#}").contains("correction"), "{err:#}");
    // Survivors must stay bit-exact.
    let err = check_report_str("BENCH_i.json", &integrity_report(1.0, 6.0, 2.0, 0.03), &gates)
        .expect_err("oracle mismatch must fail the integrity gate");
    assert!(format!("{err:#}").contains("diverged"), "{err:#}");
    // Scrub overhead is wall-clock gated, with the env-style override.
    let err = check_report_str("BENCH_i.json", &integrity_report(1.0, 6.0, 0.0, 0.35), &gates)
        .expect_err("35% overhead must fail the default 10% gate");
    assert!(format!("{err:#}").contains("scrub overhead"), "{err:#}");
    let relaxed = Gates { max_scrub_overhead: 0.5, ..Gates::default() };
    assert!(
        check_report_str("BENCH_i.json", &integrity_report(1.0, 6.0, 0.0, 0.35), &relaxed).is_ok()
    );
}

#[test]
fn malformed_unknown_and_incomplete_reports_are_errors() {
    let gates = Gates::default();
    assert!(check_report_str("x.json", "{not json", &gates).is_err());
    assert!(check_report_str("x.json", r#"{"bench":"mystery"}"#, &gates).is_err());
    assert!(check_report_str("x.json", r#"{"layer_speedup_n400_2pct":4.0}"#, &gates).is_err());
    // A hotpath report predating the SIMD section must fail loudly rather
    // than silently passing without the gate.
    let legacy = r#"{"bench":"hotpath","layer_speedup_n400_2pct":4.2,
        "layer_cases":[{"name":"x"}],
        "engine":{"sequential_samples_per_s":1.0,"by_cores":[{"samples_per_s":1.0}]}}"#;
    let err = check_report_str("legacy.json", legacy, &gates).unwrap_err();
    assert!(format!("{err:#}").contains("simd_kernel"), "{err:#}");
}
