//! Golden-vector parity: the runtime substrates must match the recorded
//! golden vectors bit-for-bit on fixed-point ops, multi-step LIF traces
//! (all four reset modes), and dataset generation. The vectors are
//! regenerated natively by `quantisenc::golden` (no Python step), so these
//! tests pin the on-disk contract a deployed store must satisfy — any
//! semantic drift between the generator and the simulator trips them.

use quantisenc::config::registers::RegisterFile;
use quantisenc::config::{LayerConfig, MemKind, Topology};
use quantisenc::datasets::{Dataset, Split};
use quantisenc::fixed::QSpec;
use quantisenc::hdl::Layer;
use quantisenc::runtime::artifacts::Manifest;
use quantisenc::util::json::Json;

fn manifest() -> Manifest {
    let dir = quantisenc::golden::ensure_artifacts().expect("native artifact bootstrap");
    Manifest::load(&dir).expect("load generated manifest")
}

#[test]
fn fixedpoint_ops_match_python() {
    let g = manifest().golden("golden_fixedpoint.json").unwrap();
    let cases = g.get("cases").unwrap().as_arr().unwrap();
    assert_eq!(cases.len(), 256);
    for c in cases {
        let qs = QSpec::parse(c.get("q").unwrap().as_str().unwrap()).unwrap();
        let a = c.get("a").unwrap().as_i64().unwrap() as i32;
        let b = c.get("b").unwrap().as_i64().unwrap() as i32;
        assert_eq!(qs.add(a, b) as i64, c.get("add").unwrap().as_i64().unwrap(), "{qs} add {a} {b}");
        assert_eq!(qs.sub(a, b) as i64, c.get("sub").unwrap().as_i64().unwrap(), "{qs} sub {a} {b}");
        assert_eq!(qs.mul(a, b) as i64, c.get("mul").unwrap().as_i64().unwrap(), "{qs} mul {a} {b}");
    }
}

fn check_lif_golden(file: &str) {
    let g = manifest().golden(file).unwrap();
    let qs = QSpec::parse(g.get("q").unwrap().as_str().unwrap()).unwrap();
    let m = g.get("m").unwrap().as_i64().unwrap() as usize;
    let n = g.get("n").unwrap().as_i64().unwrap() as usize;
    let weights: Vec<i32> = g
        .get("weights")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .flat_map(|row| row.i32_vec().unwrap())
        .collect();
    let spikes_in: Vec<Vec<i32>> = g
        .get("spikes_in")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.i32_vec().unwrap())
        .collect();

    for (mode, trace) in g.get("traces").unwrap().as_obj().unwrap() {
        let regs_v = trace.get("regs").unwrap().i32_vec().unwrap();
        let mut regs = RegisterFile::new(qs);
        for (addr, &v) in regs_v.iter().enumerate() {
            regs.write(addr, v).unwrap();
        }
        let cfg = LayerConfig { fan_in: m, neurons: n, topology: Topology::AllToAll };
        let mut layer = Layer::new(&cfg, qs, MemKind::Bram);
        layer.memory_mut().load_dense(&weights).unwrap();

        let exp_spk = trace.get("spikes_out").unwrap().as_arr().unwrap();
        let exp_vm = trace.get("vmem").unwrap().as_arr().unwrap();
        let mut out = Vec::new();
        for (t, spk_row) in spikes_in.iter().enumerate() {
            let row_u8: Vec<u8> = spk_row.iter().map(|&x| x as u8).collect();
            layer.step_regs(&row_u8, &mut out, &regs);
            let got_spk: Vec<i32> = out.iter().map(|&s| s as i32).collect();
            assert_eq!(got_spk, exp_spk[t].i32_vec().unwrap(), "{file} mode {mode} t={t} spikes");
            assert_eq!(layer.vmem_slice(), exp_vm[t].i32_vec().unwrap(), "{file} mode {mode} t={t} vmem");
        }
    }
}

#[test]
fn lif_trace_q53_matches_python_all_reset_modes() {
    check_lif_golden("golden_lif_q53.json");
}

#[test]
fn lif_trace_q97_matches_python_all_reset_modes() {
    check_lif_golden("golden_lif_q97.json");
}

#[test]
fn dataset_generators_match_python() {
    let g = manifest().golden("golden_datasets.json").unwrap();
    for ds in Dataset::all() {
        let entry = g.get(ds.label()).unwrap();
        let t = entry.get("t").unwrap().as_i64().unwrap() as usize;
        let sample = ds.sample(0, Split::Test, t);
        assert_eq!(
            sample.label as i64,
            entry.get("label").unwrap().as_i64().unwrap(),
            "{} label",
            ds.label()
        );
        let exp_rows: Vec<i64> = entry
            .get("spike_rows")
            .unwrap()
            .num_vec()
            .unwrap()
            .into_iter()
            .map(|x| x as i64)
            .collect();
        let got_rows: Vec<i64> = sample.row_counts().iter().map(|&x| x as i64).collect();
        // smnist is transcendental-free and must be exact; dvs/shd involve
        // exp/cos whose final-ulp may differ between numpy and Rust libm.
        if ds == Dataset::Smnist {
            assert_eq!(got_rows, exp_rows, "smnist rows must be bit-exact");
            let exp_first: Vec<i64> = entry
                .get("first_row_indices")
                .unwrap()
                .num_vec()
                .unwrap()
                .into_iter()
                .map(|x| x as i64)
                .collect();
            let got_first: Vec<i64> = (0..sample.inputs)
                .filter(|&i| sample.spike(0, i) == 1)
                .map(|i| i as i64)
                .collect();
            assert_eq!(got_first, exp_first);
        } else {
            let exp_nnz = entry.get("nnz").unwrap().as_i64().unwrap();
            let got_nnz = sample.nnz() as i64;
            let diff = (exp_nnz - got_nnz).abs() as f64;
            assert!(
                diff <= (exp_nnz as f64 * 0.001).max(1.0),
                "{}: nnz {got_nnz} vs python {exp_nnz}",
                ds.label()
            );
        }
    }
}

#[test]
fn golden_files_are_wellformed_json() {
    let m = manifest();
    for f in [
        "golden_fixedpoint.json",
        "golden_lif_q53.json",
        "golden_lif_q97.json",
        "golden_datasets.json",
        "manifest.json",
    ] {
        let j = m.golden(f).unwrap();
        assert!(matches!(j, Json::Obj(_)), "{f} not an object");
    }
}
