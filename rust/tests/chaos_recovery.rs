//! The PR-9 self-healing acceptance gate, as an integration test: a
//! multi-round chaos differential against the sequential [`Core`] oracle.
//!
//! An explicit [`ChaosSchedule`] injects four shard-killing faults (stage
//! panics and channel drops, covering both shards of a two-core engine)
//! under live traffic spread over four `run_batch_outcomes` rounds. The
//! contract checked after every round, at lane widths 1 and 64:
//!
//! - every non-failed stream is **bit-identical** to the oracle
//!   (prediction, counts, spike totals, epoch);
//! - every failed stream surfaces **exactly one** typed
//!   [`ServingError::ShardLost`] with `resumable: true` and a valid shard
//!   index — never a panic, never a hang, never a poisoned engine;
//! - the engine ends the round with every shard [`ShardHealth::Healthy`]
//!   (the supervisor quarantined, rebuilt from the connectome checkpoint,
//!   and re-admitted the dead shard before returning);
//! - resubmitting the lost streams afterwards succeeds bit-exactly — the
//!   `resumable` flag means what it says.

use quantisenc::config::registers::RegisterFile;
use quantisenc::config::ModelConfig;
use quantisenc::coordinator::serving::chaos::{ChaosEvent, ChaosKind, ChaosSchedule};
use quantisenc::coordinator::serving::{ServingEngine, ServingError, ServingOptions, ShardHealth};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::datasets::Sample;
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::Core;

const ROUND: usize = 12;
const ROUNDS: usize = 4;

fn fixture() -> (ModelConfig, Vec<Vec<i32>>, RegisterFile, Vec<Sample>) {
    let cfg = ModelConfig::parse_arch("24x16x10", Q5_3).unwrap();
    let mut rng = XorShift64Star::new(0x9A7E);
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(15) as i32 - 7).collect())
        .collect();
    let regs = RegisterFile::new(cfg.qspec);
    let t_steps = 6;
    let samples: Vec<Sample> = (0..(ROUND * ROUNDS) as u64)
        .map(|i| {
            let mut srng = XorShift64Star::new(0xBEEF ^ i);
            Sample {
                spikes: (0..t_steps * cfg.inputs()).map(|_| (srng.uniform() < 0.3) as u8).collect(),
                t_steps,
                inputs: cfg.inputs(),
                label: (i % 10) as usize,
            }
        })
        .collect();
    (cfg, weights, regs, samples)
}

/// One death per round, alternating shards: the surviving shard serves
/// throughout (graceful degradation), and both shards get killed — and
/// rebuilt — twice, by both fault kinds.
fn schedule() -> ChaosSchedule {
    ChaosSchedule::new(vec![
        ChaosEvent { at_sample: 3, shard: 0, kind: ChaosKind::StagePanic { stage: 1 } },
        ChaosEvent { at_sample: 16, shard: 1, kind: ChaosKind::ChannelDrop { stage: 0 } },
        ChaosEvent { at_sample: 27, shard: 0, kind: ChaosKind::ChannelDrop { stage: 1 } },
        ChaosEvent { at_sample: 40, shard: 1, kind: ChaosKind::StagePanic { stage: 0 } },
    ])
}

fn run_gate(lane_width: usize) {
    let (cfg, weights, regs, samples) = fixture();
    let mut core = Core::new(cfg.clone());
    core.load_weights(&weights).unwrap();
    core.registers = regs.clone();

    let mut engine = ServingEngine::new(
        &cfg,
        &weights,
        &regs,
        ServingOptions::with_lanes(2, lane_width).checkpoints_every(8),
    )
    .unwrap();
    engine.install_chaos(schedule());

    let mut lost: Vec<usize> = Vec::new();
    for round in 0..ROUNDS {
        let window = &samples[round * ROUND..(round + 1) * ROUND];
        let outcomes = engine.run_batch_outcomes(window).unwrap();
        assert_eq!(outcomes.len(), ROUND, "round {round}: one settlement per stream");
        for (j, outcome) in outcomes.iter().enumerate() {
            let idx = round * ROUND + j;
            match outcome {
                Ok(r) => {
                    let o = core.run(&samples[idx]);
                    assert_eq!(r.prediction, o.prediction, "round {round} stream {j} prediction");
                    assert_eq!(r.counts, o.counts, "round {round} stream {j} counts");
                    assert_eq!(r.epoch, 0, "no reconfig was issued");
                }
                Err(ServingError::ShardLost { shard, resumable }) => {
                    assert!(*shard < 2, "round {round} stream {j}: shard index out of range");
                    assert!(*resumable, "pure inference submits are always resumable");
                    lost.push(idx);
                }
                Err(other) => {
                    panic!("round {round} stream {j}: expected ShardLost, got {other:?}")
                }
            }
        }
        assert!(
            engine.shard_health().iter().all(|h| *h == ShardHealth::Healthy),
            "round {round}: supervisor must re-admit every shard before returning \
             (got {:?})",
            engine.shard_health()
        );
    }

    // Four deaths were injected; each one was quarantined and recovered
    // (a recovery is counted even when the dead shard held no streams,
    // which can happen at lane width 64 where a whole round is one lane
    // group on one shard).
    assert!(engine.recoveries() >= 3, "expected >=3 recoveries, got {}", engine.recoveries());
    assert_eq!(engine.recoveries(), engine.quarantines(), "every quarantine must recover");
    assert!(!engine.recovery_latencies_ms().is_empty());
    if lane_width == 1 {
        assert!(
            lost.len() >= 3,
            "round-robin dispatch puts streams behind every fault; got {} losses",
            lost.len()
        );
    }

    // The resumable contract, end to end: resubmitting exactly the lost
    // streams on the healed engine yields bit-exact results.
    let resubmit: Vec<Sample> = lost.iter().map(|&i| samples[i].clone()).collect();
    if !resubmit.is_empty() {
        let results = engine.run_batch(&resubmit).unwrap();
        for (r, &i) in results.iter().zip(&lost) {
            let o = core.run(&samples[i]);
            assert_eq!(r.prediction, o.prediction, "resubmitted stream {i} prediction");
            assert_eq!(r.counts, o.counts, "resubmitted stream {i} counts");
        }
    }
    assert!(engine.shard_health().iter().all(|h| *h == ShardHealth::Healthy));
}

#[test]
fn chaos_differential_gate_lane_width_1() {
    run_gate(1);
}

#[test]
fn chaos_differential_gate_lane_width_64() {
    run_gate(64);
}
