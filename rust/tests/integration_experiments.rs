//! Integration: every paper table/figure generator runs against the real
//! artifacts and reproduces the paper's qualitative shape (who wins, which
//! way the trend points). The artifact store is bootstrapped natively on
//! first use — no Python step required.

use quantisenc::experiments;
use quantisenc::runtime::artifacts::Manifest;

fn manifest() -> Manifest {
    let dir = quantisenc::golden::ensure_artifacts().expect("native artifact bootstrap");
    Manifest::load(&dir).expect("load generated manifest")
}

#[test]
fn every_experiment_generates() {
    let m = manifest();
    for (kind, id) in experiments::ALL {
        let r = match *kind {
            "table" => experiments::run_table(id, Some(&m)),
            _ => experiments::run_figure(id, Some(&m)),
        };
        let tables = r.unwrap_or_else(|e| panic!("{kind} {id} failed: {e:#}"));
        assert!(!tables.is_empty(), "{kind} {id} produced no tables");
        for t in &tables {
            assert!(!t.rows.is_empty(), "{kind} {id}: empty table {}", t.title);
            // Render both ways without panicking.
            let _ = t.to_string();
            let _ = t.to_markdown();
        }
    }
}

#[test]
fn table8_quantization_ladder_trend() {
    let m = manifest();
    let t = experiments::accuracy::table8(&m).unwrap();
    let row = &t.rows[0];
    let parse = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
    let (q97, q53, q31) = (parse(&row[2]), parse(&row[3]), parse(&row[4]));
    assert!(q97 > 90.0, "Q9.7 should be near software: {q97}");
    assert!(q53 > 85.0, "Q5.3 should stay high: {q53}");
    assert!(q31 < q53, "4-bit must degrade: {q31} vs {q53}");
    assert!(q31 > 15.0, "Q3.1 should beat chance after QAT: {q31}");
}

#[test]
fn fig12_rmse_grows_as_precision_shrinks() {
    let m = manifest();
    let t = experiments::accuracy::fig12(&m).unwrap();
    let rmse: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
    // rows are Q9.7, Q5.3, Q3.1
    assert!(rmse[0] < rmse[1], "RMSE(Q9.7) < RMSE(Q5.3): {rmse:?}");
    assert!(rmse[1] < rmse[2], "RMSE(Q5.3) < RMSE(Q3.1): {rmse:?}");
}

#[test]
fn fig10_prediction_is_correct_digit() {
    let m = manifest();
    let tables = experiments::accuracy::fig10_11(&m).unwrap();
    let note = tables[1].notes.join(" ");
    assert!(note.contains("predicted 8"), "digit-8 example should classify as 8: {note}");
}

#[test]
fn table10_dynamic_trends() {
    let m = manifest();
    let t = experiments::dynamic_cfg::table10(&m).unwrap();
    let spikes: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
    let power: Vec<f64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
    // R/C rows 0..4: spikes monotonically non-increasing as R falls.
    assert!(spikes[0] >= spikes[1] && spikes[1] >= spikes[2] && spikes[2] >= spikes[3], "{spikes:?}");
    assert_eq!(spikes[3], 0.0, "R=10MΩ must be silent");
    // Reset rows 4..7: default spikes most and burns most power.
    assert!(spikes[4] > spikes[5] && spikes[5] >= spikes[6], "{spikes:?}");
    assert!(power[4] > power[5], "{power:?}");
    // Refractory rows 7..9: refractory 5 trims spikes vs 0.
    assert!(spikes[8] < spikes[7], "{spikes:?}");
}

#[test]
fn table11_smnist_is_smallest_and_most_efficient() {
    let m = manifest();
    let t = experiments::datasets_exp::table11(&m).unwrap();
    let lut = |i: usize| t.rows[i][2].trim_end_matches('%').parse::<f64>().unwrap();
    let ppw = |i: usize| t.rows[i][7].parse::<f64>().unwrap();
    assert!(lut(0) < lut(1) && lut(0) < lut(2), "smnist smallest");
    assert!(ppw(0) > ppw(1) && ppw(0) > ppw(2), "smnist most GOPS/W");
    // accuracy column sane
    for i in 0..3 {
        let acc: f64 = t.rows[i][5].trim_end_matches('%').parse().unwrap();
        assert!(acc > 50.0, "row {i} accuracy {acc}");
    }
}

#[test]
fn table6_utilisation_tracks_paper_within_10pct() {
    let m = manifest();
    let t = experiments::resources_exp::table6(&m).unwrap();
    for row in &t.rows {
        let ours: f64 = row[4].trim_end_matches('%').parse().unwrap();
        let paper: f64 = row[5].trim_end_matches('%').parse().unwrap();
        let err = (ours - paper).abs() / paper;
        assert!(err < 0.10, "LUT% {ours} vs paper {paper} in {row:?}");
    }
}
