//! Shared fixtures for the integration suites: the fixed-point saturation
//! **corner corpus** — explicit neuron-state and register vectors at the
//! edges of each shipped QSpec — seeded by the satellite property tests in
//! `property_invariants.rs` and reused verbatim by the SIMD differential
//! suite in `simd_parity.rs`, so every boundary the scalar oracle is
//! checked against is also re-proved under the vector masks.
//!
//! Compiled once per including test crate via `mod common;`; suites that
//! use only part of the API keep the rest without dead-code noise.
#![allow(dead_code)]

use quantisenc::config::registers::{RegisterFile, ResetMode};
use quantisenc::fixed::QSpec;
use quantisenc::hdl::neuron::RegSnapshot;

/// One saturation-boundary neuron state: architectural registers plus the
/// accumulated activation fed into the step.
#[derive(Debug, Clone, Copy)]
pub struct CornerState {
    pub name: &'static str,
    pub vmem: i32,
    pub refcnt: i32,
    pub act: i32,
}

/// Explicit neuron-state corner vectors for `qs`: vmem pinned at the raw
/// representable extremes (±(2^(n+q-1) − 1) and one ulp inside), at the
/// ±1.0 fixed-point units where in range, at zero rest, and under active
/// refractory counts — each crossed with activations at 0 and both raw
/// extremes so the wrapping multiply/add in VmemDyn is exercised exactly
/// where it overflows the W-bit window.
pub fn corner_states(qs: QSpec) -> Vec<CornerState> {
    let hi = qs.max_raw();
    let lo = qs.min_raw();
    let one = (1i64 << qs.q()) as i32; // +1.0, in range whenever n >= 2
    let mut cases = vec![
        CornerState { name: "rest", vmem: 0, refcnt: 0, act: 0 },
        CornerState { name: "vmem=+max", vmem: hi, refcnt: 0, act: 0 },
        CornerState { name: "vmem=+max-ulp", vmem: hi - 1, refcnt: 0, act: 0 },
        CornerState { name: "vmem=-max", vmem: lo, refcnt: 0, act: 0 },
        CornerState { name: "vmem=-max+ulp", vmem: lo + 1, refcnt: 0, act: 0 },
        CornerState { name: "vmem=+max act=+max", vmem: hi, refcnt: 0, act: hi },
        CornerState { name: "vmem=+max act=-max", vmem: hi, refcnt: 0, act: lo },
        CornerState { name: "vmem=-max act=-max", vmem: lo, refcnt: 0, act: lo },
        CornerState { name: "vmem=-max act=+max", vmem: lo, refcnt: 0, act: hi },
        CornerState { name: "refractory hold at +max", vmem: hi, refcnt: 1, act: hi },
        CornerState { name: "refractory hold at -max", vmem: lo, refcnt: 2, act: hi },
        CornerState { name: "deep refractory count", vmem: hi - 1, refcnt: 250, act: lo },
    ];
    if hi >= one {
        cases.push(CornerState { name: "vmem=+1.0", vmem: one, refcnt: 0, act: 0 });
        cases.push(CornerState { name: "vmem=-1.0", vmem: -one, refcnt: 0, act: hi });
    }
    cases
}

/// Register corner configurations for `qs`, each tagged for assertion
/// messages: the default file under every reset mode, thresholds pinned at
/// both raw extremes (a comparator corner: `vth = min_raw` makes *every*
/// update spike, `vth = max_raw` almost none), zero decay (the exact-hold
/// configuration behind the quiescence fast path), and refractory periods
/// long enough to roll a lane through arm → hold → release inside one
/// sweep. All values are in the QSpec's W-bit range by construction, the
/// same contract [`RegisterFile`] enforces on writes.
pub fn corner_reg_sets(qs: QSpec) -> Vec<(String, RegSnapshot)> {
    let base = RegSnapshot::from(&RegisterFile::new(qs));
    let hi = qs.max_raw();
    let lo = qs.min_raw();
    let mut sets = Vec::new();
    for mode in ResetMode::all() {
        let m = RegSnapshot { mode, ..base };
        sets.push((format!("{qs} {mode:?} default"), m));
        sets.push((format!("{qs} {mode:?} vth=+max"), RegSnapshot { vth: hi, ..m }));
        sets.push((format!("{qs} {mode:?} vth=-max"), RegSnapshot { vth: lo, ..m }));
        sets.push((
            format!("{qs} {mode:?} zero-decay"),
            RegSnapshot { decay: 0, vth: hi, refractory: 1, ..m },
        ));
        sets.push((
            format!("{qs} {mode:?} refractory-wrap"),
            RegSnapshot { refractory: 3, vth: 1.max(hi >> 2), vreset: lo / 2, ..m },
        ));
        sets.push((
            format!("{qs} {mode:?} max-drive"),
            RegSnapshot { decay: hi, growth: hi, vth: hi, vreset: lo, refractory: 2, ..m },
        ));
        sets.push((
            format!("{qs} {mode:?} negative-growth"),
            RegSnapshot { growth: lo, vth: lo / 2, ..m },
        ));
    }
    sets
}
