//! Failure injection: the system must reject malformed artifacts, bus
//! transactions, and event streams with actionable errors — never panic,
//! never partially apply.

use std::path::PathBuf;

use quantisenc::config::{MemKind, ModelConfig, Topology};
use quantisenc::coordinator::interface::Device;
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::aer::{decode, AerEvent};
use quantisenc::hdl::memory::MemError;
use quantisenc::hdl::SynapticMemory;
use quantisenc::runtime::artifacts::{load_weight_file, Manifest};

fn scratch_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("q_fail_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_actionable() {
    let err = Manifest::load(&scratch_dir("none")).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "error must tell the user the fix: {msg}");
}

#[test]
fn corrupt_manifest_json_rejected() {
    let dir = scratch_dir("badjson");
    std::fs::write(dir.join("manifest.json"), "{ not json !").unwrap();
    assert!(Manifest::load(&dir).is_err());
}

#[test]
fn manifest_missing_keys_rejected() {
    let dir = scratch_dir("nokeys");
    std::fs::write(dir.join("manifest.json"), r#"{"models": {"smnist": {}}}"#).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let err = m.model("smnist", "Q5.3").unwrap_err();
    assert!(format!("{err:#}").contains("missing json key"));
    assert!(m.model("nonexistent", "Q5.3").is_err());
}

#[test]
fn truncated_weight_file_rejected() {
    let dir = scratch_dir("shortw");
    let path = dir.join("w.bin");
    std::fs::write(&path, [0u8; 10]).unwrap(); // not a multiple of the shape
    let err = load_weight_file(&path, &[(2, 2)]).unwrap_err();
    assert!(format!("{err:#}").contains("expected 16"));
}

#[test]
fn device_rejects_out_of_range_bus_traffic() {
    let cfg = ModelConfig::parse_arch("4x3x2", Q5_3).unwrap();
    let mut d = Device::new(cfg);
    // weight address out of range / value overflow / pruned α (via one-to-one)
    assert!(d.write_weight(0, 99, 0, 1).is_err());
    assert!(d.write_weight(0, 0, 0, 100_000).is_err());
    assert!(d.write_weight(9, 0, 0, 1).is_err()); // bad layer address must error, not panic
    // register: bad address, bad reset encoding, negative refractory
    assert!(d.write_register(77, 0).is_err());
    assert!(d.write_register(4, 17).is_err());
    assert!(d.write_register(5, -3).is_err());
}

#[test]
fn malformed_aer_streams_rejected() {
    // Out-of-range address, out-of-range timestamp, unordered stream.
    assert!(decode(&[AerEvent { t: 0, addr: 10 }], 2, 4).is_err());
    assert!(decode(&[AerEvent { t: 9, addr: 0 }], 2, 4).is_err());
    assert!(decode(
        &[AerEvent { t: 1, addr: 2 }, AerEvent { t: 1, addr: 1 }],
        2,
        4
    )
    .is_err());
}

#[test]
fn weight_file_with_out_of_range_values_rejected_by_core() {
    let cfg = ModelConfig::parse_arch("2x2", Q5_3).unwrap();
    let mut core = quantisenc::hdl::Core::new(cfg);
    // 999 does not fit Q5.3's 8-bit word.
    let err = core.load_weights(&[vec![0, 0, 0, 999]]).unwrap_err();
    assert!(format!("{err:#}").contains("does not fit"));
    // arity mismatch
    assert!(core.load_weights(&[]).is_err());
}

#[test]
fn sparse_store_rejects_out_of_band_addresses() {
    // Gaussian radius-1 8x8: only |i - j| <= 1 has physical storage.
    let mut g = SynapticMemory::new(8, 8, Topology::Gaussian { radius: 1 }, Q5_3, MemKind::Bram);
    for (pre, post) in [(0usize, 5usize), (0, 2), (7, 0), (3, 6), (5, 3)] {
        let err = g.write(pre, post, 1).unwrap_err();
        assert_eq!(
            err,
            MemError::Pruned { pre, post, topo: "gaussian:1".into() },
            "({pre},{post}) must be outside the band"
        );
    }
    // The same addresses read as hardwired zero, never as an error.
    assert_eq!(g.read(0, 5).unwrap(), 0);
    // Failed writes leave the store untouched and uncounted.
    assert_eq!(g.writes(), 0);
    assert!(g.dense().iter().all(|&w| w == 0));
    // Truly out-of-bounds addresses are BadAddress, not Pruned.
    assert!(matches!(g.write(8, 0, 1), Err(MemError::BadAddress { .. })));
}

#[test]
fn sparse_store_rejects_out_of_range_at_band_edges() {
    let mut g = SynapticMemory::new(8, 8, Topology::Gaussian { radius: 1 }, Q5_3, MemKind::Bram);
    // (0,1) and (7,6) are the first/last band-edge slots: storage exists,
    // but the Q5.3 word range is still enforced.
    assert!(matches!(g.write(0, 1, 4000), Err(MemError::OutOfRange { .. })));
    assert!(matches!(g.write(7, 6, -4000), Err(MemError::OutOfRange { .. })));
    // An out-of-range word delivered at a band edge via the packed bulk
    // path is rejected without mutating.
    let nnz = g.synapses();
    let mut packed = vec![0i32; nnz];
    *packed.last_mut().unwrap() = 9000;
    assert!(matches!(g.load_packed(&packed), Err(MemError::OutOfRange { .. })));
    assert_eq!(g.writes(), 0);
    // In-range edge writes succeed.
    g.write(0, 1, Q5_3.max_raw()).unwrap();
    g.write(7, 6, Q5_3.min_raw()).unwrap();
    assert_eq!(g.read(0, 1).unwrap(), Q5_3.max_raw());
}

#[test]
fn bulk_size_reports_per_topology_payload_sizes() {
    // Regression for the dense-size assumption: the packed bulk path must
    // report the per-topology physical payload in `expect` — diagonal = N,
    // banded = nnz — while the dense path keeps reporting M×N.
    let mut one = SynapticMemory::new(8, 8, Topology::OneToOne, Q5_3, MemKind::Bram);
    assert_eq!(
        one.load_packed(&[1, 2, 3]).unwrap_err(),
        MemError::BulkSize { expect: 8, got: 3 }
    );
    let mut g = SynapticMemory::new(8, 8, Topology::Gaussian { radius: 2 }, Q5_3, MemKind::Bram);
    let nnz = g.synapses(); // 5*8 - 2 - 4 band words clipped at the edges
    assert_eq!(nnz, 34);
    assert_eq!(
        g.load_packed(&vec![0; 64]).unwrap_err(),
        MemError::BulkSize { expect: nnz, got: 64 },
        "banded bulk load must not assume the dense size"
    );
    assert_eq!(
        g.load_dense(&vec![0; nnz]).unwrap_err(),
        MemError::BulkSize { expect: 64, got: nnz },
        "dense bulk load still expects the dense matrix"
    );
    // All-to-all: packed and dense coincide.
    let mut full = SynapticMemory::new(4, 3, Topology::AllToAll, Q5_3, MemKind::Bram);
    assert_eq!(
        full.load_packed(&[0; 5]).unwrap_err(),
        MemError::BulkSize { expect: 12, got: 5 }
    );
}

#[test]
fn reset_mode_from_i32_rejects_all_out_of_range_encodings() {
    use quantisenc::config::registers::ResetMode;
    // The decoder accepts exactly the four Eq. 7 encodings; everything
    // else — including the integer extremes — must decode to None, never
    // wrap or panic.
    for x in [-1, 4, 5, 17, 100, i32::MIN, i32::MAX, i32::MIN + 3, -4] {
        assert_eq!(ResetMode::from_i32(x), None, "encoding {x} must be rejected");
    }
    for mode in ResetMode::all() {
        assert_eq!(ResetMode::from_i32(mode as i32), Some(mode), "{mode:?} round-trips");
    }
}

#[test]
fn control_plane_rejects_malformed_programs_with_typed_errors() {
    use quantisenc::config::registers::{RegisterError, RegisterFile, NUM_REGS, REG_RESET_MODE};
    use quantisenc::coordinator::control::{ControlError, ReconfigProgram};
    use quantisenc::coordinator::serving::{ServingEngine, ServingOptions};

    let cfg = ModelConfig::parse_arch("8x6x4", Q5_3).unwrap();
    let weights = vec![vec![0; 48], vec![0; 24]];
    let regs = RegisterFile::new(Q5_3);
    let engine = ServingEngine::new(&cfg, &weights, &regs, ServingOptions::default()).unwrap();
    let control = engine.control_plane();

    // cfg_in: out-of-range register index → typed RegisterError inside the
    // ControlError, with the address preserved.
    for addr in [NUM_REGS, NUM_REGS + 1, 99, usize::MAX] {
        match control.apply(ReconfigProgram::new().write(addr, 0)) {
            Err(ControlError::Register(RegisterError::BadAddress(a))) => assert_eq!(a, addr),
            other => panic!("address {addr}: expected BadAddress, got {other:?}"),
        }
    }
    // cfg_in: bad reset encoding and out-of-range value are register-typed
    // too, and a good write ahead of a bad one must not stick.
    let p = ReconfigProgram::new().write(2, 4).write(REG_RESET_MODE, 9);
    assert_eq!(
        control.apply(p),
        Err(ControlError::Register(RegisterError::BadResetMode(9)))
    );
    assert_eq!(control.registers().vector(), regs.vector(), "partial apply leaked");
    // wt_in: layer address, payload size, and word range all typed.
    assert_eq!(
        control.apply(ReconfigProgram::new().swap_weights(2, vec![])),
        Err(ControlError::BadLayer { layer: 2, layers: 2 })
    );
    assert_eq!(
        control.apply(ReconfigProgram::new().swap_weights(1, vec![0; 5])),
        Err(ControlError::PayloadSize { layer: 1, expect: 24, got: 5 })
    );
    assert!(matches!(
        control.apply(ReconfigProgram::new().swap_weights(0, vec![1000; 48])),
        Err(ControlError::WeightOutOfRange { layer: 0, .. })
    ));
    // Nothing was admitted: epoch and ledger untouched.
    assert_eq!(control.epoch(), 0);
    assert_eq!(control.bus().beats(), 0);
}

#[test]
fn pipeline_survives_zero_length_streams() {
    use quantisenc::config::registers::RegisterFile;
    use quantisenc::coordinator::pipeline::run_pipelined;
    use quantisenc::datasets::Sample;
    let cfg = ModelConfig::parse_arch("3x2", Q5_3).unwrap();
    let regs = RegisterFile::new(Q5_3);
    let samples = vec![Sample { spikes: vec![], t_steps: 0, inputs: 3, label: 0 }];
    let out = run_pipelined(&cfg, &[vec![0; 6]], &regs, &samples).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].counts, vec![0, 0]);
}
