//! SIMD-vs-scalar differential suite — the conformance gate behind
//! `neuron::step_soa_lanes_simd` and the layer's vector lane kernels.
//!
//! The scalar per-lane loop (`step_soa_lanes`) is the always-available
//! oracle; every vector tier (SSE2, AVX2) and the runtime dispatcher must
//! be **bit-identical** to it — lane state banks, spike words, toggle
//! words, spike-count ledgers, and activity ledgers — across:
//!
//! * the saturation corner corpus (`tests/common`): vmem at ±max and one
//!   ulp inside, thresholds at both raw extremes, zero decay, refractory
//!   wrap — the vectors whose scalar behaviour the quiescence proofs
//!   already pin down and the vector masks must re-prove;
//! * full cores over AllToAll / OneToOne / Gaussian{2} topologies ×
//!   Q9.7 / Q5.3 / Q3.1 × 220-step streams at 0 / 2 / 35 / 90 % input
//!   firing × lane widths 1 / 37 / 64.
//!
//! On non-x86 targets (and wherever AVX2 is absent) the pinned vector
//! kernels fall back to the scalar loop inside `step_soa_lanes_with`, so
//! this suite degenerates to scalar-vs-scalar and stays green everywhere.

mod common;

use quantisenc::config::registers::{RegisterFile, ResetMode};
use quantisenc::config::{ModelConfig, Topology};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::datasets::Sample;
use quantisenc::fixed::{QSpec, Q3_1, Q5_3, Q9_7};
use quantisenc::hdl::neuron::{
    quiescent_hold_range, step_soa_lanes, step_soa_lanes_simd, step_soa_lanes_with, LaneKernel,
};
use quantisenc::hdl::{Core, SpikeMatrix};

/// Every vector kernel (and the auto dispatcher) against the scalar oracle
/// on 64-lane state banks tiled from the saturation corner corpus: 220
/// steps per register corner, active masks cycling through full / sparse /
/// alternating / random patterns, activations cycling through silence,
/// corpus extremes, and random in-range values. State, spike words, and
/// toggle words must agree bit-for-bit after every step.
#[test]
fn kernels_match_scalar_on_corner_corpus() {
    let mut rng = XorShift64Star::new(0x51D_C0DE);
    for qs in [Q9_7, Q5_3, Q3_1] {
        for (tag, regs) in common::corner_reg_sets(qs) {
            let corners = common::corner_states(qs);
            let hold = quiescent_hold_range(&regs, qs);
            let lanes = 64usize;
            let mut vmem0 = vec![0i32; lanes];
            let mut ref0 = vec![0i32; lanes];
            let mut act0 = vec![0i32; lanes];
            for l in 0..lanes {
                let c = corners[l % corners.len()];
                vmem0[l] = c.vmem;
                ref0[l] = c.refcnt;
                act0[l] = c.act;
            }
            let mut oracle = (vmem0.clone(), ref0.clone());
            let mut twins: Vec<(&str, Vec<i32>, Vec<i32>)> = vec![
                ("sse2", vmem0.clone(), ref0.clone()),
                ("avx2", vmem0.clone(), ref0.clone()),
                ("auto", vmem0, ref0),
            ];
            let mut act = act0.clone();
            for step in 0..220 {
                let active = match step % 4 {
                    0 => u64::MAX,
                    1 => 0xF0F0_F0F0_F0F0_F0F3,
                    2 => 0xAAAA_AAAA_AAAA_AAAB,
                    _ => rng.next_u64() | 1,
                };
                let want =
                    step_soa_lanes(&mut oracle.0, &mut oracle.1, &act, active, hold, &regs, qs);
                for (name, vm, rc) in twins.iter_mut() {
                    let got = match *name {
                        "sse2" => step_soa_lanes_with(
                            LaneKernel::Sse2,
                            vm,
                            rc,
                            &act,
                            active,
                            hold,
                            &regs,
                            qs,
                        ),
                        "avx2" => step_soa_lanes_with(
                            LaneKernel::Avx2,
                            vm,
                            rc,
                            &act,
                            active,
                            hold,
                            &regs,
                            qs,
                        ),
                        _ => step_soa_lanes_simd(vm, rc, &act, active, hold, &regs, qs),
                    };
                    assert_eq!(got, want, "{tag} step {step} {name}: spike/toggle words");
                    assert_eq!(vm, &oracle.0, "{tag} step {step} {name}: vmem bank");
                    assert_eq!(rc, &oracle.1, "{tag} step {step} {name}: refcnt bank");
                }
                for (l, a) in act.iter_mut().enumerate() {
                    *a = match step % 3 {
                        0 => 0,
                        1 => act0[(l + step) % lanes],
                        // Wrapped to W bits, exactly like the layer's
                        // ActGen before the neuron sweep.
                        _ => qs.wrap(rng.next_u64() as i64),
                    };
                }
            }
        }
    }
}

fn masked_weights(cfg: &ModelConfig, rng: &mut XorShift64Star) -> Vec<Vec<i32>> {
    cfg.layers()
        .iter()
        .map(|l| {
            let lim = cfg.qspec.max_raw().min(127) as u64;
            let mask = l.topology.mask(l.fan_in, l.neurons).unwrap();
            mask.iter()
                .map(|&a| if a == 0 { 0 } else { (rng.below(2 * lim + 1) as i32) - lim as i32 })
                .collect()
        })
        .collect()
}

/// The headline matrix: pinned-SIMD cores against the pinned-scalar twin
/// over AllToAll / OneToOne / Gaussian{2} × Q9.7 / Q5.3 / Q3.1 × ~220-step
/// ragged streams at 0 / 2 / 35 / 90 % firing × lanes 1 / 37 / 64 — spike
/// counts, per-layer spike ledgers, activity ledgers, predictions, and the
/// final per-layer lane state banks must all be bit-identical. The `None`
/// twin additionally runs the firing-rate-aware kernel policy, whose
/// scalar/vector choice must be invisible in the results.
#[test]
fn simd_core_twins_match_scalar_across_matrix() {
    let mut rng = XorShift64Star::new(0x51D_C1);
    let topologies: [(&str, Vec<usize>, Vec<Topology>); 3] = [
        ("all-to-all", vec![16, 12, 10], vec![Topology::AllToAll, Topology::AllToAll]),
        ("one-to-one", vec![20, 20], vec![Topology::OneToOne]),
        ("gaussian-r2", vec![24, 24], vec![Topology::Gaussian { radius: 2 }]),
    ];
    for (topo_name, sizes, topos) in &topologies {
        for qs in [Q9_7, Q5_3, Q3_1] {
            let cfg = ModelConfig::with_topologies(sizes, topos, qs).unwrap();
            let weights = masked_weights(&cfg, &mut rng);
            for (di, density) in [0.0f64, 0.02, 0.35, 0.90].into_iter().enumerate() {
                let mut regs = RegisterFile::new(qs);
                regs.set_reset_mode(ResetMode::all()[di % 4]).unwrap();
                regs.set_refractory((di % 3) as i32).unwrap();
                for lanes in [1usize, 37, 64] {
                    let samples: Vec<Sample> = (0..lanes)
                        .map(|l| {
                            let t_steps = 220 - (l % 7);
                            let spikes = (0..t_steps * cfg.inputs())
                                .map(|_| (rng.uniform() < density) as u8)
                                .collect();
                            Sample { spikes, t_steps, inputs: cfg.inputs(), label: 0 }
                        })
                        .collect();
                    let mut oracle = Core::new(cfg.clone());
                    oracle.load_weights(&weights).unwrap();
                    oracle.registers = regs.clone();
                    oracle.set_lane_kernel(Some(LaneKernel::Scalar));
                    let want = oracle.run_lanes(&samples);
                    for kernel in [Some(LaneKernel::Sse2), Some(LaneKernel::Avx2), None] {
                        let mut twin = Core::new(cfg.clone());
                        twin.load_weights(&weights).unwrap();
                        twin.registers = regs.clone();
                        twin.set_lane_kernel(kernel);
                        let got = twin.run_lanes(&samples);
                        let ctx = format!(
                            "{topo_name} {qs} density {density} lanes {lanes} kernel {kernel:?}"
                        );
                        assert_eq!(got.len(), want.len(), "{ctx}");
                        for (l, (g, w)) in got.iter().zip(&want).enumerate() {
                            assert_eq!(g.counts, w.counts, "{ctx} lane {l}: spike counts");
                            assert_eq!(
                                g.layer_spikes, w.layer_spikes,
                                "{ctx} lane {l}: per-layer spike ledger"
                            );
                            assert_eq!(g.stats, w.stats, "{ctx} lane {l}: activity ledger");
                            assert_eq!(g.prediction, w.prediction, "{ctx} lane {l}: prediction");
                        }
                        for (k, (a, b)) in
                            oracle.layers().iter().zip(twin.layers()).enumerate()
                        {
                            assert_eq!(
                                a.lane_state(),
                                b.lane_state(),
                                "{ctx} layer {k}: final lane vmem/refcnt bank"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Saturation-boundary vectors driven through the full core lane datapath:
/// corner vmem/refcnt banks are injected into every layer of pinned twins
/// via `restore_lanes`, then 60 input matrices (dense, silent, and
/// ragged-masked) are stepped through `Core::step_lanes` — output spike
/// matrices and every layer's lane banks must stay bit-identical while the
/// injected extremes decay, spike, reset, and wrap through refractory.
#[test]
fn injected_saturation_banks_step_identically() {
    let mut rng = XorShift64Star::new(0x51D_C2);
    let lanes = 64usize;
    for qs in [Q9_7, Q5_3, Q3_1] {
        let cfg = ModelConfig::with_topologies(&[14, 11, 10], &[Topology::AllToAll; 2], qs)
            .unwrap();
        let weights = masked_weights(&cfg, &mut rng);
        let corners = common::corner_states(qs);
        let mut regs = RegisterFile::new(qs);
        regs.set_refractory(3).unwrap();
        regs.set_reset_mode(ResetMode::ToConstant).unwrap();
        for kernel in [LaneKernel::Sse2, LaneKernel::Avx2] {
            let mut oracle = Core::new(cfg.clone());
            let mut twin = Core::new(cfg.clone());
            for core in [&mut oracle, &mut twin] {
                core.load_weights(&weights).unwrap();
                core.registers = regs.clone();
            }
            oracle.set_lane_kernel(Some(LaneKernel::Scalar));
            twin.set_lane_kernel(Some(kernel));
            // Inject the corner corpus, tiled with a different phase per
            // layer so every (corner state, lane slot) pairing occurs.
            for (k, layer_cfg) in cfg.layers().iter().enumerate() {
                let m = layer_cfg.neurons;
                let mut vbank = vec![0i32; m * lanes];
                let mut rbank = vec![0i32; m * lanes];
                for j in 0..m {
                    for l in 0..lanes {
                        let c = corners[(j * 13 + l + k) % corners.len()];
                        vbank[j * lanes + l] = c.vmem;
                        rbank[j * lanes + l] = c.refcnt;
                    }
                }
                oracle.layer_mut(k).restore_lanes(lanes, &vbank, &rbank);
                twin.layer_mut(k).restore_lanes(lanes, &vbank, &rbank);
            }
            let n_layers = cfg.num_layers();
            let mut spikes_a = vec![0u64; n_layers * lanes];
            let mut spikes_b = vec![0u64; n_layers * lanes];
            let mut stats_a = vec![Default::default(); lanes];
            let mut stats_b = vec![Default::default(); lanes];
            let mut input = SpikeMatrix::new(cfg.inputs(), lanes);
            for step in 0..60 {
                input.resize_clear(cfg.inputs(), lanes);
                let density = [0.0, 0.9, 0.2][step % 3];
                for i in 0..cfg.inputs() {
                    let mut word = 0u64;
                    for l in 0..lanes {
                        if rng.uniform() < density {
                            word |= 1 << l;
                        }
                    }
                    input.set_line_word(i, word);
                }
                let active = match step % 3 {
                    0 => u64::MAX,
                    1 => 0x0F0F_0F0F_0F0F_0F0F,
                    _ => rng.next_u64() | 1,
                };
                let out_a = oracle.step_lanes(&input, active, &mut spikes_a, &mut stats_a);
                let ctx = format!("{qs} kernel {kernel:?} step {step}");
                let out_b = twin.step_lanes(&input, active, &mut spikes_b, &mut stats_b);
                assert_eq!(out_a, out_b, "{ctx}: output spike matrix");
                assert_eq!(spikes_a, spikes_b, "{ctx}: layer spike ledgers");
                assert_eq!(stats_a, stats_b, "{ctx}: activity ledgers");
                for (k, (a, b)) in oracle.layers().iter().zip(twin.layers()).enumerate() {
                    assert_eq!(a.lane_state(), b.lane_state(), "{ctx} layer {k}: lane banks");
                }
            }
        }
    }
}
