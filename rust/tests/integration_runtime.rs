//! Integration: PJRT runtime × artifacts × cycle-accurate hdl core.
//!
//! The strongest correctness statement in the repo: the AOT-compiled HLO
//! (jax + Pallas, lowered at build time) and the Rust cycle-accurate
//! simulator must produce **bit-identical** spike counts and per-layer
//! spike totals on real dataset samples, with the same programmed weights
//! and control registers. Requires `make artifacts`.

use quantisenc::config::ModelConfig;
use quantisenc::datasets::{Dataset, Split};
use quantisenc::fixed::QSpec;
use quantisenc::hdl::Core;
use quantisenc::runtime::{artifacts::Manifest, Runtime};

fn manifest() -> Manifest {
    let dir = quantisenc::golden::ensure_artifacts().expect("native artifact bootstrap");
    Manifest::load(&dir).expect("load generated manifest")
}

#[test]
fn manifest_lists_all_models() {
    let m = manifest();
    let ds = m.datasets();
    for want in ["smnist", "dvs", "shd"] {
        assert!(ds.contains(&want.to_string()), "{want} missing from manifest");
    }
    assert!(m.variants("smnist").unwrap().contains(&"Q5.3".to_string()));
}

#[test]
fn pjrt_loads_and_runs_smnist() {
    let m = manifest();
    let art = m.model("smnist", "Q5.3").unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_model(&art).unwrap();

    let sample = Dataset::Smnist.sample(0, Split::Test, art.t_steps);
    let out = exe.run(&sample.spikes).unwrap();
    assert_eq!(out.counts.len(), 10);
    assert!(out.counts.iter().sum::<i32>() > 0, "output layer silent");
}

#[test]
fn hlo_and_hdl_core_are_bitexact() {
    let m = manifest();
    let art = m.model("smnist", "Q5.3").unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_model(&art).unwrap();

    let config = ModelConfig::parse_arch(
        &art.sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join("x"),
        QSpec::parse(&art.qname).unwrap(),
    )
    .unwrap();
    let mut core = Core::new(config);
    core.load_weights(&art.weights).unwrap();
    for (addr, &v) in art.default_regs.iter().enumerate() {
        core.registers.write(addr, v).unwrap();
    }

    for i in 0..5u64 {
        let sample = Dataset::Smnist.sample(i, Split::Test, art.t_steps);
        let hlo = exe.run(&sample.spikes).unwrap();
        let hdl = core.run(&sample);
        let hdl_counts: Vec<i32> = hdl.counts.iter().map(|&c| c as i32).collect();
        assert_eq!(hlo.counts, hdl_counts, "sample {i}: counts diverge");
        let hdl_layer: Vec<i32> = hdl.layer_spikes.iter().map(|&c| c as i32).collect();
        assert_eq!(hlo.layer_spikes, hdl_layer, "sample {i}: layer totals diverge");
    }
}

#[test]
fn quantized_accuracy_beats_chance_and_tracks_float() {
    let m = manifest();
    let art = m.model("smnist", "Q5.3").unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_model(&art).unwrap();

    let n = 60;
    let mut correct = 0;
    for i in 0..n {
        let s = Dataset::Smnist.sample(i, Split::Test, art.t_steps);
        if exe.run(&s.spikes).unwrap().prediction == s.label {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.6, "quantized accuracy {acc} too low (float was {})", art.float_acc);
    assert!(acc <= art.float_acc + 0.15, "quantized can't beat float by much");
}

#[test]
fn quantization_ladder_q97_at_least_q31() {
    // Table VIII ordering: Q9.7 ≥ Q5.3 ≥ Q3.1 accuracy (weak form ≥ with
    // small-sample slack handled by using the same 60 samples).
    let m = manifest();
    let rt = Runtime::cpu().unwrap();
    let mut accs = std::collections::BTreeMap::new();
    for q in ["Q9.7", "Q5.3", "Q3.1"] {
        let art = m.model("smnist", q).unwrap();
        let exe = rt.load_model(&art).unwrap();
        let n = 60;
        let mut correct = 0;
        for i in 0..n {
            let s = Dataset::Smnist.sample(i, Split::Test, art.t_steps);
            if exe.run(&s.spikes).unwrap().prediction == s.label {
                correct += 1;
            }
        }
        accs.insert(q, correct as f64 / n as f64);
    }
    assert!(
        accs["Q9.7"] + 0.05 >= accs["Q3.1"],
        "higher precision should not lose badly: {accs:?}"
    );
}

#[test]
fn lif_step_kernel_artifact_matches_hdl_layer() {
    use quantisenc::config::registers::RegisterFile;
    use quantisenc::config::{LayerConfig, MemKind, Topology};
    use quantisenc::datasets::rng::XorShift64Star;
    use quantisenc::fixed::Q5_3;
    use quantisenc::hdl::Layer;

    let m = manifest();
    let path = m.kernel_hlo_path("lif_step_Q53").unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.compile_hlo_file(&path).unwrap();

    // Random single-step case, 256 -> 128 (the artifact's baked shape).
    let mut rng = XorShift64Star::new(0x99);
    let (mm, nn) = (256usize, 128usize);
    let weights: Vec<i32> = (0..mm * nn).map(|_| rng.below(256) as i32 - 128).collect();
    let spikes: Vec<i32> = (0..mm).map(|_| (rng.uniform() < 0.3) as i32).collect();
    let vmem: Vec<i32> = (0..nn).map(|_| rng.below(256) as i32 - 128).collect();
    let refc: Vec<i32> = (0..nn).map(|_| rng.below(3) as i32).collect();
    let regs = RegisterFile::new(Q5_3);
    let regs_v: Vec<i32> = regs.vector().to_vec();

    let args = [
        xla::Literal::vec1(&spikes),
        xla::Literal::vec1(&weights).reshape(&[mm as i64, nn as i64]).unwrap(),
        xla::Literal::vec1(&vmem),
        xla::Literal::vec1(&refc),
        xla::Literal::vec1(&regs_v),
    ];
    let arg_refs: Vec<&xla::Literal> = args.iter().collect();
    let result = exe.execute::<&xla::Literal>(&arg_refs).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let tup = result.to_tuple().unwrap();
    let hlo_spikes = tup[0].to_vec::<i32>().unwrap();
    let hlo_vmem = tup[1].to_vec::<i32>().unwrap();
    let hlo_ref = tup[2].to_vec::<i32>().unwrap();

    // hdl layer with the same state.
    let cfg = LayerConfig { fan_in: mm, neurons: nn, topology: Topology::AllToAll };
    let mut layer = Layer::new(&cfg, Q5_3, MemKind::Bram);
    layer.memory_mut().load_dense(&weights).unwrap();
    // Seed neuron state by direct construction: run a custom step.
    // (Layer starts at rest; to match arbitrary vmem/refcnt we use the
    // neuron API through a fresh layer is not enough — so instead compare
    // through the rest state: zero vmem/refcnt.)
    let spikes_u8: Vec<u8> = spikes.iter().map(|&x| x as u8).collect();
    // Re-run HLO with rest state for the apples-to-apples comparison.
    let zero = vec![0i32; nn];
    let args2 = [
        xla::Literal::vec1(&spikes),
        xla::Literal::vec1(&weights).reshape(&[mm as i64, nn as i64]).unwrap(),
        xla::Literal::vec1(&zero),
        xla::Literal::vec1(&zero),
        xla::Literal::vec1(&regs_v),
    ];
    let arg_refs2: Vec<&xla::Literal> = args2.iter().collect();
    let r2 = exe.execute::<&xla::Literal>(&arg_refs2).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let tup2 = r2.to_tuple().unwrap();
    let hlo_spikes0 = tup2[0].to_vec::<i32>().unwrap();
    let hlo_vmem0 = tup2[1].to_vec::<i32>().unwrap();

    let mut out = Vec::new();
    layer.step_regs(&spikes_u8, &mut out, &regs);
    let hdl_spikes: Vec<i32> = out.iter().map(|&s| s as i32).collect();
    assert_eq!(hlo_spikes0, hdl_spikes, "single-step kernel vs hdl layer");
    assert_eq!(hlo_vmem0, layer.vmem_slice());

    // And the arbitrary-state outputs at least have the right arity.
    assert_eq!(hlo_spikes.len(), nn);
    assert_eq!(hlo_vmem.len(), nn);
    assert_eq!(hlo_ref.len(), nn);
}
