//! The snapshot differential gate (the PR's headline acceptance): run k
//! samples, freeze the engine to a connectome image, revive it into a
//! fresh engine, and run the remainder — the interrupted run must be
//! bit-identical to an uninterrupted one, across three topologies, both
//! lane widths, and an in-band reconfiguration that straddles the
//! snapshot point. Plus the corruption suite: no mutilated image —
//! truncated, bit-flipped, wrong magic or version — may panic the
//! decoder or restore into an engine.

use quantisenc::config::registers::{RegisterFile, REG_VTH};
use quantisenc::config::{ModelConfig, Topology};
use quantisenc::coordinator::connectome::{Connectome, SnapshotError};
use quantisenc::coordinator::control::ReconfigProgram;
use quantisenc::coordinator::serving::{ServingEngine, ServingOptions, SessionOp};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::datasets::Sample;
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::ActivityStats;

/// A 32→32→10 model whose first layer uses the given topology, with
/// seeded random weights sized to the dense fan-in (the topology store
/// masks them down internally).
fn model_for(topo: Topology) -> (ModelConfig, Vec<Vec<i32>>, RegisterFile) {
    let sizes = [32usize, 32, 10];
    let cfg = ModelConfig::with_topologies(&sizes, &[topo, Topology::AllToAll], Q5_3).unwrap();
    let mut rng = XorShift64Star::new(0xC0_FFEE ^ topo_tag(topo));
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(15) as i32 - 7).collect())
        .collect();
    (cfg, weights, RegisterFile::new(Q5_3))
}

fn topo_tag(t: Topology) -> u64 {
    match t {
        Topology::AllToAll => 1,
        Topology::OneToOne => 2,
        Topology::Gaussian { radius } => 0x100 + radius as u64,
    }
}

/// Deterministic random spike trains shaped for the 32-input model.
fn spike_samples(n: usize) -> Vec<Sample> {
    let mut rng = XorShift64Star::new(0x5A_17E5);
    (0..n)
        .map(|_| {
            let t_steps = 6;
            let inputs = 32;
            let spikes = (0..t_steps * inputs).map(|_| (rng.uniform() < 0.25) as u8).collect();
            Sample { spikes, t_steps, inputs, label: 0 }
        })
        .collect()
}

fn assert_results_equal(
    a: &[quantisenc::coordinator::pipeline::StreamResult],
    b: &[quantisenc::coordinator::pipeline::StreamResult],
    ctx: &str,
) {
    assert_eq!(a.len(), b.len(), "{ctx}: result count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.stream_id, y.stream_id, "{ctx}");
        assert_eq!(x.counts, y.counts, "{ctx}: stream {}", x.stream_id);
        assert_eq!(x.prediction, y.prediction, "{ctx}: stream {}", x.stream_id);
        assert_eq!(x.spikes_total, y.spikes_total, "{ctx}: stream {}", x.stream_id);
        assert_eq!(x.epoch, y.epoch, "{ctx}: stream {}", x.stream_id);
        let (xs, ys): (ActivityStats, ActivityStats) = (x.stats, y.stats);
        assert_eq!(xs, ys, "{ctx}: stream {}", x.stream_id);
    }
}

/// The gate proper: snapshot after 4 samples, restore, then run 4 more
/// with an in-band reconfig in the second half — so the epoch bump the
/// snapshot must survive happens *after* the restore point.
#[test]
fn interrupted_run_is_bit_identical_to_uninterrupted() {
    let topologies = [Topology::AllToAll, Topology::OneToOne, Topology::Gaussian { radius: 2 }];
    let samples = spike_samples(8);
    for topo in topologies {
        for lanes in [1usize, 64] {
            let ctx = format!("{topo:?} lanes={lanes}");
            let (cfg, weights, regs) = model_for(topo);
            let options = ServingOptions::with_lanes(2, lanes);
            let mut uninterrupted = ServingEngine::new(&cfg, &weights, &regs, options).unwrap();
            let mut donor = ServingEngine::new(&cfg, &weights, &regs, options).unwrap();

            let first: Vec<SessionOp> = samples[..4].iter().map(SessionOp::Submit).collect();
            let second: Vec<SessionOp> = samples[4..6]
                .iter()
                .map(SessionOp::Submit)
                .chain(std::iter::once(SessionOp::Reconfig(
                    ReconfigProgram::new().write(REG_VTH, regs.vth() + 8),
                )))
                .chain(samples[6..].iter().map(SessionOp::Submit))
                .collect();

            let u_first = uninterrupted.run_session(&first).unwrap();
            let d_first = donor.run_session(&first).unwrap();
            assert_results_equal(&u_first, &d_first, &ctx);

            // Freeze the donor, push the image through the codec, revive.
            let snap = donor.snapshot().unwrap_or_else(|e| panic!("{ctx}: snapshot: {e}"));
            assert_eq!((snap.submitted, snap.completed), (4, 4), "{ctx}: quiesced");
            let bytes = snap.encode();
            let decoded = Connectome::decode(&bytes).unwrap_or_else(|e| panic!("{ctx}: {e}"));
            assert_eq!(decoded, snap, "{ctx}: codec round-trip");
            let mut revived = ServingEngine::from_connectome(&decoded)
                .unwrap_or_else(|e| panic!("{ctx}: restore: {e}"));

            // The remainder — including the straddling reconfig — must be
            // bit-identical between the revived and uninterrupted engines.
            let u_second = uninterrupted.run_session(&second).unwrap();
            let r_second = revived.run_session(&second).unwrap();
            assert_results_equal(&u_second, &r_second, &ctx);

            // Stronger than result equality: both machines re-freeze to
            // byte-identical images.
            let u_image = uninterrupted.snapshot().unwrap().encode();
            let r_image = revived.snapshot().unwrap().encode();
            assert_eq!(u_image, r_image, "{ctx}: final state images differ");
        }
    }
}

/// A small engine keeps the image compact enough to sweep every
/// truncation length and a dense grid of bit flips in test time.
fn small_image() -> Vec<u8> {
    let sizes = [8usize, 6, 4];
    let cfg = ModelConfig::with_topologies(
        &sizes,
        &[Topology::AllToAll, Topology::Gaussian { radius: 1 }],
        Q5_3,
    )
    .unwrap();
    let mut rng = XorShift64Star::new(0xDEC0DE);
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(15) as i32 - 7).collect())
        .collect();
    let regs = RegisterFile::new(Q5_3);
    let mut engine =
        ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_lanes(2, 1)).unwrap();
    let samples: Vec<Sample> = (0..3)
        .map(|_| {
            let spikes = (0..6 * 8).map(|_| (rng.uniform() < 0.3) as u8).collect();
            Sample { spikes, t_steps: 6, inputs: 8, label: 0 }
        })
        .collect();
    engine.run_batch(&samples).unwrap();
    engine.snapshot().unwrap().encode()
}

#[test]
fn every_truncation_is_a_typed_error_never_a_panic() {
    let bytes = small_image();
    assert!(Connectome::decode(&bytes).is_ok(), "the intact image decodes");
    for cut in 0..bytes.len() {
        match Connectome::decode(&bytes[..cut]) {
            Ok(c) => panic!("truncated image decoded at cut {cut}/{}: {c:?}", bytes.len()),
            Err(_) => {} // any typed error is fine; a panic would abort the test
        }
    }
}

#[test]
fn bit_flips_never_panic_and_never_silently_corrupt() {
    let bytes = small_image();
    let baseline = Connectome::decode(&bytes).unwrap();
    let mut rng = XorShift64Star::new(0xF11B);
    // Every byte of the header region plus a dense random sample of the
    // payload: a flip must surface as a typed error (CRC, magic, version,
    // structure) — or, where it lands in redundant freedom the format
    // does not have, decode to something that still re-encodes
    // byte-identically to the mutated image. Never a panic, and never a
    // silent pass-through of different state under an intact-looking API.
    let positions: Vec<usize> = (0..bytes.len().min(64))
        .chain((0..400).map(|_| rng.below(bytes.len() as u64) as usize))
        .collect();
    for pos in positions {
        for bit in 0..8 {
            let mut mutated = bytes.clone();
            mutated[pos] ^= 1 << bit;
            match Connectome::decode(&mutated) {
                Err(_) => {}
                Ok(c) => {
                    assert_eq!(
                        c.encode(),
                        mutated,
                        "byte {pos} bit {bit}: decode accepted a mutation it cannot re-encode"
                    );
                    assert_ne!(
                        c, baseline,
                        "byte {pos} bit {bit}: mutation decoded back to the baseline image"
                    );
                }
            }
        }
    }
}

#[test]
fn wrong_magic_and_version_are_typed_errors() {
    let bytes = small_image();
    let mut bad_magic = bytes.clone();
    bad_magic[0] ^= 0xFF;
    assert!(matches!(Connectome::decode(&bad_magic), Err(SnapshotError::BadMagic(_))));
    let mut bad_version = bytes.clone();
    bad_version[4] ^= 0xFF;
    assert!(matches!(Connectome::decode(&bad_version), Err(SnapshotError::BadVersion(_))));
    assert!(matches!(Connectome::decode(&[]), Err(SnapshotError::Truncated { .. })));
    assert!(matches!(Connectome::decode(&[0; 3]), Err(SnapshotError::Truncated { .. })));
}

#[test]
fn geometry_mismatched_restore_is_a_typed_error() {
    // An image from the 8x6x4 engine must not revive after its geometry
    // header is edited to claim a different shard count — the layer
    // section arity check catches it as a typed error.
    let bytes = small_image();
    let c = Connectome::decode(&bytes).unwrap();
    let mut wrong = c.clone();
    wrong.cores = 3; // image still carries 2 shards' worth of layer sections
    assert!(
        ServingEngine::from_connectome(&wrong).is_err(),
        "shard arity mismatch must be a typed error"
    );
    let mut wrong = c.clone();
    wrong.sizes = vec![8, 7, 4]; // weights no longer fit the claimed model
    assert!(
        ServingEngine::from_connectome(&wrong).is_err(),
        "payload-size mismatch must be a typed error"
    );
}
