//! Property/fuzz tests for the network front door's wire codec
//! (`coordinator::wire`): random frames round-trip bit-exactly, and no
//! input — truncated, oversized, bit-flipped, or pure garbage — can make
//! the decoder panic or accept a malformed frame silently.

use quantisenc::coordinator::wire::{
    self, ErrorCode, Frame, WireError, DEFAULT_MAX_FRAME_LEN, VERSION,
};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::datasets::{Dataset, Split};

/// Draw one random-but-valid frame of every variant class.
fn random_frame(rng: &mut XorShift64Star) -> Frame {
    match rng.below(13) {
        0 => Frame::Hello { version: VERSION },
        1 => Frame::HelloAck {
            version: rng.next_u64() as u16,
            inputs: rng.below(1 << 20) as u32,
            outputs: rng.below(1 << 12) as u32,
            cores: rng.below(64) as u16,
            lane_width: (1 + rng.below(64)) as u16,
        },
        2 => Frame::OpenSession { max_inflight: rng.below(1 << 16) as u32 },
        3 => Frame::SessionOpened {
            session: rng.next_u64() as u32,
            max_inflight: rng.below(1 << 16) as u32,
        },
        4 => {
            let t_steps = 1 + rng.below(24) as u32;
            let inputs = 1 + rng.below(300) as u32;
            let bits: Vec<u8> =
                (0..t_steps as usize * inputs as usize).map(|_| (rng.uniform() < 0.2) as u8).collect();
            Frame::SubmitSample {
                session: rng.next_u64() as u32,
                sample: rng.next_u64(),
                t_steps,
                inputs,
                spikes: wire::pack_bits(&bits),
            }
        }
        5 => {
            let cfg: Vec<(u16, i32)> =
                (0..rng.below(5)).map(|_| (rng.below(32) as u16, rng.next_u64() as i32)).collect();
            let weights: Vec<(u16, Vec<i32>)> = (0..rng.below(3))
                .map(|_| {
                    let words = rng.below(40) as usize;
                    (rng.below(4) as u16, (0..words).map(|_| rng.next_u64() as i32).collect())
                })
                .collect();
            Frame::Reconfig { session: rng.next_u64() as u32, request: rng.next_u64(), cfg, weights }
        }
        6 => {
            let counts: Vec<u32> = (0..rng.below(20)).map(|_| rng.next_u64() as u32).collect();
            Frame::Result {
                session: rng.next_u64() as u32,
                sample: rng.next_u64(),
                epoch: rng.below(1 << 20),
                prediction: rng.below(16) as u32,
                spikes_total: rng.below(1 << 30),
                counts,
            }
        }
        7 => Frame::ReconfigAck {
            session: rng.next_u64() as u32,
            request: rng.next_u64(),
            epoch: rng.below(1 << 20),
        },
        8 => Frame::Snapshot { session: rng.next_u64() as u32, request: rng.next_u64() },
        9 => {
            let bytes: Vec<u8> = (0..rng.below(200)).map(|_| rng.next_u64() as u8).collect();
            Frame::SnapshotData { session: rng.next_u64() as u32, request: rng.next_u64(), bytes }
        }
        10 => {
            let bytes: Vec<u8> = (0..rng.below(200)).map(|_| rng.next_u64() as u8).collect();
            Frame::Restore { session: rng.next_u64() as u32, request: rng.next_u64(), bytes }
        }
        11 => Frame::RestoreAck {
            session: rng.next_u64() as u32,
            request: rng.next_u64(),
            epoch: rng.below(1 << 20),
        },
        _ => {
            let code = ErrorCode::from_u16(1 + rng.below(7) as u16).unwrap();
            let msg: String =
                (0..rng.below(40)).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
            Frame::Error {
                code,
                session: rng.next_u64() as u32,
                reference: rng.next_u64(),
                message: msg,
            }
        }
    }
}

#[test]
fn random_frames_roundtrip_bit_exactly() {
    let mut rng = XorShift64Star::new(0x51DE_CA7);
    for _ in 0..2000 {
        let frame = random_frame(&mut rng);
        let body = frame.encode().expect("valid frames encode");
        let back = Frame::decode(&body)
            .unwrap_or_else(|e| panic!("decode of {frame:?} failed: {e}"));
        assert_eq!(frame, back);
    }
}

#[test]
fn every_truncation_is_a_typed_error_never_a_panic() {
    let mut rng = XorShift64Star::new(0x7A_BC01);
    for _ in 0..300 {
        let frame = random_frame(&mut rng);
        let body = frame.encode().unwrap();
        for cut in 0..body.len() {
            match Frame::decode(&body[..cut]) {
                Ok(f) => {
                    // A prefix that still decodes must not silently drop
                    // payload: it can only happen if the cut removed
                    // nothing the decoder reads, which the trailing-bytes
                    // check forbids for every variant.
                    panic!("truncated body decoded to {f:?} (cut {cut}/{})", body.len());
                }
                Err(WireError::Truncated { .. })
                | Err(WireError::BadValue(_))
                | Err(WireError::BadType(_))
                | Err(WireError::BadMagic(_)) => {}
                Err(e) => panic!("unexpected error class for truncation: {e}"),
            }
        }
    }
}

#[test]
fn garbage_bodies_never_panic() {
    let mut rng = XorShift64Star::new(0xBAD_F00D);
    for _ in 0..5000 {
        let len = rng.below(200) as usize + 1;
        let body: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        // Any outcome but a panic is acceptable; a successful decode must
        // re-encode (the grammar has no unparseable-but-valid frames).
        if let Ok(f) = Frame::decode(&body) {
            f.encode().expect("decoded frames must re-encode");
        }
    }
}

#[test]
fn bit_flips_never_panic_and_often_reject() {
    let mut rng = XorShift64Star::new(0xF11B_1234);
    for _ in 0..400 {
        let frame = random_frame(&mut rng);
        let mut body = frame.encode().unwrap();
        let byte = rng.below(body.len() as u64) as usize;
        body[byte] ^= 1 << rng.below(8);
        if let Ok(f) = Frame::decode(&body) {
            f.encode().expect("mutated-but-valid frames must re-encode");
        }
    }
}

#[test]
fn trailing_bytes_are_rejected() {
    let mut rng = XorShift64Star::new(0x7_1A11);
    for _ in 0..200 {
        let frame = random_frame(&mut rng);
        let mut body = frame.encode().unwrap();
        body.push(0xAB);
        match Frame::decode(&body) {
            Err(WireError::TrailingBytes { .. }) => {}
            // Variants whose last field is length-counted may instead see
            // the extra byte as a truncated next element — also typed.
            Err(WireError::Truncated { .. }) | Err(WireError::BadValue(_)) => {}
            other => panic!("trailing byte not rejected: {other:?}"),
        }
    }
}

#[test]
fn hostile_length_prefix_is_capped_before_allocation() {
    // 4 GiB-1 length prefix: must be rejected by the cap, not allocated.
    let mut stream: &[u8] = &[0xFF, 0xFF, 0xFF, 0xFF, 0x01];
    match wire::read_frame(&mut stream, DEFAULT_MAX_FRAME_LEN) {
        Err(WireError::TooLarge { len, max }) => {
            assert_eq!(len, u32::MAX);
            assert_eq!(max, DEFAULT_MAX_FRAME_LEN);
        }
        other => panic!("expected TooLarge, got {other:?}"),
    }
    // Zero-length frames are equally invalid.
    let mut empty: &[u8] = &[0, 0, 0, 0];
    assert!(matches!(
        wire::read_frame(&mut empty, DEFAULT_MAX_FRAME_LEN),
        Err(WireError::Truncated { .. })
    ));
}

#[test]
fn submit_sample_payload_arity_is_enforced() {
    // A SubmitSample whose spike payload does not match t_steps × inputs
    // must be rejected — the decoder may not trust the counts.
    let frame = Frame::SubmitSample {
        session: 1,
        sample: 2,
        t_steps: 4,
        inputs: 16,
        spikes: wire::pack_bits(&vec![1u8; 4 * 16]),
    };
    let good = frame.encode().unwrap();
    assert!(Frame::decode(&good).is_ok());
    // Claim more timesteps than the payload carries.
    let frame = Frame::SubmitSample {
        session: 1,
        sample: 2,
        t_steps: 4,
        inputs: 16,
        spikes: vec![0u8; 3],
    };
    assert!(frame.encode().is_err(), "encoder refuses arity mismatch too");
}

#[test]
fn hostile_submit_headers_are_typed_errors_not_panics() {
    // The classic multiply-overflow header: t_steps × inputs would wrap (or
    // demand an attacker-sized allocation). Must be a typed error.
    assert!(matches!(
        wire::sample_from_submit(u32::MAX, u32::MAX, &[]),
        Err(WireError::BadValue(_))
    ));
    // Fuzz the header space: no (t_steps, inputs, payload) triple panics,
    // and whenever the conversion succeeds the arity invariant holds.
    let mut rng = XorShift64Star::new(0x0EADBEEF);
    for _ in 0..5000 {
        let t_steps = rng.next_u64() as u32;
        let inputs = rng.next_u64() as u32;
        let payload: Vec<u8> = (0..rng.below(64)).map(|_| rng.next_u64() as u8).collect();
        if let Ok(s) = wire::sample_from_submit(t_steps, inputs, &payload) {
            assert_eq!(s.spikes.len(), t_steps as usize * inputs as usize);
        }
    }
}

#[test]
fn frame_stream_roundtrips_over_a_buffer() {
    let mut rng = XorShift64Star::new(0x57_12EA);
    let frames: Vec<Frame> = (0..64).map(|_| random_frame(&mut rng)).collect();
    let mut buf = Vec::new();
    for f in &frames {
        wire::write_frame(&mut buf, f).unwrap();
    }
    let mut r: &[u8] = &buf;
    let mut back = Vec::new();
    while let Some(f) = wire::read_frame(&mut r, DEFAULT_MAX_FRAME_LEN).unwrap() {
        back.push(f);
    }
    assert_eq!(frames, back);
}

#[test]
fn sample_conversion_roundtrips_real_datasets() {
    for (ds, i) in [(Dataset::Smnist, 0u64), (Dataset::Dvs, 3), (Dataset::Shd, 7)] {
        let s = ds.sample(i, Split::Test, 9);
        let frame = wire::submit_from_sample(5, i, &s);
        let Frame::SubmitSample { t_steps, inputs, ref spikes, .. } = frame else {
            panic!("submit_from_sample must build SubmitSample");
        };
        let back = wire::sample_from_submit(t_steps, inputs, spikes)
            .expect("well-formed submit headers convert");
        assert_eq!(back.spikes, s.spikes, "bit-packing must be lossless for {ds:?}");
        assert_eq!(back.t_steps, s.t_steps);
        assert_eq!(back.inputs, s.inputs);
    }
}
