//! The PR-10 memory-integrity acceptance gate: seeded single-event upsets
//! (SEUs) against parity- and SECDED-protected engines, differentially
//! checked against the sequential [`Core`] oracle.
//!
//! Matrix: three topologies x lane widths 1 and 64 x flip targets
//! {Weights, Vmem}, in both integrity modes:
//!
//! - **Correct** (SECDED): every injected flip is repaired in place by the
//!   boundary scrubber — all streams bit-exact, `corrected` equals the
//!   flip count, no shard is ever lost;
//! - **Detect** (parity): every injected flip costs exactly one shard
//!   session — the lost streams surface as typed resumable
//!   [`ServingError::ShardLost`], the supervisor quarantines and rebuilds
//!   from the checkpoint, survivors and resubmits are bit-exact, and
//!   `detected` equals the flip count.
//!
//! One flip per `run_batch_outcomes` round keeps the accounting exact in
//! both modes: a boundary scrub always lands between consecutive upsets to
//! the same shard (no XOR cancellation, no accumulated double-bit words),
//! and no flip is ever aimed at a shard that is already down.

use quantisenc::config::registers::RegisterFile;
use quantisenc::config::ModelConfig;
use quantisenc::coordinator::serving::chaos::{ChaosEvent, ChaosKind, ChaosSchedule};
use quantisenc::coordinator::serving::{ServingEngine, ServingError, ServingOptions, ShardHealth};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::datasets::Sample;
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::integrity::FlipTarget;
use quantisenc::hdl::{Core, IntegrityMode};

const CORES: usize = 2;
const FLIP_ROUNDS: usize = 4;

fn fixture(arch: &str, n: usize) -> (ModelConfig, Vec<Vec<i32>>, RegisterFile, Vec<Sample>) {
    let cfg = ModelConfig::parse_arch(arch, Q5_3).unwrap();
    let mut rng = XorShift64Star::new(0xA11E ^ arch.len() as u64);
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(15) as i32 - 7).collect())
        .collect();
    let regs = RegisterFile::new(cfg.qspec);
    let t_steps = 6;
    let samples: Vec<Sample> = (0..n as u64)
        .map(|i| {
            let mut srng = XorShift64Star::new(0x5EED ^ (i << 8) ^ arch.len() as u64);
            Sample {
                spikes: (0..t_steps * cfg.inputs()).map(|_| (srng.uniform() < 0.3) as u8).collect(),
                t_steps,
                inputs: cfg.inputs(),
                label: (i % 10) as usize,
            }
        })
        .collect();
    (cfg, weights, regs, samples)
}

fn oracle(cfg: &ModelConfig, weights: &[Vec<i32>], regs: &RegisterFile) -> Core {
    let mut core = Core::new(cfg.clone());
    core.load_weights(weights).unwrap();
    core.registers = regs.clone();
    core
}

fn build_engine(
    cfg: &ModelConfig,
    weights: &[Vec<i32>],
    regs: &RegisterFile,
    lane_width: usize,
    mode: IntegrityMode,
) -> ServingEngine {
    ServingEngine::new(
        cfg,
        weights,
        regs,
        ServingOptions::with_lanes(CORES, lane_width).checkpoints_every(8).with_integrity(mode),
    )
    .unwrap()
}

/// Arm one seeded upset for the round about to start: the admitted-sample
/// counter is read back so the event fires on the round's first admission,
/// ahead of the target shard's next boundary scrub. Targets alternate
/// between the synaptic store and the membrane bank, shards alternate too,
/// and the layer index sweeps the whole stack.
fn flip_round(
    engine: &mut ServingEngine,
    cfg: &ModelConfig,
    rng: &mut XorShift64Star,
    round: usize,
) {
    let (submitted, _) = engine.stats();
    let target = if round % 2 == 0 { FlipTarget::Weights } else { FlipTarget::Vmem };
    engine.install_chaos(ChaosSchedule::new(vec![ChaosEvent {
        at_sample: submitted + 1,
        shard: round % CORES,
        kind: ChaosKind::BitFlip {
            layer: round % cfg.num_layers(),
            target,
            word: rng.below(1 << 20) as usize,
            bit: rng.below(32) as u8,
        },
    }]));
}

fn run_correct(arch: &str, lane_width: usize) {
    let round = CORES * lane_width.max(12);
    let (cfg, weights, regs, samples) = fixture(arch, round * (FLIP_ROUNDS + 1));
    let mut core = oracle(&cfg, &weights, &regs);
    let mut engine = build_engine(&cfg, &weights, &regs, lane_width, IntegrityMode::Correct);
    let mut rng = XorShift64Star::new(0xC0DE ^ lane_width as u64 ^ arch.len() as u64);

    for r in 0..=FLIP_ROUNDS {
        if r < FLIP_ROUNDS {
            flip_round(&mut engine, &cfg, &mut rng, r);
        }
        let window = &samples[r * round..(r + 1) * round];
        let results = engine.run_batch(window).unwrap();
        for (j, res) in results.iter().enumerate() {
            let o = core.run(&window[j]);
            assert_eq!(res.counts, o.counts, "{arch} w{lane_width} round {r} stream {j} counts");
            assert_eq!(res.prediction, o.prediction, "{arch} w{lane_width} round {r} stream {j}");
        }
    }
    let (scrubbed, corrected, detected) = engine.integrity_counters();
    assert!(scrubbed > 0, "the boundary scrubber never ran");
    assert_eq!(corrected, FLIP_ROUNDS as u64, "every SECDED upset repaired in place");
    assert_eq!(detected, 0, "no upset may escape to detected-uncorrectable");
    assert_eq!(engine.quarantines(), 0, "Correct mode must not cost a shard");
    assert!(engine.shard_health().iter().all(|h| *h == ShardHealth::Healthy));
}

fn run_detect(arch: &str, lane_width: usize) {
    let round = CORES * lane_width.max(12);
    let (cfg, weights, regs, samples) = fixture(arch, round * (FLIP_ROUNDS + 1));
    let mut core = oracle(&cfg, &weights, &regs);
    let mut engine = build_engine(&cfg, &weights, &regs, lane_width, IntegrityMode::Detect);
    let mut rng = XorShift64Star::new(0xDE7EC7 ^ lane_width as u64 ^ arch.len() as u64);

    let mut lost: Vec<usize> = Vec::new();
    for r in 0..=FLIP_ROUNDS {
        if r < FLIP_ROUNDS {
            flip_round(&mut engine, &cfg, &mut rng, r);
        }
        let window = &samples[r * round..(r + 1) * round];
        let outcomes = engine.run_batch_outcomes(window).unwrap();
        let mut failed = 0usize;
        for (j, outcome) in outcomes.iter().enumerate() {
            let idx = r * round + j;
            match outcome {
                Ok(res) => {
                    let o = core.run(&samples[idx]);
                    assert_eq!(res.counts, o.counts, "{arch} w{lane_width} round {r} stream {j}");
                    assert_eq!(res.prediction, o.prediction, "{arch} w{lane_width} round {r}");
                }
                Err(ServingError::ShardLost { shard, resumable }) => {
                    assert!(*shard < CORES && *resumable, "typed resumable loss expected");
                    failed += 1;
                    lost.push(idx);
                }
                Err(other) => panic!("round {r} stream {j}: expected ShardLost, got {other:?}"),
            }
        }
        if r < FLIP_ROUNDS {
            assert!(failed > 0, "{arch} w{lane_width} round {r}: the upset cost no stream");
        } else {
            assert_eq!(failed, 0, "{arch} w{lane_width}: clean round lost a stream");
        }
        assert!(
            engine.shard_health().iter().all(|h| *h == ShardHealth::Healthy),
            "round {r}: supervisor must rebuild the flipped shard before returning"
        );
    }
    let (_, corrected, detected) = engine.integrity_counters();
    assert_eq!(corrected, 0, "parity cannot correct");
    assert_eq!(detected, FLIP_ROUNDS as u64, "every parity upset must be detected");
    assert_eq!(engine.quarantines(), FLIP_ROUNDS as u64, "one quarantine per upset");
    assert_eq!(engine.recoveries(), engine.quarantines(), "every quarantine must recover");

    // The resumable contract: exactly the lost streams, replayed on the
    // healed engine, come back bit-exact — and the replay itself is clean
    // (the rebuilt shard carries no residue of the flip).
    let resubmit: Vec<Sample> = lost.iter().map(|&i| samples[i].clone()).collect();
    let results = engine.run_batch(&resubmit).unwrap();
    for (res, &i) in results.iter().zip(&lost) {
        let o = core.run(&samples[i]);
        assert_eq!(res.counts, o.counts, "resubmitted stream {i} counts");
        assert_eq!(res.prediction, o.prediction, "resubmitted stream {i} prediction");
    }
    let (_, _, detected_after) = engine.integrity_counters();
    assert_eq!(detected_after, FLIP_ROUNDS as u64, "resubmit must run clean");
}

#[test]
fn seu_gate_16x20x10_lane_1() {
    run_correct("16x20x10", 1);
    run_detect("16x20x10", 1);
}

#[test]
fn seu_gate_16x20x10_lane_64() {
    run_correct("16x20x10", 64);
    run_detect("16x20x10", 64);
}

#[test]
fn seu_gate_24x16x10_lane_1() {
    run_correct("24x16x10", 1);
    run_detect("24x16x10", 1);
}

#[test]
fn seu_gate_24x16x10_lane_64() {
    run_correct("24x16x10", 64);
    run_detect("24x16x10", 64);
}

#[test]
fn seu_gate_32x24x12x10_lane_1() {
    run_correct("32x24x12x10", 1);
    run_detect("32x24x12x10", 1);
}

#[test]
fn seu_gate_32x24x12x10_lane_64() {
    run_correct("32x24x12x10", 64);
    run_detect("32x24x12x10", 64);
}
