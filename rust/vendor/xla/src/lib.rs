//! API stub of the `xla` PJRT bindings used by the `pjrt` feature.
//!
//! The offline image cannot link the real `xla` crate (it needs the
//! `xla_extension` native distribution), so this stub provides the exact
//! API surface the repo compiles against. Every runtime entry point
//! returns an error directing the user to link the real bindings: swap
//! this path dependency in the workspace `Cargo.toml` for the real crate
//! and rebuild with `--features pjrt` — no source change needed.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?`/`context`.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: this build links the vendored `xla` API stub; point the workspace's \
         `xla` path dependency at the real PJRT bindings (see README, \"PJRT runtime\") \
         to execute AOT HLO artifacts"
    )))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper around an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host literal (stub: construction is allowed so argument-marshalling code
/// compiles; device transfers fail).
#[derive(Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Compiled executable (stub: can never be constructed, methods satisfy
/// the type checker).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[3, 1]).is_ok());
        assert!(lit.to_vec::<i32>().is_err());
    }
}
