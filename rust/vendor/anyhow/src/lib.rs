//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! This container has no crates.io access, so the workspace ships the small
//! part of `anyhow` the repo actually uses: [`Error`] (a context chain of
//! messages), [`Result`], the [`Context`] extension trait for `Result` and
//! `Option`, and the `anyhow!` / `bail!` / `ensure!` macros. Formatting
//! follows upstream: `{}` prints the outermost message, `{:#}` prints the
//! whole chain joined by `": "`, and `{:?}` prints the message plus a
//! "Caused by" list.

use std::fmt;

/// `Result<T, anyhow::Error>` (the error type defaults like upstream).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error. `chain[0]` is the outermost context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// Iterate the chain from outermost to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(c)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Leaf;

    impl fmt::Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "leaf failure")
        }
    }

    impl std::error::Error for Leaf {}

    fn fails() -> Result<()> {
        Err(Leaf).context("outer context")
    }

    #[test]
    fn chain_formats() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "outer context");
        assert_eq!(format!("{e:#}"), "outer context: leaf failure");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros_work() {
        fn inner(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(inner(5).is_ok());
        assert!(format!("{:#}", inner(-1).unwrap_err()).contains("must be positive"));
        assert!(format!("{:#}", inner(11).unwrap_err()).contains("too big"));
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing value");
    }
}
