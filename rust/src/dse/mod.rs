//! Design-space exploration — paper Table IX (largest wide/deep
//! configuration per FPGA board) and the general "estimate without
//! synthesising" workflow the paper motivates in §VI-D.
//!
//! The explorer walks candidate architectures through the analytic
//! hardware models — [`crate::hwmodel::resources`] for LUT/FF/BRAM
//! occupancy against a [`crate::hwmodel::Board`]'s budget and
//! [`crate::hwmodel::power`] for the dynamic-power operating point — so a
//! design is sized in microseconds instead of a synthesis run. Two search
//! shapes reproduce Table IX: [`largest_wide`] (binary search over the
//! hidden width H of `in × H × out`) and [`largest_deep`] (deepest stack
//! of fixed-width hidden layers that still fits). The CLI exposes this as
//! `repro table 9`, and [`crate::experiments::dse_exp`] renders the
//! paper-facing table.

use crate::config::ModelConfig;
use crate::fixed::QSpec;
use crate::hwmodel::power;
use crate::hwmodel::resources;
use crate::hwmodel::Board;

/// A found design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub config: ModelConfig,
    pub resources: resources::Resources,
    /// Modelled dynamic power (W) at the baseline activity/operating point.
    pub power_w: f64,
}

fn point(config: ModelConfig) -> DesignPoint {
    let r = resources::core(&config);
    let p = power::core_dynamic_w(&config, power::RATE0, power::F0_HZ);
    DesignPoint { config, resources: r, power_w: p }
}

/// Largest **wide** design (single hidden layer `in × H × out`) that fits
/// the board — Table IX left half. Binary search over H.
pub fn largest_wide(
    board: &Board,
    inputs: usize,
    outputs: usize,
    qspec: QSpec,
) -> Option<DesignPoint> {
    let fits = |h: usize| -> Option<DesignPoint> {
        let cfg = ModelConfig::new(&[inputs, h, outputs], qspec).ok()?;
        let p = point(cfg);
        board.fits(&p.resources).then_some(p)
    };
    fits(1)?;
    let (mut lo, mut hi) = (1usize, 2usize);
    while fits(hi).is_some() {
        lo = hi;
        hi *= 2;
        if hi > 1 << 20 {
            break;
        }
    }
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if fits(mid).is_some() {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    fits(lo)
}

/// Largest **deep** design (`in × D·(width) × out`) that fits the board —
/// Table IX right half (the paper uses hidden width 64).
pub fn largest_deep(
    board: &Board,
    inputs: usize,
    outputs: usize,
    hidden_width: usize,
    qspec: QSpec,
) -> Option<DesignPoint> {
    let fits = |d: usize| -> Option<DesignPoint> {
        let mut sizes = Vec::with_capacity(d + 2);
        sizes.push(inputs);
        sizes.extend(std::iter::repeat(hidden_width).take(d));
        sizes.push(outputs);
        let cfg = ModelConfig::new(&sizes, qspec).ok()?;
        let p = point(cfg);
        board.fits(&p.resources).then_some(p)
    };
    fits(1)?;
    let mut d = 1usize;
    while fits(d + 1).is_some() {
        d += 1;
        if d > 4096 {
            break;
        }
    }
    fits(d)
}

/// Generic feasibility check + estimate for an arbitrary architecture —
/// the §VI-D "skip synthesis during DSE" workflow.
pub fn estimate(arch: &str, qspec: QSpec, board: &Board) -> anyhow::Result<(DesignPoint, bool)> {
    let cfg = ModelConfig::parse_arch(arch, qspec)?;
    let p = point(cfg);
    let fits = board.fits(&p.resources);
    Ok((p, fits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q5_3;
    use crate::hwmodel::boards;
    use crate::util::stats::rel_err;

    #[test]
    fn table9_wide_virtex_ultrascale() {
        // Paper: 256-1470-10 on Virtex UltraScale.
        let p = largest_wide(&boards::VIRTEX_ULTRASCALE, 256, 10, Q5_3).unwrap();
        let h = p.config.sizes()[1];
        assert!(rel_err(h as f64, 1470.0) < 0.05, "H = {h} (paper 1470)");
    }

    #[test]
    fn table9_wide_ordering_across_boards() {
        // More resources ⇒ wider maximum (paper: 1470 > 704 > 640).
        let hs: Vec<usize> = Board::all()
            .iter()
            .map(|b| largest_wide(b, 256, 10, Q5_3).unwrap().config.sizes()[1])
            .collect();
        assert!(hs[0] > hs[1] && hs[1] > hs[2], "{hs:?}");
    }

    #[test]
    fn table9_deep_ordering_across_boards() {
        let ds: Vec<usize> = Board::all()
            .iter()
            .map(|b| largest_deep(b, 256, 10, 64, Q5_3).unwrap().config.num_layers() - 1)
            .collect();
        assert!(ds[0] > ds[2], "Virtex US deeper than Zynq US: {ds:?}");
    }

    #[test]
    fn found_points_actually_fit_and_next_does_not() {
        let b = &boards::ZYNQ_ULTRASCALE;
        let p = largest_wide(b, 256, 10, Q5_3).unwrap();
        assert!(b.fits(&p.resources));
        let h = p.config.sizes()[1];
        let bigger = ModelConfig::new(&[256, h + 1, 10], Q5_3).unwrap();
        assert!(!b.fits(&resources::core(&bigger)), "H={h} not maximal");
    }

    #[test]
    fn estimate_reports_fit() {
        let (p, fits) = estimate("256x128x10", Q5_3, &boards::VIRTEX_ULTRASCALE).unwrap();
        assert!(fits);
        assert!(p.power_w > 0.0);
        let (_, fits2) = estimate("256x9999x10", Q5_3, &boards::ZYNQ_ULTRASCALE).unwrap();
        assert!(!fits2);
    }
}
