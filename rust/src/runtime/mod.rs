//! Runtime layer — artifact loading plus (behind the `pjrt` feature) the
//! PJRT executor for the AOT HLO artifacts.
//!
//! * [`artifacts`] — manifest parsing, weight-file loading, golden vectors.
//!   Always available; the native substrate in [`crate::golden`] can
//!   regenerate every artifact the manifest describes without Python.
//! * `Runtime` / `ModelExecutable` (feature `pjrt`) — loads the AOT HLO
//!   text produced by `python/compile/aot.py` and executes it on the PJRT
//!   CPU client. Off by default so the stock build carries zero XLA
//!   dependencies; the workspace ships a vendored API stub, and pointing
//!   the `xla` path dependency at the real bindings enables execution.
//!
//! HLO is shipped as **text** (never a serialized proto — jax ≥ 0.5 emits
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids).

pub mod artifacts;

#[cfg(feature = "pjrt")]
mod pjrt_runtime {
    use anyhow::{Context, Result};
    use std::path::Path;

    use crate::config::registers::NUM_REGS;
    use crate::runtime::artifacts;

    /// Shared PJRT CPU client (one per process; compilation is cached per
    /// executable, mirroring "one compiled executable per model variant").
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            Ok(Runtime { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text file.
        pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
            // The XLA loader wants a &str path; a non-UTF-8 path is a typed
            // artifact error, not a panic.
            let path_str = path.to_str().ok_or_else(|| {
                artifacts::ArtifactsError::NonUtf8Path { path: path.to_path_buf() }
            })?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))
        }

        /// Load + compile a dataset forward artifact described by the manifest.
        pub fn load_model(&self, art: &artifacts::ModelArtifact) -> Result<ModelExecutable> {
            let exe = self.compile_hlo_file(&art.hlo_path)?;
            Ok(ModelExecutable {
                exe,
                t_steps: art.t_steps,
                inputs: art.layer_shapes[0].0,
                layer_shapes: art.layer_shapes.clone(),
                weights: art.weights.clone(),
                regs: art.default_regs,
            })
        }
    }

    /// A compiled dataset forward: `(spikes [T,N_in], W_1..W_K, regs[6]) ->
    /// (counts [n_out], layer_spike_totals [K])`.
    pub struct ModelExecutable {
        exe: xla::PjRtLoadedExecutable,
        pub t_steps: usize,
        pub inputs: usize,
        pub layer_shapes: Vec<(usize, usize)>,
        /// Currently-programmed weights (dense row-major per layer) — the wt_in
        /// state. Mutable at run time, exactly like the hardware's synaptic
        /// memory.
        pub weights: Vec<Vec<i32>>,
        /// Currently-programmed control registers — the cfg_in state.
        pub regs: [i32; NUM_REGS],
    }

    /// Inference result from the PJRT path.
    #[derive(Debug, Clone)]
    pub struct PjrtRun {
        pub counts: Vec<i32>,
        pub layer_spikes: Vec<i32>,
        pub prediction: usize,
    }

    impl ModelExecutable {
        /// Execute one sample (spike train as row-major [T × N_in] 0/1 bytes).
        pub fn run(&self, spikes: &[u8]) -> Result<PjrtRun> {
            anyhow::ensure!(
                spikes.len() == self.t_steps * self.inputs,
                "spike train shape mismatch: got {}, expected {}x{}",
                spikes.len(),
                self.t_steps,
                self.inputs
            );
            let spikes_i32: Vec<i32> = spikes.iter().map(|&x| x as i32).collect();
            let mut args: Vec<xla::Literal> = Vec::with_capacity(2 + self.weights.len());
            args.push(
                xla::Literal::vec1(&spikes_i32)
                    .reshape(&[self.t_steps as i64, self.inputs as i64])?,
            );
            for (w, &(m, n)) in self.weights.iter().zip(&self.layer_shapes) {
                args.push(xla::Literal::vec1(w).reshape(&[m as i64, n as i64])?);
            }
            let regs: Vec<i32> = self.regs.to_vec();
            args.push(xla::Literal::vec1(&regs));

            let arg_refs: Vec<&xla::Literal> = args.iter().collect();
            let result = self.exe.execute::<&xla::Literal>(&arg_refs)?[0][0].to_literal_sync()?;
            // Lowered with return_tuple=True: (counts, layer_spike_totals).
            let counts_lit = result.to_tuple()?;
            anyhow::ensure!(
                counts_lit.len() == 2,
                "expected 2-tuple output, got {}",
                counts_lit.len()
            );
            let counts = counts_lit[0].to_vec::<i32>()?;
            let layer_spikes = counts_lit[1].to_vec::<i32>()?;
            let mut prediction = 0;
            for (i, &c) in counts.iter().enumerate() {
                if c > counts[prediction] {
                    prediction = i;
                }
            }
            Ok(PjrtRun { counts, layer_spikes, prediction })
        }

        /// cfg_in: program the control-register vector.
        pub fn program_regs(&mut self, regs: [i32; NUM_REGS]) {
            self.regs = regs;
        }

        /// wt_in: program a single synaptic weight (per-weight addressing).
        pub fn program_weight(
            &mut self,
            layer: usize,
            pre: usize,
            post: usize,
            w: i32,
        ) -> Result<()> {
            let (m, n) = *self
                .layer_shapes
                .get(layer)
                .with_context(|| format!("layer {layer} out of range"))?;
            anyhow::ensure!(pre < m && post < n, "weight address ({pre},{post}) out of {m}x{n}");
            self.weights[layer][pre * n + post] = w;
            Ok(())
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_runtime::{ModelExecutable, PjrtRun, Runtime};

// PJRT-dependent tests live in rust/tests/integration_runtime.rs (gated on
// the `pjrt` feature in Cargo.toml) because they need the built artifacts.
