//! Artifact manifest + weight/golden file loading.
//!
//! `make artifacts` (the one-time Python build path) writes
//! `artifacts/manifest.json` describing every lowered model variant; this
//! module parses it and loads the binary weight files so the request path
//! never touches Python.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

use crate::config::registers::NUM_REGS;
use crate::util::json::Json;

/// One deployable model variant (dataset × quantization).
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    pub dataset: String,
    pub qname: String,
    pub sizes: Vec<usize>,
    pub t_steps: usize,
    pub hlo_path: PathBuf,
    pub layer_shapes: Vec<(usize, usize)>,
    /// Dense row-major per-layer quantized weights from the .bin file.
    /// The dense `[M × N]` layout is the on-disk contract for every
    /// topology; `hdl::SynapticMemory::load_dense` scatters it into the
    /// topology-aware (banded/diagonal) store at deploy time.
    pub weights: Vec<Vec<i32>>,
    pub default_regs: [i32; NUM_REGS],
    /// Float ("software") accuracy recorded at training time.
    pub float_acc: f64,
}

/// Typed artifact-store failures, each carrying its own actionable message
/// (so a missing store reports the fix instead of surfacing as a test-time
/// panic): `Missing` means nobody has built the artifacts yet, `Unreadable`
/// means the store exists but could not be read (the I/O error is
/// preserved), `Corrupt` means `manifest.json` is not valid JSON.
#[derive(Debug)]
pub enum ArtifactsError {
    /// `manifest.json` is absent from the artifacts directory.
    Missing { dir: PathBuf },
    /// `manifest.json` exists but reading it failed (permissions, I/O).
    Unreadable { path: PathBuf, detail: String },
    /// `manifest.json` exists but is not valid JSON.
    Corrupt { path: PathBuf, detail: String },
    /// An artifact path is not valid UTF-8 but a consumer (the XLA text
    /// loader) requires a `&str` path — surfaced as a typed error instead
    /// of a `to_str().unwrap()` panic.
    NonUtf8Path { path: PathBuf },
}

impl std::fmt::Display for ArtifactsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactsError::Missing { dir } => write!(
                f,
                "artifacts missing: no manifest.json in {} — run `make artifacts` \
                 (or call quantisenc::golden::ensure_artifacts()) first",
                dir.display()
            ),
            ArtifactsError::Unreadable { path, detail } => {
                write!(f, "artifacts unreadable: {}: {detail}", path.display())
            }
            ArtifactsError::Corrupt { path, detail } => {
                write!(f, "artifacts corrupt: {} does not parse: {detail}", path.display())
            }
            ArtifactsError::NonUtf8Path { path } => {
                write!(f, "artifact path {} is not valid UTF-8", path.display())
            }
        }
    }
}

impl std::error::Error for ArtifactsError {}

/// Parsed manifest (the index of everything the build path produced).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    json: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(ArtifactsError::Missing { dir: dir.to_path_buf() }.into())
            }
            Err(e) => {
                return Err(ArtifactsError::Unreadable { path, detail: e.to_string() }.into())
            }
        };
        let json = Json::parse(&text)
            .map_err(|e| ArtifactsError::Corrupt { path: path.clone(), detail: e.to_string() })?;
        Ok(Manifest { root: dir.to_path_buf(), json })
    }

    pub fn datasets(&self) -> Vec<String> {
        self.json
            .get("models")
            .and_then(|m| m.as_obj())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    pub fn variants(&self, dataset: &str) -> Result<Vec<String>> {
        let v = self
            .json
            .req("models")?
            .req(dataset)?
            .req("variants")?
            .as_obj()
            .context("variants not an object")?;
        Ok(v.keys().cloned().collect())
    }

    /// Load one model variant, including its weight file.
    pub fn model(&self, dataset: &str, qname: &str) -> Result<ModelArtifact> {
        let entry = self.json.req("models")?.req(dataset)?;
        let sizes: Vec<usize> =
            entry.req("sizes")?.i32_vec()?.into_iter().map(|x| x as usize).collect();
        let t_steps = entry.req("t_steps")?.as_i64().context("t_steps")? as usize;
        let float_acc = entry.req("float_acc")?.as_f64().unwrap_or(0.0);
        let var = entry.req("variants")?.req(qname)?;

        let hlo_path = self.root.join(var.req("hlo")?.as_str().context("hlo")?);
        let layer_shapes: Vec<(usize, usize)> = var
            .req("layer_shapes")?
            .as_arr()
            .context("layer_shapes")?
            .iter()
            .map(|s| {
                let v = s.i32_vec()?;
                anyhow::ensure!(v.len() == 2, "layer shape arity");
                Ok((v[0] as usize, v[1] as usize))
            })
            .collect::<Result<_>>()?;

        let regs_v = var.req("default_regs")?.i32_vec()?;
        anyhow::ensure!(regs_v.len() == NUM_REGS, "register vector arity");
        let mut default_regs = [0i32; NUM_REGS];
        default_regs.copy_from_slice(&regs_v);

        let wpath = self.root.join(var.req("weights")?.as_str().context("weights")?);
        let weights = load_weight_file(&wpath, &layer_shapes)?;

        Ok(ModelArtifact {
            dataset: dataset.to_string(),
            qname: qname.to_string(),
            sizes,
            t_steps,
            hlo_path,
            layer_shapes,
            weights,
            default_regs,
            float_acc,
        })
    }

    pub fn kernels(&self) -> Vec<String> {
        self.json
            .get("kernels")
            .and_then(|m| m.as_obj())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    pub fn kernel_hlo_path(&self, name: &str) -> Result<PathBuf> {
        let f = self.json.req("kernels")?.req(name)?.req("file")?;
        Ok(self.root.join(f.as_str().context("kernel file")?))
    }

    /// Parse a golden-vector JSON file from the artifacts directory.
    pub fn golden(&self, name: &str) -> Result<Json> {
        let path = self.root.join(name);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading golden {}", path.display()))?;
        Ok(Json::parse(&text)?)
    }
}

/// Flat little-endian i32 weight file → per-layer dense matrices.
pub fn load_weight_file(path: &Path, layer_shapes: &[(usize, usize)]) -> Result<Vec<Vec<i32>>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading weights {}", path.display()))?;
    let total: usize = layer_shapes.iter().map(|(m, n)| m * n).sum();
    anyhow::ensure!(
        bytes.len() == total * 4,
        "weight file {} has {} bytes, expected {}",
        path.display(),
        bytes.len(),
        total * 4
    );
    let flat: Vec<i32> = bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let mut out = Vec::with_capacity(layer_shapes.len());
    let mut off = 0;
    for &(m, n) in layer_shapes {
        out.push(flat[off..off + m * n].to_vec());
        off += m * n;
    }
    Ok(out)
}

/// Float32 weight file (the "software" reference weights).
pub fn load_float_weight_file(path: &Path, layer_shapes: &[(usize, usize)]) -> Result<Vec<Vec<f32>>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading weights {}", path.display()))?;
    let total: usize = layer_shapes.iter().map(|(m, n)| m * n).sum();
    anyhow::ensure!(bytes.len() == total * 4, "float weight file size mismatch");
    let flat: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let mut out = Vec::with_capacity(layer_shapes.len());
    let mut off = 0;
    for &(m, n) in layer_shapes {
        out.push(flat[off..off + m * n].to_vec());
        off += m * n;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_file_roundtrip() {
        let dir = std::env::temp_dir().join("q_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let vals: Vec<i32> = vec![1, -2, 3, -4, 5, 6, 7, -8, 9, 10];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let w = load_weight_file(&path, &[(2, 2), (2, 3)]).unwrap();
        assert_eq!(w[0], vec![1, -2, 3, -4]);
        assert_eq!(w[1], vec![5, 6, 7, -8, 9, 10]);
        // wrong shape errors
        assert!(load_weight_file(&path, &[(3, 3)]).is_err());
    }

    #[test]
    fn missing_manifest_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
