//! Bit-packed spike planes — the event-driven wire format of the hot path.
//!
//! A [`SpikePlane`] is one timestep's spike vector packed one bit per
//! pre-synaptic line into `u64` words (line `i` is bit `i % 64` of word
//! `i / 64`). This is the software mirror of what makes QUANTISENC fast in
//! hardware: the design clock-gates every synaptic row with no input spike
//! (§VI-E), so per step the ActGen only *does work* proportional to the
//! number of firing rows. With a packed plane the simulator walks exactly
//! those rows via [`u64::trailing_zeros`] — O(popcount) iteration instead
//! of an O(M) branch-per-row scan — and the gating ledger is charged in
//! bulk from a precomputed per-row synapse prefix sum
//! (see [`crate::hdl::Layer::step_plane`]).
//!
//! Planes are also the unit of **buffer recycling** on the serving path:
//! [`PlanePool`] is a shared free-list the engine pre-fills at construction
//! so the steady-state streaming path performs zero plane allocations
//! (asserted in debug builds by
//! [`crate::coordinator::serving::ServingEngine`]). A recycled plane keeps
//! its word allocation across [`SpikePlane::load_bytes`]/
//! [`SpikePlane::resize_clear`] calls of any width it has already seen.
//!
//! Invariant: bits at positions `>= len` are always zero, so derived
//! equality, [`SpikePlane::count_ones`], and word-level consumers never see
//! ghost spikes in the tail word.
//!
//! # Lane batching
//!
//! [`SpikeMatrix`] is the transpose of up to 64 pooled planes: one `u64`
//! **lane-word per pre-synaptic line**, bit `l` of line `i`'s word saying
//! "sample (lane) `l` fired line `i` this timestep". This is the wire
//! format of the lane-batched datapath
//! ([`crate::hdl::Layer::step_lanes`]): walking the lines whose lane-word
//! is nonzero lets the ActGen fetch each synaptic row from the topology
//! store **once** and scatter it into every active lane via
//! `trailing_zeros`, amortizing weight-memory traffic across the whole
//! batch — the software analogue of QUANTISENC streaming many samples
//! through one synaptic memory read port. [`MatrixPool`] mirrors
//! [`PlanePool`] for the batched serving path's recycled buffers.
//!
//! Invariant (mirroring the plane tail rule): bits at lane positions
//! `>= lanes` are zero in every line word.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Words needed to hold `lines` one-bit lanes.
#[inline]
const fn words_for(lines: usize) -> usize {
    lines.div_ceil(64)
}

/// One timestep's spike vector, bit-packed (one `u64` word per 64 lines).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SpikePlane {
    words: Vec<u64>,
    len: usize,
}

impl SpikePlane {
    /// An all-zero plane of `len` lines.
    pub fn new(len: usize) -> SpikePlane {
        SpikePlane { words: vec![0; words_for(len)], len }
    }

    /// An empty plane whose word storage can hold `lines` lines without
    /// reallocating — what pools pre-fill with.
    pub fn with_line_capacity(lines: usize) -> SpikePlane {
        SpikePlane { words: Vec::with_capacity(words_for(lines)), len: 0 }
    }

    /// Number of lines (bits) in the plane.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed word view (tail bits beyond `len` are zero by invariant).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Set the plane to `len` all-zero lines, reusing the existing word
    /// allocation (no allocation once the plane has seen this width).
    pub fn resize_clear(&mut self, len: usize) {
        self.words.clear();
        self.words.resize(words_for(len), 0);
        self.len = len;
    }

    /// Mark line `i` as firing. Out-of-range lines are rejected (a silent
    /// tail-word write would break the ghost-bit invariant).
    #[inline]
    pub fn set(&mut self, i: usize) {
        assert!(i < self.len, "line {i} out of range for plane of {}", self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Whether line `i` fired.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.len, "line {i} out of range for plane of {}", self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Number of firing lines (popcount over the packed words).
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate the firing line indices in ascending order. Each word is
    /// consumed with `trailing_zeros` / clear-lowest-set, so a sparse plane
    /// costs O(popcount + len/64), not O(len).
    pub fn iter_ones(&self) -> Ones<'_> {
        Ones { words: &self.words, word_pos: 0, cur: 0, cur_base: 0 }
    }

    /// Pack a dense byte vector (any non-zero byte = spike) into this
    /// plane, reusing the word allocation.
    pub fn load_bytes(&mut self, bytes: &[u8]) {
        self.resize_clear(bytes.len());
        for (wi, chunk) in bytes.chunks(64).enumerate() {
            let mut w = 0u64;
            for (bi, &b) in chunk.iter().enumerate() {
                w |= ((b != 0) as u64) << bi;
            }
            self.words[wi] = w;
        }
    }

    /// A fresh plane packed from a dense byte vector.
    pub fn from_bytes(bytes: &[u8]) -> SpikePlane {
        let mut p = SpikePlane::default();
        p.load_bytes(bytes);
        p
    }

    /// Append the dense 0/1 byte expansion of this plane to `out`.
    pub fn append_bytes_to(&self, out: &mut Vec<u8>) {
        out.reserve(self.len);
        for (wi, &w) in self.words.iter().enumerate() {
            let lanes = (self.len - wi * 64).min(64);
            for bit in 0..lanes {
                out.push(((w >> bit) & 1) as u8);
            }
        }
    }

    /// The dense 0/1 byte expansion (allocating; adapters and tests).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len);
        self.append_bytes_to(&mut out);
        out
    }

    /// Become a copy of `other`, reusing this plane's word allocation.
    pub fn copy_from(&mut self, other: &SpikePlane) {
        self.words.clear();
        self.words.extend_from_slice(&other.words);
        self.len = other.len;
    }
}

/// Iterator over a plane's firing line indices (see
/// [`SpikePlane::iter_ones`]).
pub struct Ones<'a> {
    words: &'a [u64],
    word_pos: usize,
    cur: u64,
    cur_base: usize,
}

impl Iterator for Ones<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        while self.cur == 0 {
            if self.word_pos == self.words.len() {
                return None;
            }
            self.cur = self.words[self.word_pos];
            self.cur_base = self.word_pos * 64;
            self.word_pos += 1;
        }
        let t = self.cur.trailing_zeros() as usize;
        self.cur &= self.cur - 1; // clear lowest set bit
        Some(self.cur_base + t)
    }
}

/// One timestep's spikes for up to 64 concurrent samples: a transposed
/// stack of [`SpikePlane`]s with one `u64` **lane-word per line** (bit `l`
/// of line `i`'s word = lane `l` fired line `i`). See the module docs for
/// why this layout amortizes synaptic-row fetches across the batch.
///
/// Invariant: bits at lane positions `>= lanes` are zero in every word.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SpikeMatrix {
    /// `words[i]` is line `i`'s lane-word.
    words: Vec<u64>,
    lines: usize,
    lanes: usize,
}

impl SpikeMatrix {
    /// An all-zero matrix of `lines` lines × `lanes` lanes (`lanes` ≤ 64).
    pub fn new(lines: usize, lanes: usize) -> SpikeMatrix {
        let mut m = SpikeMatrix::default();
        m.resize_clear(lines, lanes);
        m
    }

    /// An empty matrix whose word storage can hold `lines` lines without
    /// reallocating — what pools pre-fill with.
    pub fn with_line_capacity(lines: usize) -> SpikeMatrix {
        SpikeMatrix { words: Vec::with_capacity(lines), lines: 0, lanes: 0 }
    }

    pub fn lines(&self) -> usize {
        self.lines
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Mask with one bit set per lane (`lanes` low bits).
    #[inline]
    pub fn lane_mask(&self) -> u64 {
        lane_mask(self.lanes)
    }

    /// Set the matrix to `lines` all-zero lines of `lanes` lanes, reusing
    /// the word allocation (no allocation once the matrix has seen this
    /// line count).
    pub fn resize_clear(&mut self, lines: usize, lanes: usize) {
        assert!(lanes <= 64, "lane width {lanes} exceeds the 64-bit lane word");
        self.words.clear();
        self.words.resize(lines, 0);
        self.lines = lines;
        self.lanes = lanes;
    }

    /// The per-line lane-words (tail lane bits are zero by invariant).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Line `i`'s lane-word.
    #[inline]
    pub fn line_word(&self, i: usize) -> u64 {
        self.words[i]
    }

    /// Overwrite line `i`'s lane-word (bits `>= lanes` must be clear).
    #[inline]
    pub fn set_line_word(&mut self, i: usize, word: u64) {
        debug_assert_eq!(word & !self.lane_mask(), 0, "ghost lane bits in line {i}");
        self.words[i] = word;
    }

    /// Mark (line, lane) as firing.
    #[inline]
    pub fn set(&mut self, line: usize, lane: usize) {
        assert!(line < self.lines && lane < self.lanes, "({line},{lane}) out of range");
        self.words[line] |= 1u64 << lane;
    }

    /// Whether (line, lane) fired.
    #[inline]
    pub fn get(&self, line: usize, lane: usize) -> bool {
        assert!(line < self.lines && lane < self.lanes, "({line},{lane}) out of range");
        (self.words[line] >> lane) & 1 == 1
    }

    /// Total spikes across all lines and lanes.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Transpose one plane into lane `lane` (OR-in; the matrix must have
    /// been `resize_clear`ed to this plane's length first).
    pub fn set_lane_from_plane(&mut self, lane: usize, plane: &SpikePlane) {
        assert!(lane < self.lanes, "lane {lane} out of range for {} lanes", self.lanes);
        assert_eq!(plane.len(), self.lines, "plane length != matrix lines");
        let bit = 1u64 << lane;
        for i in plane.iter_ones() {
            self.words[i] |= bit;
        }
    }

    /// Pack a dense byte vector (any non-zero byte = spike) into lane
    /// `lane` (OR-in) — the serving feeder's zero-copy lane encoder.
    pub fn load_lane_bytes(&mut self, lane: usize, bytes: &[u8]) {
        assert!(lane < self.lanes, "lane {lane} out of range for {} lanes", self.lanes);
        assert_eq!(bytes.len(), self.lines, "byte length != matrix lines");
        let bit = 1u64 << lane;
        for (w, &b) in self.words.iter_mut().zip(bytes) {
            if b != 0 {
                *w |= bit;
            }
        }
    }

    /// Gather lane `lane` back out as a bit-packed plane (the demux
    /// inverse of [`SpikeMatrix::set_lane_from_plane`]), reusing `out`'s
    /// allocation.
    pub fn lane_plane_into(&self, lane: usize, out: &mut SpikePlane) {
        assert!(lane < self.lanes, "lane {lane} out of range for {} lanes", self.lanes);
        out.resize_clear(self.lines);
        for (i, &w) in self.words.iter().enumerate() {
            if (w >> lane) & 1 == 1 {
                out.set(i);
            }
        }
    }

    /// Become a copy of `other`, reusing this matrix's word allocation.
    pub fn copy_from(&mut self, other: &SpikeMatrix) {
        self.words.clear();
        self.words.extend_from_slice(&other.words);
        self.lines = other.lines;
        self.lanes = other.lanes;
    }
}

/// Mask with the `lanes` low bits set.
#[inline]
pub const fn lane_mask(lanes: usize) -> u64 {
    if lanes >= 64 {
        u64::MAX
    } else {
        (1u64 << lanes) - 1
    }
}

/// Thread-safe free-list of recycled [`SpikeMatrix`] buffers — the
/// lane-batched serving path's mirror of [`PlanePool`], with the same
/// pre-fill / zero-steady-state-allocation contract (each dry-pool
/// fallback allocation is counted in [`MatrixPool::misses`]).
#[derive(Debug, Default)]
pub struct MatrixPool {
    free: Mutex<Vec<SpikeMatrix>>,
    misses: AtomicU64,
}

impl MatrixPool {
    /// An empty pool: every `take` until the first `put` is a (counted)
    /// allocation.
    pub fn new() -> MatrixPool {
        MatrixPool::default()
    }

    /// A pool pre-filled with `count` matrices whose word storage already
    /// covers `line_capacity` lines.
    pub fn prefilled(count: usize, line_capacity: usize) -> MatrixPool {
        let free = (0..count).map(|_| SpikeMatrix::with_line_capacity(line_capacity)).collect();
        MatrixPool { free: Mutex::new(free), misses: AtomicU64::new(0) }
    }

    /// Pop a recycled matrix, or allocate (and count a miss) if the pool
    /// is dry. The returned matrix has unspecified contents —
    /// `resize_clear` it before use.
    pub fn take(&self) -> SpikeMatrix {
        if let Some(m) = self.free.lock().unwrap().pop() {
            return m;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        SpikeMatrix::default()
    }

    /// Return a matrix to the free list.
    pub fn put(&self, matrix: SpikeMatrix) {
        self.free.lock().unwrap().push(matrix);
    }

    /// Matrices currently resting in the free list.
    pub fn available(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Times `take` found the pool dry and had to allocate.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// Thread-safe free-list of recycled [`SpikePlane`] buffers.
///
/// The serving engine pre-fills one pool per engine with enough planes to
/// cover its maximum in-flight footprint (every bounded-channel slot plus
/// every stage's in-hand planes), so [`PlanePool::take`] never has to
/// allocate in steady state; each fallback allocation is counted in
/// [`PlanePool::misses`], which is what the engine's zero-alloc
/// debug-assert checks.
#[derive(Debug, Default)]
pub struct PlanePool {
    free: Mutex<Vec<SpikePlane>>,
    misses: AtomicU64,
}

impl PlanePool {
    /// An empty pool: every `take` until the first `put` is a (counted)
    /// allocation. Used by one-shot executors that don't pre-size.
    pub fn new() -> PlanePool {
        PlanePool::default()
    }

    /// A pool pre-filled with `count` planes whose word storage already
    /// covers `line_capacity` lines.
    pub fn prefilled(count: usize, line_capacity: usize) -> PlanePool {
        let free = (0..count).map(|_| SpikePlane::with_line_capacity(line_capacity)).collect();
        PlanePool { free: Mutex::new(free), misses: AtomicU64::new(0) }
    }

    /// Pop a recycled plane, or allocate (and count a miss) if the pool is
    /// dry. The returned plane has unspecified contents — load or
    /// `resize_clear` it before use.
    pub fn take(&self) -> SpikePlane {
        if let Some(p) = self.free.lock().unwrap().pop() {
            return p;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        SpikePlane::default()
    }

    /// Return a plane to the free list.
    pub fn put(&self, plane: SpikePlane) {
        self.free.lock().unwrap().push(plane);
    }

    /// Planes currently resting in the free list.
    pub fn available(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Times `take` found the pool dry and had to allocate.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_count() {
        let mut p = SpikePlane::new(130);
        assert_eq!(p.len(), 130);
        assert_eq!(p.count_ones(), 0);
        for i in [0usize, 63, 64, 127, 129] {
            p.set(i);
            assert!(p.get(i));
        }
        assert_eq!(p.count_ones(), 5);
        assert!(!p.get(1));
        assert_eq!(p.iter_ones().collect::<Vec<_>>(), vec![0, 63, 64, 127, 129]);
    }

    #[test]
    fn bytes_roundtrip_and_nonbinary_bytes() {
        let bytes = vec![0u8, 1, 0, 2, 255, 0, 1];
        let p = SpikePlane::from_bytes(&bytes);
        assert_eq!(p.len(), 7);
        assert_eq!(p.count_ones(), 4); // any non-zero byte is a spike
        assert_eq!(p.to_bytes(), vec![0, 1, 0, 1, 1, 0, 1]);
    }

    #[test]
    fn empty_and_word_boundary_planes() {
        assert_eq!(SpikePlane::new(0).to_bytes(), Vec::<u8>::new());
        assert_eq!(SpikePlane::new(0).iter_ones().count(), 0);
        for len in [63usize, 64, 65, 128] {
            let bytes = vec![1u8; len];
            let p = SpikePlane::from_bytes(&bytes);
            assert_eq!(p.count_ones(), len);
            assert_eq!(p.iter_ones().collect::<Vec<_>>(), (0..len).collect::<Vec<_>>());
            assert_eq!(p.to_bytes(), bytes);
        }
    }

    #[test]
    fn recycling_keeps_tail_invariant() {
        // A plane that held a wide all-ones vector must not leak ghost
        // spikes when recycled for a narrower one.
        let mut p = SpikePlane::from_bytes(&vec![1u8; 200]);
        p.load_bytes(&[0, 1, 0]);
        assert_eq!(p.len(), 3);
        assert_eq!(p.count_ones(), 1);
        assert_eq!(p.iter_ones().collect::<Vec<_>>(), vec![1]);
        p.resize_clear(100);
        assert_eq!(p.count_ones(), 0);
    }

    #[test]
    fn copy_from_matches_clone() {
        let a = SpikePlane::from_bytes(&[1, 0, 1, 1, 0]);
        let mut b = SpikePlane::from_bytes(&vec![1u8; 90]);
        b.copy_from(&a);
        assert_eq!(a, b);
    }

    #[test]
    fn matrix_set_get_and_lane_words() {
        let mut m = SpikeMatrix::new(5, 3);
        assert_eq!((m.lines(), m.lanes()), (5, 3));
        assert_eq!(m.lane_mask(), 0b111);
        m.set(0, 0);
        m.set(0, 2);
        m.set(4, 1);
        assert_eq!(m.line_word(0), 0b101);
        assert_eq!(m.line_word(4), 0b010);
        assert!(m.get(0, 2) && !m.get(0, 1));
        assert_eq!(m.count_ones(), 3);
        assert_eq!(lane_mask(64), u64::MAX);
        assert_eq!(lane_mask(1), 1);
    }

    #[test]
    fn matrix_transposes_planes_and_demuxes_back() {
        // L planes in, transpose to lane-words, gather each lane back out:
        // a lossless round-trip including the 64-lane full-word case.
        for lanes in [1usize, 3, 64] {
            let lines = 130;
            let planes: Vec<SpikePlane> = (0..lanes)
                .map(|l| {
                    let bytes: Vec<u8> =
                        (0..lines).map(|i| ((i * 7 + l * 13) % 5 == 0) as u8).collect();
                    SpikePlane::from_bytes(&bytes)
                })
                .collect();
            let mut m = SpikeMatrix::new(lines, lanes);
            for (l, p) in planes.iter().enumerate() {
                m.set_lane_from_plane(l, p);
            }
            let total: usize = planes.iter().map(|p| p.count_ones()).sum();
            assert_eq!(m.count_ones(), total, "lanes={lanes}");
            let mut back = SpikePlane::default();
            for (l, p) in planes.iter().enumerate() {
                m.lane_plane_into(l, &mut back);
                assert_eq!(&back, p, "lane {l} of {lanes}");
            }
            // Per-line words agree with a bit-by-bit gather.
            for i in 0..lines {
                let mut want = 0u64;
                for (l, p) in planes.iter().enumerate() {
                    want |= (p.get(i) as u64) << l;
                }
                assert_eq!(m.line_word(i), want, "line {i}");
            }
        }
    }

    #[test]
    fn matrix_recycle_clears_previous_contents() {
        let mut m = SpikeMatrix::new(100, 64);
        for i in 0..100 {
            m.set_line_word(i, u64::MAX);
        }
        m.resize_clear(40, 5);
        assert_eq!((m.lines(), m.lanes()), (40, 5));
        assert_eq!(m.count_ones(), 0);
        m.load_lane_bytes(4, &[1; 40]);
        assert_eq!(m.count_ones(), 40);
        assert_eq!(m.line_word(0), 0b10000);
    }

    #[test]
    fn matrix_copy_from_matches_clone() {
        let mut a = SpikeMatrix::new(9, 7);
        a.set(3, 2);
        a.set(8, 6);
        let mut b = SpikeMatrix::new(200, 64);
        b.copy_from(&a);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn matrix_rejects_out_of_range_lane() {
        let mut m = SpikeMatrix::new(4, 2);
        m.set(0, 2);
    }

    #[test]
    fn matrix_pool_recycles_and_counts_misses() {
        let pool = MatrixPool::prefilled(1, 256);
        let a = pool.take();
        assert_eq!(pool.misses(), 0);
        let b = pool.take(); // dry: allocates
        assert_eq!(pool.misses(), 1);
        pool.put(a);
        pool.put(b);
        assert_eq!(pool.available(), 2);
        let _ = pool.take();
        assert_eq!(pool.misses(), 1);
    }

    #[test]
    fn pool_recycles_and_counts_misses() {
        let pool = PlanePool::prefilled(2, 128);
        assert_eq!(pool.available(), 2);
        let a = pool.take();
        let b = pool.take();
        assert_eq!(pool.misses(), 0);
        let c = pool.take(); // dry: allocates
        assert_eq!(pool.misses(), 1);
        pool.put(a);
        pool.put(b);
        pool.put(c);
        assert_eq!(pool.available(), 3);
        let _ = pool.take();
        assert_eq!(pool.misses(), 1);
    }
}
