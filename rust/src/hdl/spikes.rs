//! Bit-packed spike planes — the event-driven wire format of the hot path.
//!
//! A [`SpikePlane`] is one timestep's spike vector packed one bit per
//! pre-synaptic line into `u64` words (line `i` is bit `i % 64` of word
//! `i / 64`). This is the software mirror of what makes QUANTISENC fast in
//! hardware: the design clock-gates every synaptic row with no input spike
//! (§VI-E), so per step the ActGen only *does work* proportional to the
//! number of firing rows. With a packed plane the simulator walks exactly
//! those rows via [`u64::trailing_zeros`] — O(popcount) iteration instead
//! of an O(M) branch-per-row scan — and the gating ledger is charged in
//! bulk from a precomputed per-row synapse prefix sum
//! (see [`crate::hdl::Layer::step_plane`]).
//!
//! Planes are also the unit of **buffer recycling** on the serving path:
//! [`PlanePool`] is a shared free-list the engine pre-fills at construction
//! so the steady-state streaming path performs zero plane allocations
//! (asserted in debug builds by
//! [`crate::coordinator::serving::ServingEngine`]). A recycled plane keeps
//! its word allocation across [`SpikePlane::load_bytes`]/
//! [`SpikePlane::resize_clear`] calls of any width it has already seen.
//!
//! Invariant: bits at positions `>= len` are always zero, so derived
//! equality, [`SpikePlane::count_ones`], and word-level consumers never see
//! ghost spikes in the tail word.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Words needed to hold `lines` one-bit lanes.
#[inline]
const fn words_for(lines: usize) -> usize {
    lines.div_ceil(64)
}

/// One timestep's spike vector, bit-packed (one `u64` word per 64 lines).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SpikePlane {
    words: Vec<u64>,
    len: usize,
}

impl SpikePlane {
    /// An all-zero plane of `len` lines.
    pub fn new(len: usize) -> SpikePlane {
        SpikePlane { words: vec![0; words_for(len)], len }
    }

    /// An empty plane whose word storage can hold `lines` lines without
    /// reallocating — what pools pre-fill with.
    pub fn with_line_capacity(lines: usize) -> SpikePlane {
        SpikePlane { words: Vec::with_capacity(words_for(lines)), len: 0 }
    }

    /// Number of lines (bits) in the plane.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed word view (tail bits beyond `len` are zero by invariant).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Set the plane to `len` all-zero lines, reusing the existing word
    /// allocation (no allocation once the plane has seen this width).
    pub fn resize_clear(&mut self, len: usize) {
        self.words.clear();
        self.words.resize(words_for(len), 0);
        self.len = len;
    }

    /// Mark line `i` as firing. Out-of-range lines are rejected (a silent
    /// tail-word write would break the ghost-bit invariant).
    #[inline]
    pub fn set(&mut self, i: usize) {
        assert!(i < self.len, "line {i} out of range for plane of {}", self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Whether line `i` fired.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.len, "line {i} out of range for plane of {}", self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Number of firing lines (popcount over the packed words).
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate the firing line indices in ascending order. Each word is
    /// consumed with `trailing_zeros` / clear-lowest-set, so a sparse plane
    /// costs O(popcount + len/64), not O(len).
    pub fn iter_ones(&self) -> Ones<'_> {
        Ones { words: &self.words, word_pos: 0, cur: 0, cur_base: 0 }
    }

    /// Pack a dense byte vector (any non-zero byte = spike) into this
    /// plane, reusing the word allocation.
    pub fn load_bytes(&mut self, bytes: &[u8]) {
        self.resize_clear(bytes.len());
        for (wi, chunk) in bytes.chunks(64).enumerate() {
            let mut w = 0u64;
            for (bi, &b) in chunk.iter().enumerate() {
                w |= ((b != 0) as u64) << bi;
            }
            self.words[wi] = w;
        }
    }

    /// A fresh plane packed from a dense byte vector.
    pub fn from_bytes(bytes: &[u8]) -> SpikePlane {
        let mut p = SpikePlane::default();
        p.load_bytes(bytes);
        p
    }

    /// Append the dense 0/1 byte expansion of this plane to `out`.
    pub fn append_bytes_to(&self, out: &mut Vec<u8>) {
        out.reserve(self.len);
        for (wi, &w) in self.words.iter().enumerate() {
            let lanes = (self.len - wi * 64).min(64);
            for bit in 0..lanes {
                out.push(((w >> bit) & 1) as u8);
            }
        }
    }

    /// The dense 0/1 byte expansion (allocating; adapters and tests).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len);
        self.append_bytes_to(&mut out);
        out
    }

    /// Become a copy of `other`, reusing this plane's word allocation.
    pub fn copy_from(&mut self, other: &SpikePlane) {
        self.words.clear();
        self.words.extend_from_slice(&other.words);
        self.len = other.len;
    }
}

/// Iterator over a plane's firing line indices (see
/// [`SpikePlane::iter_ones`]).
pub struct Ones<'a> {
    words: &'a [u64],
    word_pos: usize,
    cur: u64,
    cur_base: usize,
}

impl Iterator for Ones<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        while self.cur == 0 {
            if self.word_pos == self.words.len() {
                return None;
            }
            self.cur = self.words[self.word_pos];
            self.cur_base = self.word_pos * 64;
            self.word_pos += 1;
        }
        let t = self.cur.trailing_zeros() as usize;
        self.cur &= self.cur - 1; // clear lowest set bit
        Some(self.cur_base + t)
    }
}

/// Thread-safe free-list of recycled [`SpikePlane`] buffers.
///
/// The serving engine pre-fills one pool per engine with enough planes to
/// cover its maximum in-flight footprint (every bounded-channel slot plus
/// every stage's in-hand planes), so [`PlanePool::take`] never has to
/// allocate in steady state; each fallback allocation is counted in
/// [`PlanePool::misses`], which is what the engine's zero-alloc
/// debug-assert checks.
#[derive(Debug, Default)]
pub struct PlanePool {
    free: Mutex<Vec<SpikePlane>>,
    misses: AtomicU64,
}

impl PlanePool {
    /// An empty pool: every `take` until the first `put` is a (counted)
    /// allocation. Used by one-shot executors that don't pre-size.
    pub fn new() -> PlanePool {
        PlanePool::default()
    }

    /// A pool pre-filled with `count` planes whose word storage already
    /// covers `line_capacity` lines.
    pub fn prefilled(count: usize, line_capacity: usize) -> PlanePool {
        let free = (0..count).map(|_| SpikePlane::with_line_capacity(line_capacity)).collect();
        PlanePool { free: Mutex::new(free), misses: AtomicU64::new(0) }
    }

    /// Pop a recycled plane, or allocate (and count a miss) if the pool is
    /// dry. The returned plane has unspecified contents — load or
    /// `resize_clear` it before use.
    pub fn take(&self) -> SpikePlane {
        if let Some(p) = self.free.lock().unwrap().pop() {
            return p;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        SpikePlane::default()
    }

    /// Return a plane to the free list.
    pub fn put(&self, plane: SpikePlane) {
        self.free.lock().unwrap().push(plane);
    }

    /// Planes currently resting in the free list.
    pub fn available(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Times `take` found the pool dry and had to allocate.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_count() {
        let mut p = SpikePlane::new(130);
        assert_eq!(p.len(), 130);
        assert_eq!(p.count_ones(), 0);
        for i in [0usize, 63, 64, 127, 129] {
            p.set(i);
            assert!(p.get(i));
        }
        assert_eq!(p.count_ones(), 5);
        assert!(!p.get(1));
        assert_eq!(p.iter_ones().collect::<Vec<_>>(), vec![0, 63, 64, 127, 129]);
    }

    #[test]
    fn bytes_roundtrip_and_nonbinary_bytes() {
        let bytes = vec![0u8, 1, 0, 2, 255, 0, 1];
        let p = SpikePlane::from_bytes(&bytes);
        assert_eq!(p.len(), 7);
        assert_eq!(p.count_ones(), 4); // any non-zero byte is a spike
        assert_eq!(p.to_bytes(), vec![0, 1, 0, 1, 1, 0, 1]);
    }

    #[test]
    fn empty_and_word_boundary_planes() {
        assert_eq!(SpikePlane::new(0).to_bytes(), Vec::<u8>::new());
        assert_eq!(SpikePlane::new(0).iter_ones().count(), 0);
        for len in [63usize, 64, 65, 128] {
            let bytes = vec![1u8; len];
            let p = SpikePlane::from_bytes(&bytes);
            assert_eq!(p.count_ones(), len);
            assert_eq!(p.iter_ones().collect::<Vec<_>>(), (0..len).collect::<Vec<_>>());
            assert_eq!(p.to_bytes(), bytes);
        }
    }

    #[test]
    fn recycling_keeps_tail_invariant() {
        // A plane that held a wide all-ones vector must not leak ghost
        // spikes when recycled for a narrower one.
        let mut p = SpikePlane::from_bytes(&vec![1u8; 200]);
        p.load_bytes(&[0, 1, 0]);
        assert_eq!(p.len(), 3);
        assert_eq!(p.count_ones(), 1);
        assert_eq!(p.iter_ones().collect::<Vec<_>>(), vec![1]);
        p.resize_clear(100);
        assert_eq!(p.count_ones(), 0);
    }

    #[test]
    fn copy_from_matches_clone() {
        let a = SpikePlane::from_bytes(&[1, 0, 1, 1, 0]);
        let mut b = SpikePlane::from_bytes(&vec![1u8; 90]);
        b.copy_from(&a);
        assert_eq!(a, b);
    }

    #[test]
    fn pool_recycles_and_counts_misses() {
        let pool = PlanePool::prefilled(2, 128);
        assert_eq!(pool.available(), 2);
        let a = pool.take();
        let b = pool.take();
        assert_eq!(pool.misses(), 0);
        let c = pool.take(); // dry: allocates
        assert_eq!(pool.misses(), 1);
        pool.put(a);
        pool.put(b);
        pool.put(c);
        assert_eq!(pool.available(), 3);
        let _ = pool.take();
        assert_eq!(pool.misses(), 1);
    }
}
