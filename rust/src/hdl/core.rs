//! The QUANTISENC core — K layers + the decoder's control registers
//! (paper Fig. 1a). Dataflow processing: per spk_clk timestep, the spike
//! vector flows layer-by-layer through the core (the pipelined *stream*
//! overlap across samples lives in `coordinator::pipeline`; the core itself
//! is the per-sample datapath).

use crate::config::registers::RegisterFile;
use crate::config::ModelConfig;
use crate::datasets::Sample;

use super::clock::ActivityStats;
use super::layer::Layer;
use super::spikes::{SpikeMatrix, SpikePlane};

#[derive(Debug, Clone)]
pub struct Core {
    config: ModelConfig,
    layers: Vec<Layer>,
    pub registers: RegisterFile,
    /// Ping-pong bit-packed spike planes — zero allocation on the hot path;
    /// every layer hop is event-driven ([`Layer::step_plane`]).
    buf_a: SpikePlane,
    buf_b: SpikePlane,
    /// Scratch plane backing the byte-slice [`Core::step`] adapter.
    in_scratch: SpikePlane,
    /// Dense expansion of the output plane for the byte-slice adapter.
    out_bytes: Vec<u8>,
    /// Ping-pong lane matrices + scratch for the lane-batched path
    /// ([`Core::step_lanes`] / [`Core::run_lanes`]).
    mat_a: SpikeMatrix,
    mat_b: SpikeMatrix,
    mat_in_scratch: SpikeMatrix,
    lane_scratch: Vec<ActivityStats>,
}

/// Result of running one full input stream (sample) through the core.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Output spike counts per output neuron (the Fig.-11 spike counter).
    pub counts: Vec<u32>,
    /// Total spikes per layer (drives the power model, matches the HLO
    /// artifact's `layer_spike_totals` output bit-for-bit).
    pub layer_spikes: Vec<u64>,
    pub stats: ActivityStats,
    /// argmax of counts — the classification readout.
    pub prediction: usize,
}

impl Core {
    pub fn new(config: ModelConfig) -> Core {
        let layers = config
            .layers()
            .iter()
            .map(|l| Layer::new(l, config.qspec, config.mem))
            .collect();
        let registers = RegisterFile::new(config.qspec);
        let max_width = config.sizes().iter().copied().max().unwrap_or(1);
        Core {
            config,
            layers,
            registers,
            buf_a: SpikePlane::with_line_capacity(max_width),
            buf_b: SpikePlane::with_line_capacity(max_width),
            in_scratch: SpikePlane::with_line_capacity(max_width),
            out_bytes: Vec::new(),
            mat_a: SpikeMatrix::with_line_capacity(max_width),
            mat_b: SpikeMatrix::with_line_capacity(max_width),
            mat_in_scratch: SpikeMatrix::with_line_capacity(max_width),
            lane_scratch: Vec::new(),
        }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.config
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn layer_mut(&mut self, k: usize) -> &mut Layer {
        &mut self.layers[k]
    }

    /// Pin the lane-step kernel on every layer (`None` restores each
    /// layer's firing-rate-aware auto policy). Purely a performance knob —
    /// all kernels are bit-identical (see
    /// [`super::neuron::step_soa_lanes_with`]); the `simd_parity` suite
    /// uses this to build scalar-vs-SIMD conformance twins.
    pub fn set_lane_kernel(&mut self, kernel: Option<super::neuron::LaneKernel>) {
        for l in &mut self.layers {
            l.set_lane_kernel(kernel);
        }
    }

    /// Reset all membrane state (inter-stream settle, Fig. 8's `s`).
    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.reset();
        }
    }

    /// One spk_clk timestep over bit-packed planes: feed one input spike
    /// plane through all layers. Returns the output layer's plane (borrowed
    /// from the internal ping-pong buffer — zero allocation on the hot
    /// path) and the step's activity; per-layer spike counts accumulate
    /// into `layer_spikes`.
    pub fn step_plane(
        &mut self,
        spikes_in: &SpikePlane,
        layer_spikes: &mut [u64],
    ) -> (&SpikePlane, ActivityStats) {
        assert_eq!(layer_spikes.len(), self.layers.len());
        let mut total = ActivityStats::default();
        self.buf_a.copy_from(spikes_in);
        for (k, layer) in self.layers.iter_mut().enumerate() {
            let stats = layer.step_plane(&self.buf_a, &mut self.buf_b, &self.registers);
            layer_spikes[k] += stats.spikes;
            total.add(&stats);
            std::mem::swap(&mut self.buf_a, &mut self.buf_b);
        }
        total.spk_steps = 1; // one core timestep, not one per layer
        (&self.buf_a, total)
    }

    /// Byte-slice adapter over [`Core::step_plane`] — packs the input into
    /// a recycled scratch plane and expands the output plane to 0/1 bytes
    /// (kept for external callers; zero steady-state allocation).
    pub fn step(&mut self, spikes_in: &[u8], layer_spikes: &mut [u64]) -> (&[u8], ActivityStats) {
        self.in_scratch.load_bytes(spikes_in);
        let plane = std::mem::take(&mut self.in_scratch);
        let (_, stats) = self.step_plane(&plane, layer_spikes);
        self.in_scratch = plane;
        self.out_bytes.clear();
        self.buf_a.append_bytes_to(&mut self.out_bytes);
        (&self.out_bytes, stats)
    }

    /// Run a full sample (T timesteps), starting from reset state.
    pub fn run(&mut self, sample: &Sample) -> RunResult {
        assert_eq!(
            sample.inputs,
            self.config.inputs(),
            "sample width does not match core input layer"
        );
        self.run_with(sample.t_steps, |t, plane| plane.load_bytes(sample.step(t)), |_, _| {})
    }

    /// The one per-sample accumulation loop (reset → T plane steps →
    /// counts/layer_spikes/stats/argmax), shared by [`Core::run`] and the
    /// AER device interface so the two request paths can never
    /// desynchronize: `load` fills the input plane for each timestep,
    /// `on_step` observes each output plane (e.g. to stream spk_out
    /// events).
    pub fn run_with(
        &mut self,
        t_steps: usize,
        mut load: impl FnMut(usize, &mut SpikePlane),
        mut on_step: impl FnMut(usize, &SpikePlane),
    ) -> RunResult {
        self.reset();
        let n_out = self.config.outputs();
        let mut counts = vec![0u32; n_out];
        let mut layer_spikes = vec![0u64; self.layers.len()];
        let mut stats = ActivityStats::default();
        let mut input = std::mem::take(&mut self.in_scratch);
        for t in 0..t_steps {
            load(t, &mut input);
            let (out, st) = self.step_plane(&input, &mut layer_spikes);
            for j in out.iter_ones() {
                counts[j] += 1;
            }
            on_step(t, out);
            stats.add(&st);
        }
        self.in_scratch = input;
        let prediction = argmax(&counts);
        RunResult { counts, layer_spikes, stats, prediction }
    }

    /// One spk_clk timestep for up to 64 independent samples — feeds one
    /// lane [`SpikeMatrix`] through all layers on the lane-batched datapath
    /// ([`Layer::step_lanes`]: every synaptic row fetched once per firing
    /// line and scattered across the batch). `active` masks the live lanes;
    /// `layer_spikes[k · L + l]` accumulates layer `k`'s spikes in lane
    /// `l`; `step_stats[l]` is overwritten with lane `l`'s ledger for this
    /// step (summed over layers, one spk_clk edge per active lane — the
    /// same accounting as [`Core::step_plane`]). Returns the output
    /// layer's lane matrix, borrowed from the internal ping-pong buffer.
    pub fn step_lanes(
        &mut self,
        spikes_in: &SpikeMatrix,
        active: u64,
        layer_spikes: &mut [u64],
        step_stats: &mut [ActivityStats],
    ) -> &SpikeMatrix {
        let lanes = spikes_in.lanes();
        assert_eq!(layer_spikes.len(), self.layers.len() * lanes, "layer_spikes arity");
        assert_eq!(step_stats.len(), lanes, "per-lane stats arity");
        for st in step_stats.iter_mut() {
            *st = ActivityStats::default();
        }
        let mut scratch = std::mem::take(&mut self.lane_scratch);
        scratch.clear();
        scratch.resize(lanes, ActivityStats::default());
        self.mat_a.copy_from(spikes_in);
        for (k, layer) in self.layers.iter_mut().enumerate() {
            layer.step_lanes(&self.mat_a, &mut self.mat_b, &self.registers, active, &mut scratch);
            for (l, st) in scratch.iter_mut().enumerate() {
                if k != 0 {
                    // One spk_clk edge per *core* timestep per lane, not
                    // one per layer — matches `Core::step_plane`.
                    st.spk_steps = 0;
                }
                layer_spikes[k * lanes + l] += st.spikes;
                step_stats[l].add(st);
            }
            std::mem::swap(&mut self.mat_a, &mut self.mat_b);
        }
        self.lane_scratch = scratch;
        &self.mat_a
    }

    /// Run up to 64 full samples concurrently on the lane-batched datapath,
    /// starting from reset state: lane `l` carries `samples[l]`, ragged
    /// stream lengths are masked out as lanes finish, and each returned
    /// [`RunResult`] is **bit-identical** (counts, layer spikes, activity
    /// ledger, prediction) to `self.run(&samples[l])` — the conformance
    /// contract the twin gates in `rust/tests/sparse_parity.rs` and the
    /// core unit tests pin down.
    pub fn run_lanes(&mut self, samples: &[Sample]) -> Vec<RunResult> {
        let lanes = samples.len();
        assert!((1..=64).contains(&lanes), "lane batch of {lanes} samples (need 1..=64)");
        for s in samples {
            assert_eq!(s.inputs, self.config.inputs(), "sample width does not match core input");
        }
        self.reset();
        let n_out = self.config.outputs();
        let n_layers = self.layers.len();
        let t_max = samples.iter().map(|s| s.t_steps).max().unwrap_or(0);
        let mut counts = vec![0u32; lanes * n_out];
        let mut layer_spikes = vec![0u64; n_layers * lanes];
        let mut totals = vec![ActivityStats::default(); lanes];
        let mut step_stats = vec![ActivityStats::default(); lanes];
        let mut input = std::mem::take(&mut self.mat_in_scratch);
        for t in 0..t_max {
            input.resize_clear(self.config.inputs(), lanes);
            let mut active = 0u64;
            for (l, s) in samples.iter().enumerate() {
                if t < s.t_steps {
                    input.load_lane_bytes(l, s.step(t));
                    active |= 1 << l;
                }
            }
            let out = self.step_lanes(&input, active, &mut layer_spikes, &mut step_stats);
            for (j, &word) in out.words().iter().enumerate() {
                let mut bits = word;
                while bits != 0 {
                    let l = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    counts[l * n_out + j] += 1;
                }
            }
            for (l, st) in step_stats.iter().enumerate() {
                totals[l].add(st);
            }
        }
        self.mat_in_scratch = input;
        (0..lanes)
            .map(|l| {
                let counts = counts[l * n_out..(l + 1) * n_out].to_vec();
                let layer_spikes = (0..n_layers).map(|k| layer_spikes[k * lanes + l]).collect();
                let prediction = argmax(&counts);
                RunResult { counts, layer_spikes, stats: totals[l], prediction }
            })
            .collect()
    }

    /// Program trained weights (dense row-major per layer) — the wt_in bulk
    /// path used when deploying an artifact's weight file. Each layer's
    /// dense matrix is scattered into its topology-aware store (see
    /// [`super::memory::SynapticMemory`]): pruned entries must be zero.
    pub fn load_weights(&mut self, per_layer: &[Vec<i32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            per_layer.len() == self.layers.len(),
            "expected {} weight matrices, got {}",
            self.layers.len(),
            per_layer.len()
        );
        for (layer, w) in self.layers.iter_mut().zip(per_layer) {
            layer.memory_mut().load_dense(w)?;
        }
        Ok(())
    }

    /// Program trained weights in packed per-topology layout — exactly the
    /// physical words each layer stores (see
    /// [`super::memory::SynapticMemory::load_packed`]).
    pub fn load_packed_weights(&mut self, per_layer: &[Vec<i32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            per_layer.len() == self.layers.len(),
            "expected {} packed weight payloads, got {}",
            self.layers.len(),
            per_layer.len()
        );
        for (layer, w) in self.layers.iter_mut().zip(per_layer) {
            layer.load_packed(w)?;
        }
        Ok(())
    }

    /// Physical synaptic storage words across all layers, measured from the
    /// actual topology-aware stores (not the static mask model) — what the
    /// resource/power models charge for.
    pub fn synapse_words(&self) -> usize {
        self.layers.iter().map(|l| l.memory().synapses()).sum()
    }
}

/// First-max argmax (ties resolve to the lowest index, like numpy).
pub fn argmax(counts: &[u32]) -> usize {
    let mut best = 0;
    for (i, &c) in counts.iter().enumerate() {
        if c > counts[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::Sample;
    use crate::fixed::Q5_3;

    fn tiny_core() -> Core {
        let cfg = ModelConfig::parse_arch("4x3x2", Q5_3).unwrap();
        let mut core = Core::new(cfg);
        // Excitatory path: input 0..3 -> neuron 0 of layer 1 -> output 0.
        for i in 0..4 {
            core.layer_mut(0).memory_mut().write(i, 0, 8).unwrap(); // 1.0
        }
        core.layer_mut(1).memory_mut().write(0, 0, 16).unwrap(); // 2.0
        core
    }

    #[test]
    fn spikes_propagate_through_layers() {
        let mut core = tiny_core();
        let sample = Sample { spikes: vec![1, 1, 1, 1].repeat(5), t_steps: 5, inputs: 4, label: 0 };
        let r = core.run(&sample);
        assert!(r.layer_spikes[0] > 0, "hidden layer silent");
        assert!(r.counts[0] > 0, "output neuron silent");
        assert_eq!(r.prediction, 0);
    }

    #[test]
    fn silent_input_is_silent() {
        let mut core = tiny_core();
        let sample = Sample { spikes: vec![0; 20], t_steps: 5, inputs: 4, label: 0 };
        let r = core.run(&sample);
        assert_eq!(r.layer_spikes, vec![0, 0]);
        assert_eq!(r.counts, vec![0, 0]);
    }

    #[test]
    fn run_resets_between_samples() {
        let mut core = tiny_core();
        let sample = Sample { spikes: vec![1, 1, 1, 1].repeat(5), t_steps: 5, inputs: 4, label: 0 };
        let a = core.run(&sample);
        let b = core.run(&sample);
        assert_eq!(a.counts, b.counts, "state leaked across runs");
    }

    #[test]
    fn stats_cycle_accounting() {
        let mut core = tiny_core();
        let sample = Sample { spikes: vec![1, 0, 0, 0].repeat(3), t_steps: 3, inputs: 4, label: 0 };
        let r = core.run(&sample);
        // mem cycles = (M1 + M2) per step = (4 + 3) * 3 steps
        assert_eq!(r.stats.mem_cycles, 21);
        assert_eq!(r.stats.spk_steps, 3);
        assert_eq!(r.stats.neuron_updates, (3 + 2) * 3);
    }

    #[test]
    fn argmax_ties_lowest() {
        assert_eq!(argmax(&[3, 5, 5, 1]), 1);
        assert_eq!(argmax(&[0, 0]), 0);
    }

    #[test]
    fn synapse_words_follow_topology() {
        use crate::config::Topology;
        let dense = Core::new(ModelConfig::parse_arch("4x3x2", Q5_3).unwrap());
        assert_eq!(dense.synapse_words(), 4 * 3 + 3 * 2);
        let cfg = ModelConfig::with_topologies(
            &[6, 6, 6],
            &[Topology::OneToOne, Topology::Gaussian { radius: 1 }],
            Q5_3,
        )
        .unwrap();
        let sparse = Core::new(cfg.clone());
        assert_eq!(sparse.synapse_words(), 6 + 16);
        assert_eq!(sparse.synapse_words(), cfg.total_synapses());
    }

    #[test]
    fn packed_weights_equal_dense_weights() {
        use crate::config::Topology;
        let cfg = ModelConfig::with_topologies(
            &[5, 5, 2],
            &[Topology::Gaussian { radius: 1 }, Topology::AllToAll],
            Q5_3,
        )
        .unwrap();
        let mut a = Core::new(cfg.clone());
        let mut b = Core::new(cfg.clone());
        // Program a via single writes, then load b from a's packed payloads.
        for i in 0..5 {
            a.layer_mut(0).memory_mut().write(i, i, 7).unwrap();
        }
        a.layer_mut(1).memory_mut().write(3, 1, -9).unwrap();
        let packed: Vec<Vec<i32>> =
            a.layers().iter().map(|l| l.memory().packed().to_vec()).collect();
        b.load_packed_weights(&packed).unwrap();
        let sample = Sample { spikes: vec![1; 15], t_steps: 3, inputs: 5, label: 0 };
        assert_eq!(a.run(&sample).counts, b.run(&sample).counts);
        // Arity and size failures surface as errors, not panics.
        assert!(b.load_packed_weights(&[]).is_err());
        assert!(b.load_packed_weights(&[vec![0; 3], vec![0; 10]]).is_err());
    }

    #[test]
    fn plane_step_matches_byte_step() {
        use super::super::spikes::SpikePlane;
        let mut a = tiny_core();
        let mut b = tiny_core();
        let mut ls_a = vec![0u64; 2];
        let mut ls_b = vec![0u64; 2];
        let mut plane = SpikePlane::default();
        for t in 0..6usize {
            let spikes: Vec<u8> = (0..4).map(|i| ((t + i) % 3 != 0) as u8).collect();
            plane.load_bytes(&spikes);
            let (out_b, st_b) = b.step(&spikes, &mut ls_b);
            let (out_bytes, st_a) = (out_b.to_vec(), st_b);
            let (out_a, st) = a.step_plane(&plane, &mut ls_a);
            assert_eq!(out_a.to_bytes(), out_bytes, "t={t}");
            assert_eq!(st, st_a, "t={t}");
        }
        assert_eq!(ls_a, ls_b);
    }

    #[test]
    fn run_lanes_matches_per_sample_run_including_ragged() {
        // A ragged 5-lane batch (unequal stream lengths, one silent lane)
        // must be bit-identical per lane to sequential Core::run — counts,
        // per-layer spikes, prediction, and the full activity ledger.
        let mut batched = tiny_core();
        let mut seq = tiny_core();
        let mut rng = crate::datasets::rng::XorShift64Star::new(0x1A4E5);
        let samples: Vec<Sample> = [7usize, 3, 7, 1, 5]
            .iter()
            .enumerate()
            .map(|(l, &t_steps)| {
                let density = if l == 3 { 0.0 } else { 0.4 };
                let spikes =
                    (0..t_steps * 4).map(|_| (rng.uniform() < density) as u8).collect();
                Sample { spikes, t_steps, inputs: 4, label: 0 }
            })
            .collect();
        let out = batched.run_lanes(&samples);
        assert_eq!(out.len(), samples.len());
        for (l, (r, s)) in out.iter().zip(&samples).enumerate() {
            let want = seq.run(s);
            assert_eq!(r.counts, want.counts, "lane {l} counts");
            assert_eq!(r.layer_spikes, want.layer_spikes, "lane {l} layer spikes");
            assert_eq!(r.stats, want.stats, "lane {l} ledger");
            assert_eq!(r.prediction, want.prediction, "lane {l}");
        }
        // Lane runs are idempotent (state fully reset between batches).
        let again = batched.run_lanes(&samples);
        for (a, b) in out.iter().zip(&again) {
            assert_eq!(a.counts, b.counts, "state leaked across lane batches");
        }
    }

    #[test]
    fn step_lanes_matches_step_plane_per_lane() {
        use super::super::spikes::SpikeMatrix;
        let mut batched = tiny_core();
        let mut single = tiny_core();
        let lanes = 3usize;
        let mut layer_spikes = vec![0u64; 2 * lanes];
        let mut ls_single = vec![0u64; 2];
        let mut step_stats = vec![ActivityStats::default(); lanes];
        let mut mat = SpikeMatrix::default();
        let mut plane = crate::hdl::SpikePlane::default();
        // Lane 1 mirrors the single-sample core; other lanes carry noise.
        for t in 0..6usize {
            mat.resize_clear(4, lanes);
            let spikes: Vec<u8> = (0..4).map(|i| ((t + i) % 3 != 0) as u8).collect();
            mat.load_lane_bytes(0, &[1, 1, 1, 1]);
            mat.load_lane_bytes(1, &spikes);
            let out = batched.step_lanes(&mat, 0b111, &mut layer_spikes, &mut step_stats);
            let mut lane1 = crate::hdl::SpikePlane::default();
            out.lane_plane_into(1, &mut lane1);
            plane.load_bytes(&spikes);
            let (want_out, want_stats) = single.step_plane(&plane, &mut ls_single);
            assert_eq!(&lane1, want_out, "t={t}");
            assert_eq!(step_stats[1], want_stats, "t={t}");
        }
        assert_eq!(vec![layer_spikes[1], layer_spikes[lanes + 1]], ls_single);
    }

    #[test]
    #[should_panic(expected = "lane batch")]
    fn run_lanes_rejects_empty_batch() {
        tiny_core().run_lanes(&[]);
    }

    #[test]
    #[should_panic(expected = "sample width")]
    fn input_width_checked() {
        let mut core = tiny_core();
        let sample = Sample { spikes: vec![0; 10], t_steps: 2, inputs: 5, label: 0 };
        core.run(&sample);
    }
}
