//! The QUANTISENC core — K layers + the decoder's control registers
//! (paper Fig. 1a). Dataflow processing: per spk_clk timestep, the spike
//! vector flows layer-by-layer through the core (the pipelined *stream*
//! overlap across samples lives in `coordinator::pipeline`; the core itself
//! is the per-sample datapath).

use crate::config::registers::RegisterFile;
use crate::config::ModelConfig;
use crate::datasets::Sample;

use super::clock::ActivityStats;
use super::layer::Layer;
use super::spikes::SpikePlane;

#[derive(Debug, Clone)]
pub struct Core {
    config: ModelConfig,
    layers: Vec<Layer>,
    pub registers: RegisterFile,
    /// Ping-pong bit-packed spike planes — zero allocation on the hot path;
    /// every layer hop is event-driven ([`Layer::step_plane`]).
    buf_a: SpikePlane,
    buf_b: SpikePlane,
    /// Scratch plane backing the byte-slice [`Core::step`] adapter.
    in_scratch: SpikePlane,
    /// Dense expansion of the output plane for the byte-slice adapter.
    out_bytes: Vec<u8>,
}

/// Result of running one full input stream (sample) through the core.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Output spike counts per output neuron (the Fig.-11 spike counter).
    pub counts: Vec<u32>,
    /// Total spikes per layer (drives the power model, matches the HLO
    /// artifact's `layer_spike_totals` output bit-for-bit).
    pub layer_spikes: Vec<u64>,
    pub stats: ActivityStats,
    /// argmax of counts — the classification readout.
    pub prediction: usize,
}

impl Core {
    pub fn new(config: ModelConfig) -> Core {
        let layers = config
            .layers()
            .iter()
            .map(|l| Layer::new(l, config.qspec, config.mem))
            .collect();
        let registers = RegisterFile::new(config.qspec);
        let max_width = config.sizes().iter().copied().max().unwrap_or(1);
        Core {
            config,
            layers,
            registers,
            buf_a: SpikePlane::with_line_capacity(max_width),
            buf_b: SpikePlane::with_line_capacity(max_width),
            in_scratch: SpikePlane::with_line_capacity(max_width),
            out_bytes: Vec::new(),
        }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.config
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn layer_mut(&mut self, k: usize) -> &mut Layer {
        &mut self.layers[k]
    }

    /// Reset all membrane state (inter-stream settle, Fig. 8's `s`).
    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.reset();
        }
    }

    /// One spk_clk timestep over bit-packed planes: feed one input spike
    /// plane through all layers. Returns the output layer's plane (borrowed
    /// from the internal ping-pong buffer — zero allocation on the hot
    /// path) and the step's activity; per-layer spike counts accumulate
    /// into `layer_spikes`.
    pub fn step_plane(
        &mut self,
        spikes_in: &SpikePlane,
        layer_spikes: &mut [u64],
    ) -> (&SpikePlane, ActivityStats) {
        assert_eq!(layer_spikes.len(), self.layers.len());
        let mut total = ActivityStats::default();
        self.buf_a.copy_from(spikes_in);
        for (k, layer) in self.layers.iter_mut().enumerate() {
            let stats = layer.step_plane(&self.buf_a, &mut self.buf_b, &self.registers);
            layer_spikes[k] += stats.spikes;
            total.add(&stats);
            std::mem::swap(&mut self.buf_a, &mut self.buf_b);
        }
        total.spk_steps = 1; // one core timestep, not one per layer
        (&self.buf_a, total)
    }

    /// Byte-slice adapter over [`Core::step_plane`] — packs the input into
    /// a recycled scratch plane and expands the output plane to 0/1 bytes
    /// (kept for external callers; zero steady-state allocation).
    pub fn step(&mut self, spikes_in: &[u8], layer_spikes: &mut [u64]) -> (&[u8], ActivityStats) {
        self.in_scratch.load_bytes(spikes_in);
        let plane = std::mem::take(&mut self.in_scratch);
        let (_, stats) = self.step_plane(&plane, layer_spikes);
        self.in_scratch = plane;
        self.out_bytes.clear();
        self.buf_a.append_bytes_to(&mut self.out_bytes);
        (&self.out_bytes, stats)
    }

    /// Run a full sample (T timesteps), starting from reset state.
    pub fn run(&mut self, sample: &Sample) -> RunResult {
        assert_eq!(
            sample.inputs,
            self.config.inputs(),
            "sample width does not match core input layer"
        );
        self.run_with(sample.t_steps, |t, plane| plane.load_bytes(sample.step(t)), |_, _| {})
    }

    /// The one per-sample accumulation loop (reset → T plane steps →
    /// counts/layer_spikes/stats/argmax), shared by [`Core::run`] and the
    /// AER device interface so the two request paths can never
    /// desynchronize: `load` fills the input plane for each timestep,
    /// `on_step` observes each output plane (e.g. to stream spk_out
    /// events).
    pub fn run_with(
        &mut self,
        t_steps: usize,
        mut load: impl FnMut(usize, &mut SpikePlane),
        mut on_step: impl FnMut(usize, &SpikePlane),
    ) -> RunResult {
        self.reset();
        let n_out = self.config.outputs();
        let mut counts = vec![0u32; n_out];
        let mut layer_spikes = vec![0u64; self.layers.len()];
        let mut stats = ActivityStats::default();
        let mut input = std::mem::take(&mut self.in_scratch);
        for t in 0..t_steps {
            load(t, &mut input);
            let (out, st) = self.step_plane(&input, &mut layer_spikes);
            for j in out.iter_ones() {
                counts[j] += 1;
            }
            on_step(t, out);
            stats.add(&st);
        }
        self.in_scratch = input;
        let prediction = argmax(&counts);
        RunResult { counts, layer_spikes, stats, prediction }
    }

    /// Program trained weights (dense row-major per layer) — the wt_in bulk
    /// path used when deploying an artifact's weight file. Each layer's
    /// dense matrix is scattered into its topology-aware store (see
    /// [`super::memory::SynapticMemory`]): pruned entries must be zero.
    pub fn load_weights(&mut self, per_layer: &[Vec<i32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            per_layer.len() == self.layers.len(),
            "expected {} weight matrices, got {}",
            self.layers.len(),
            per_layer.len()
        );
        for (layer, w) in self.layers.iter_mut().zip(per_layer) {
            layer.memory_mut().load_dense(w)?;
        }
        Ok(())
    }

    /// Program trained weights in packed per-topology layout — exactly the
    /// physical words each layer stores (see
    /// [`super::memory::SynapticMemory::load_packed`]).
    pub fn load_packed_weights(&mut self, per_layer: &[Vec<i32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            per_layer.len() == self.layers.len(),
            "expected {} packed weight payloads, got {}",
            self.layers.len(),
            per_layer.len()
        );
        for (layer, w) in self.layers.iter_mut().zip(per_layer) {
            layer.load_packed(w)?;
        }
        Ok(())
    }

    /// Physical synaptic storage words across all layers, measured from the
    /// actual topology-aware stores (not the static mask model) — what the
    /// resource/power models charge for.
    pub fn synapse_words(&self) -> usize {
        self.layers.iter().map(|l| l.memory().synapses()).sum()
    }
}

/// First-max argmax (ties resolve to the lowest index, like numpy).
pub fn argmax(counts: &[u32]) -> usize {
    let mut best = 0;
    for (i, &c) in counts.iter().enumerate() {
        if c > counts[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::Sample;
    use crate::fixed::Q5_3;

    fn tiny_core() -> Core {
        let cfg = ModelConfig::parse_arch("4x3x2", Q5_3).unwrap();
        let mut core = Core::new(cfg);
        // Excitatory path: input 0..3 -> neuron 0 of layer 1 -> output 0.
        for i in 0..4 {
            core.layer_mut(0).memory_mut().write(i, 0, 8).unwrap(); // 1.0
        }
        core.layer_mut(1).memory_mut().write(0, 0, 16).unwrap(); // 2.0
        core
    }

    #[test]
    fn spikes_propagate_through_layers() {
        let mut core = tiny_core();
        let sample = Sample { spikes: vec![1, 1, 1, 1].repeat(5), t_steps: 5, inputs: 4, label: 0 };
        let r = core.run(&sample);
        assert!(r.layer_spikes[0] > 0, "hidden layer silent");
        assert!(r.counts[0] > 0, "output neuron silent");
        assert_eq!(r.prediction, 0);
    }

    #[test]
    fn silent_input_is_silent() {
        let mut core = tiny_core();
        let sample = Sample { spikes: vec![0; 20], t_steps: 5, inputs: 4, label: 0 };
        let r = core.run(&sample);
        assert_eq!(r.layer_spikes, vec![0, 0]);
        assert_eq!(r.counts, vec![0, 0]);
    }

    #[test]
    fn run_resets_between_samples() {
        let mut core = tiny_core();
        let sample = Sample { spikes: vec![1, 1, 1, 1].repeat(5), t_steps: 5, inputs: 4, label: 0 };
        let a = core.run(&sample);
        let b = core.run(&sample);
        assert_eq!(a.counts, b.counts, "state leaked across runs");
    }

    #[test]
    fn stats_cycle_accounting() {
        let mut core = tiny_core();
        let sample = Sample { spikes: vec![1, 0, 0, 0].repeat(3), t_steps: 3, inputs: 4, label: 0 };
        let r = core.run(&sample);
        // mem cycles = (M1 + M2) per step = (4 + 3) * 3 steps
        assert_eq!(r.stats.mem_cycles, 21);
        assert_eq!(r.stats.spk_steps, 3);
        assert_eq!(r.stats.neuron_updates, (3 + 2) * 3);
    }

    #[test]
    fn argmax_ties_lowest() {
        assert_eq!(argmax(&[3, 5, 5, 1]), 1);
        assert_eq!(argmax(&[0, 0]), 0);
    }

    #[test]
    fn synapse_words_follow_topology() {
        use crate::config::Topology;
        let dense = Core::new(ModelConfig::parse_arch("4x3x2", Q5_3).unwrap());
        assert_eq!(dense.synapse_words(), 4 * 3 + 3 * 2);
        let cfg = ModelConfig::with_topologies(
            &[6, 6, 6],
            &[Topology::OneToOne, Topology::Gaussian { radius: 1 }],
            Q5_3,
        )
        .unwrap();
        let sparse = Core::new(cfg.clone());
        assert_eq!(sparse.synapse_words(), 6 + 16);
        assert_eq!(sparse.synapse_words(), cfg.total_synapses());
    }

    #[test]
    fn packed_weights_equal_dense_weights() {
        use crate::config::Topology;
        let cfg = ModelConfig::with_topologies(
            &[5, 5, 2],
            &[Topology::Gaussian { radius: 1 }, Topology::AllToAll],
            Q5_3,
        )
        .unwrap();
        let mut a = Core::new(cfg.clone());
        let mut b = Core::new(cfg.clone());
        // Program a via single writes, then load b from a's packed payloads.
        for i in 0..5 {
            a.layer_mut(0).memory_mut().write(i, i, 7).unwrap();
        }
        a.layer_mut(1).memory_mut().write(3, 1, -9).unwrap();
        let packed: Vec<Vec<i32>> =
            a.layers().iter().map(|l| l.memory().packed().to_vec()).collect();
        b.load_packed_weights(&packed).unwrap();
        let sample = Sample { spikes: vec![1; 15], t_steps: 3, inputs: 5, label: 0 };
        assert_eq!(a.run(&sample).counts, b.run(&sample).counts);
        // Arity and size failures surface as errors, not panics.
        assert!(b.load_packed_weights(&[]).is_err());
        assert!(b.load_packed_weights(&[vec![0; 3], vec![0; 10]]).is_err());
    }

    #[test]
    fn plane_step_matches_byte_step() {
        use super::super::spikes::SpikePlane;
        let mut a = tiny_core();
        let mut b = tiny_core();
        let mut ls_a = vec![0u64; 2];
        let mut ls_b = vec![0u64; 2];
        let mut plane = SpikePlane::default();
        for t in 0..6usize {
            let spikes: Vec<u8> = (0..4).map(|i| ((t + i) % 3 != 0) as u8).collect();
            plane.load_bytes(&spikes);
            let (out_b, st_b) = b.step(&spikes, &mut ls_b);
            let (out_bytes, st_a) = (out_b.to_vec(), st_b);
            let (out_a, st) = a.step_plane(&plane, &mut ls_a);
            assert_eq!(out_a.to_bytes(), out_bytes, "t={t}");
            assert_eq!(st, st_a, "t={t}");
        }
        assert_eq!(ls_a, ls_b);
    }

    #[test]
    #[should_panic(expected = "sample width")]
    fn input_width_checked() {
        let mut core = tiny_core();
        let sample = Sample { spikes: vec![0; 10], t_steps: 2, inputs: 5, label: 0 };
        core.run(&sample);
    }
}
