//! Extended neuron/synapse models — the paper's §I modularity claim made
//! concrete: "QUANTISENC can be easily extended to support other types of
//! neurons, e.g., Izhikevich and compartmental, and synapse, e.g.,
//! conductance-based synapse (COBA)".
//!
//! Both models below run on the same signed Qn.q datapath, the same control
//! registers idea (their parameters are run-time-programmable raw words),
//! and slot into a layer the same way the LIF datapath does — they share
//! ActGen (the weighted-sum front end) and replace VmemDyn/VmemSel.

use crate::fixed::QSpec;

/// Quantized Izhikevich neuron (Izhikevich 2003), forward-Euler:
///
///   v' = v + Δt·(0.04 v² + 5 v + 140 − u + I)
///   u' = u + Δt·a·(b·v − u)
///   spike when v ≥ 30 mV → v := c, u := u + d
///
/// All constants live in Qn.q control words (run-time programmable, like
/// the LIF registers). Needs ≥ Q14.x integer headroom for the v² term in
/// the mV regime (v² reaches ~4900); the constructor enforces it.
#[derive(Debug, Clone)]
pub struct IzhikevichNeuron {
    pub v: i32,
    pub u: i32,
    qspec: QSpec,
    // Control words (raw Qn.q).
    pub a: i32,
    pub b: i32,
    pub c: i32,
    pub d: i32,
    k_sq: i32,    // 0.04
    k_lin: i32,   // 5
    k_bias: i32,  // 140
    v_spike: i32, // 30
    dt: i32,
}

/// Canonical parameter presets from the Izhikevich paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IzhPreset {
    /// a=0.02, b=0.2, c=-65, d=8 — regular spiking (cortical excitatory).
    RegularSpiking,
    /// a=0.1, b=0.2, c=-65, d=2 — fast spiking (inhibitory interneuron).
    FastSpiking,
    /// a=0.02, b=0.2, c=-50, d=2 — chattering / bursting.
    Chattering,
}

impl IzhikevichNeuron {
    pub fn new(qspec: QSpec, preset: IzhPreset) -> anyhow::Result<IzhikevichNeuron> {
        anyhow::ensure!(
            qspec.n() >= 14,
            "Izhikevich dynamics need >= Q14.x headroom (v^2 reaches ~4900 mV^2), got {qspec}"
        );
        let (a, b, c, d) = match preset {
            IzhPreset::RegularSpiking => (0.02, 0.2, -65.0, 8.0),
            IzhPreset::FastSpiking => (0.1, 0.2, -65.0, 2.0),
            IzhPreset::Chattering => (0.02, 0.2, -50.0, 2.0),
        };
        Ok(IzhikevichNeuron {
            v: qspec.from_float(-65.0),
            u: qspec.from_float(b * -65.0),
            qspec,
            a: qspec.from_float(a),
            b: qspec.from_float(b),
            c: qspec.from_float(c),
            d: qspec.from_float(d),
            k_sq: qspec.from_float(0.04),
            k_lin: qspec.from_float(5.0),
            k_bias: qspec.from_float(140.0),
            v_spike: qspec.from_float(30.0),
            dt: qspec.from_float(0.5), // 0.5 ms Euler step (stability)
        })
    }

    /// One Euler step with input current `i_in` (raw Qn.q). Returns spike.
    ///
    /// The v² term is computed with the *saturating* wide product rather
    /// than the wrapping datapath multiply: in silicon this node gets a
    /// wider intermediate (2W bits, like Fig. 6 pre-truncation) precisely
    /// because a wrapped v² flips the parabola's sign and destroys the
    /// dynamics. This is the one documented departure from the pure LIF
    /// datapath and the reason the paper calls the extension "modular" —
    /// only VmemDyn changes.
    pub fn step(&mut self, i_in: i32) -> bool {
        let qs = self.qspec;
        // v^2 with saturation (wide product, then clamp into Qn.q).
        let v2_wide = (self.v as i64 * self.v as i64) >> qs.q();
        let v2 = v2_wide.clamp(qs.min_raw() as i64, qs.max_raw() as i64) as i32;
        let quad = qs.mul(self.k_sq, v2);
        let lin = qs.mul(self.k_lin, self.v);
        let dv_wide = quad as i64 + lin as i64 + self.k_bias as i64 - self.u as i64 + i_in as i64;
        let dv = dv_wide.clamp(qs.min_raw() as i64, qs.max_raw() as i64) as i32;
        self.v = {
            let step = qs.mul(self.dt, dv);
            (self.v as i64 + step as i64).clamp(qs.min_raw() as i64, qs.max_raw() as i64) as i32
        };
        let du = qs.mul(self.a, qs.sub(qs.mul(self.b, self.v), self.u));
        self.u = qs.add(self.u, qs.mul(self.dt, du));

        if self.v >= self.v_spike {
            self.v = self.c;
            self.u = qs.add(self.u, self.d);
            true
        } else {
            false
        }
    }

    /// Drive with constant current; return (spike count, v trace in floats).
    pub fn run_constant(&mut self, i_in_f: f64, steps: usize) -> (usize, Vec<f64>) {
        let i_raw = self.qspec.from_float(i_in_f);
        let mut spikes = 0;
        let mut trace = Vec::with_capacity(steps);
        for _ in 0..steps {
            if self.step(i_raw) {
                spikes += 1;
            }
            trace.push(self.qspec.to_float(self.v));
        }
        (spikes, trace)
    }
}

/// Conductance-based (COBA) synapse state for one neuron: instead of the
/// CUBA weighted sum feeding current directly (Eq. 6), spikes charge a
/// conductance g that decays exponentially, and the delivered current is
/// g·(E_rev − v): excitatory for v < E_rev, shunting as v approaches it.
#[derive(Debug, Clone)]
pub struct CobaSynapse {
    pub g: i32,
    qspec: QSpec,
    /// Conductance decay per step (Qn.q raw), e.g. 0.25.
    pub g_decay: i32,
    /// Reversal potential (raw). 0 mV for excitatory AMPA-like, very
    /// negative for inhibitory GABA-like.
    pub e_rev: i32,
}

impl CobaSynapse {
    pub fn new(qspec: QSpec, g_decay: f64, e_rev: f64) -> CobaSynapse {
        CobaSynapse {
            g: 0,
            qspec,
            g_decay: qspec.from_float(g_decay),
            e_rev: qspec.from_float(e_rev),
        }
    }

    /// One step: `weighted_spikes` is ActGen's weighted spike sum (the same
    /// front end as CUBA — modularity point), `vmem` the neuron's membrane.
    /// Returns the synaptic current to feed VmemDyn.
    pub fn step(&mut self, weighted_spikes: i32, vmem: i32) -> i32 {
        let qs = self.qspec;
        // g decays, then integrates the arriving spikes.
        self.g = qs.add(qs.sub(self.g, qs.mul(self.g_decay, self.g)), weighted_spikes);
        qs.mul(self.g, qs.sub(self.e_rev, vmem))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{QSpec, Q5_3, Q9_7};

    fn q14() -> QSpec {
        QSpec::new(14, 10).unwrap()
    }

    #[test]
    fn izh_requires_headroom() {
        assert!(IzhikevichNeuron::new(Q5_3, IzhPreset::RegularSpiking).is_err());
        assert!(IzhikevichNeuron::new(Q9_7, IzhPreset::RegularSpiking).is_err());
        assert!(IzhikevichNeuron::new(q14(), IzhPreset::RegularSpiking).is_ok());
    }

    #[test]
    fn izh_rests_without_input() {
        let mut n = IzhikevichNeuron::new(q14(), IzhPreset::RegularSpiking).unwrap();
        let (spikes, trace) = n.run_constant(0.0, 400);
        assert_eq!(spikes, 0, "no drive, no spikes");
        // v stays near the fixed point (between -80 and -50 mV).
        assert!(trace.iter().all(|&v| (-80.0..=-50.0).contains(&v)), "{:?}", &trace[..8]);
    }

    #[test]
    fn izh_spikes_under_drive_and_resets_to_c() {
        let mut n = IzhikevichNeuron::new(q14(), IzhPreset::RegularSpiking).unwrap();
        let (spikes, trace) = n.run_constant(10.0, 800);
        assert!(spikes >= 3, "regular spiking expected, got {spikes}");
        // After a spike v jumps to c = -65.
        let c = -65.0;
        assert!(trace.iter().any(|&v| (v - c).abs() < 1.0));
    }

    #[test]
    fn fast_spiking_outpaces_regular() {
        let mut rs = IzhikevichNeuron::new(q14(), IzhPreset::RegularSpiking).unwrap();
        let mut fs = IzhikevichNeuron::new(q14(), IzhPreset::FastSpiking).unwrap();
        let (s_rs, _) = rs.run_constant(10.0, 800);
        let (s_fs, _) = fs.run_constant(10.0, 800);
        assert!(
            s_fs > s_rs,
            "fast-spiking ({s_fs}) must fire more than regular ({s_rs}) — the preset's defining property"
        );
    }

    #[test]
    fn coba_excitatory_drives_toward_reversal() {
        let qs = Q9_7;
        let mut syn = CobaSynapse::new(qs, 0.25, 0.0); // E_rev = 0 (excitatory)
        let w_spk = qs.from_float(0.5);
        // Below reversal: positive (depolarising) current.
        let i1 = syn.step(w_spk, qs.from_float(-65.0));
        assert!(i1 > 0, "below E_rev must depolarise");
        // At reversal: current vanishes (shunting) even with conductance up.
        let mut syn2 = CobaSynapse::new(qs, 0.25, 0.0);
        syn2.step(w_spk, 0);
        let i2 = syn2.step(w_spk, 0);
        assert_eq!(i2, 0, "at E_rev the driving force is zero");
    }

    #[test]
    fn coba_inhibitory_hyperpolarises() {
        let qs = Q9_7;
        let mut syn = CobaSynapse::new(qs, 0.25, -80.0); // GABA-like
        let i = syn.step(qs.from_float(0.5), qs.from_float(-65.0));
        assert!(i < 0, "inhibitory reversal below vmem must hyperpolarise");
    }

    #[test]
    fn coba_conductance_decays() {
        let qs = Q9_7;
        let mut syn = CobaSynapse::new(qs, 0.5, 0.0);
        syn.step(qs.from_float(1.0), 0);
        let g1 = syn.g;
        syn.step(0, 0);
        assert!(syn.g < g1, "g must decay without input spikes");
        for _ in 0..100 {
            syn.step(0, 0);
        }
        // Truncating fixed-point decay floors at one LSB (mul(0.5, 1) == 0
        // in the Fig.-6 datapath) — the hardware behaviour, not a bug.
        assert!(syn.g <= 1, "g must decay to the truncation floor, got {}", syn.g);
    }
}
