//! Distributed synaptic memory — paper §II/§III-A and Fig. 1b.
//!
//! Each layer owns an M×N weight matrix holding all pre-synaptic weights of
//! its neurons ("all pre-synaptic weights are stored in their respective
//! layer"). The access granularity is a single (pre, post) weight, which is
//! what makes every weight individually programmable through wt_in.
//!
//! The implementation choice (BRAM / distributed LUT / register, Fig. 13)
//! does not change function — only the resource/timing/power models in
//! [`crate::hwmodel`] — but is carried here so a programmed core knows what
//! it is "made of".

use crate::config::{MemKind, Topology};
use crate::fixed::QSpec;

#[derive(Debug, PartialEq)]
pub enum MemError {
    BadAddress { pre: usize, post: usize, m: usize, n: usize },
    OutOfRange { value: i32, q: String },
    Pruned { pre: usize, post: usize, topo: String },
    BulkSize { expect: usize, got: usize },
}

impl std::fmt::Display for MemError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemError::BadAddress { pre, post, m, n } => {
                write!(f, "weight address ({pre}, {post}) out of range for {m}x{n} memory")
            }
            MemError::OutOfRange { value, q } => write!(f, "weight {value} does not fit {q}"),
            MemError::Pruned { pre, post, topo } => write!(
                f,
                "connection ({pre}, {post}) is pruned by topology {topo} (α=0: no storage exists)"
            ),
            MemError::BulkSize { expect, got } => {
                write!(f, "expected {expect} weights for this memory, got {got}")
            }
        }
    }
}

impl std::error::Error for MemError {}

/// One layer's synaptic weight memory (row-major [M × N], i32 Qn.q raw).
#[derive(Debug, Clone)]
pub struct SynapticMemory {
    m: usize,
    n: usize,
    qspec: QSpec,
    kind: MemKind,
    topology: Topology,
    mask: Vec<u8>,
    weights: Vec<i32>,
    /// Accepted wt_in writes (interface telemetry).
    writes: u64,
}

impl SynapticMemory {
    pub fn new(m: usize, n: usize, topology: Topology, qspec: QSpec, kind: MemKind) -> Self {
        let mask = topology.mask(m, n).expect("topology validated by ModelConfig");
        SynapticMemory { m, n, qspec, kind, topology, mask, weights: vec![0; m * n], writes: 0 }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn kind(&self) -> MemKind {
        self.kind
    }

    pub fn qspec(&self) -> QSpec {
        self.qspec
    }

    pub fn writes(&self) -> u64 {
        self.writes
    }

    /// α=1 synapse count (physical storage words).
    pub fn synapses(&self) -> usize {
        self.mask.iter().map(|&x| x as usize).sum()
    }

    /// wt_in transaction: program one synaptic weight. Rejects out-of-range
    /// addresses, values that don't fit the Qn.q word, and writes to pruned
    /// (α=0) connections — which have no physical storage in the hardware.
    pub fn write(&mut self, pre: usize, post: usize, value: i32) -> Result<(), MemError> {
        if pre >= self.m || post >= self.n {
            return Err(MemError::BadAddress { pre, post, m: self.m, n: self.n });
        }
        if !self.qspec.in_range(value) {
            return Err(MemError::OutOfRange { value, q: self.qspec.name() });
        }
        if self.mask[pre * self.n + post] == 0 {
            return Err(MemError::Pruned { pre, post, topo: self.topology.label() });
        }
        self.weights[pre * self.n + post] = value;
        self.writes += 1;
        Ok(())
    }

    #[inline]
    pub fn read(&self, pre: usize, post: usize) -> Result<i32, MemError> {
        if pre >= self.m || post >= self.n {
            return Err(MemError::BadAddress { pre, post, m: self.m, n: self.n });
        }
        Ok(self.weights[pre * self.n + post])
    }

    /// One row (all post-synaptic weights of pre-neuron `pre`) — what the
    /// address generator reads in one mem_clk cycle group.
    #[inline]
    pub fn row(&self, pre: usize) -> &[i32] {
        &self.weights[pre * self.n..(pre + 1) * self.n]
    }

    /// Bulk-load a full dense [M × N] matrix (the artifact weight files).
    /// Entries at pruned positions must be zero; others must fit Qn.q.
    pub fn load_dense(&mut self, weights: &[i32]) -> Result<(), MemError> {
        if weights.len() != self.m * self.n {
            return Err(MemError::BulkSize { expect: self.m * self.n, got: weights.len() });
        }
        for (idx, &w) in weights.iter().enumerate() {
            if self.mask[idx] == 0 {
                if w != 0 {
                    return Err(MemError::Pruned {
                        pre: idx / self.n,
                        post: idx % self.n,
                        topo: self.topology.label(),
                    });
                }
            } else if !self.qspec.in_range(w) {
                return Err(MemError::OutOfRange { value: w, q: self.qspec.name() });
            }
        }
        self.weights.copy_from_slice(weights);
        self.writes += self.synapses() as u64;
        Ok(())
    }

    pub fn dense(&self) -> &[i32] {
        &self.weights
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q5_3;

    fn mem() -> SynapticMemory {
        SynapticMemory::new(4, 3, Topology::AllToAll, Q5_3, MemKind::Bram)
    }

    #[test]
    fn write_read_roundtrip() {
        let mut m = mem();
        m.write(2, 1, -17).unwrap();
        assert_eq!(m.read(2, 1).unwrap(), -17);
        assert_eq!(m.read(0, 0).unwrap(), 0);
        assert_eq!(m.writes(), 1);
    }

    #[test]
    fn rejects_bad_address_value() {
        let mut m = mem();
        assert!(matches!(m.write(4, 0, 1), Err(MemError::BadAddress { .. })));
        assert!(matches!(m.write(0, 3, 1), Err(MemError::BadAddress { .. })));
        assert!(matches!(m.write(0, 0, 400), Err(MemError::OutOfRange { .. })));
        assert!(matches!(m.read(9, 9), Err(MemError::BadAddress { .. })));
    }

    #[test]
    fn pruned_connections_have_no_storage() {
        let mut m = SynapticMemory::new(3, 3, Topology::OneToOne, Q5_3, MemKind::Bram);
        assert!(m.write(0, 0, 5).is_ok());
        assert!(matches!(m.write(0, 1, 5), Err(MemError::Pruned { .. })));
        assert_eq!(m.synapses(), 3);
    }

    #[test]
    fn load_dense_validates() {
        let mut m = SynapticMemory::new(2, 2, Topology::OneToOne, Q5_3, MemKind::Bram);
        assert!(m.load_dense(&[1, 0, 0, 2]).is_ok());
        assert!(matches!(m.load_dense(&[1, 9, 0, 2]), Err(MemError::Pruned { .. })));
        assert!(matches!(m.load_dense(&[1, 0, 0]), Err(MemError::BulkSize { .. })));
        assert!(matches!(m.load_dense(&[1, 0, 0, 4000]), Err(MemError::OutOfRange { .. })));
    }

    #[test]
    fn row_view() {
        let mut m = mem();
        m.write(1, 0, 3).unwrap();
        m.write(1, 2, -4).unwrap();
        assert_eq!(m.row(1), &[3, 0, -4]);
    }
}
