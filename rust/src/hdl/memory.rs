//! Distributed synaptic memory — paper §II/§III-A and Figs. 1b/13.
//!
//! Each layer owns the pre-synaptic weights of its neurons ("all
//! pre-synaptic weights are stored in their respective layer"). The access
//! granularity is a single (pre, post) weight, which is what makes every
//! weight individually programmable through wt_in.
//!
//! # Topology-aware storage
//!
//! QUANTISENC's distributed memory only instantiates the synapses a
//! topology actually has (Fig. 13: the one-to-one and Gaussian connection
//! blocks use a small fraction of the all-to-all block's resources). The
//! store mirrors that:
//!
//! * [`Topology::AllToAll`] — dense row-major `[M × N]` words, exactly the
//!   full FC connection block.
//! * [`Topology::OneToOne`] — a single diagonal vector of `N` words
//!   (`α_ij = 1` iff `i == j`), the paper's one-to-one block.
//! * [`Topology::Gaussian { radius }`] — *banded* rows: every pre-synaptic
//!   row `i` stores only its contiguous α=1 column window (at most
//!   `2·radius + 1` wide for equal-width layers, the paper's `|i − j| ≤ r`
//!   receptive field; windows are clipped at the grid edges and rescaled
//!   for unequal widths). Row windows are concatenated CSR-style with a
//!   per-row start column and offset.
//!
//! All three layouts sit behind the same accessors: [`accumulate_row`]
//! (the fused walk the ActGen hot loop uses — synaptic work is O(nnz)
//! instead of O(N) per active row), [`row_nonzero`] (iterate the stored
//! `(post, weight)` pairs of a row, the inspection/differential-test
//! view of the same window), and materialized [`row`]/[`dense`] views
//! for artifacts.
//!
//! Bulk programming has two shapes: [`load_dense`] takes the artifact
//! store's full `[M × N]` matrix (pruned entries must be zero), while
//! [`load_packed`] takes exactly the physical words in canonical order
//! (row-major over stored positions). [`MemError::BulkSize`] reports the
//! *per-topology* payload size of whichever path rejected it — `M × N` for
//! the dense path, [`synapses`] for the packed path — never a blanket
//! dense-size assumption.
//!
//! The implementation choice (BRAM / distributed LUT / register, Fig. 13)
//! does not change function — only the resource/timing/power models in
//! [`crate::hwmodel`] — but is carried here so a programmed core knows what
//! it is "made of".
//!
//! [`row_nonzero`]: SynapticMemory::row_nonzero
//! [`accumulate_row`]: SynapticMemory::accumulate_row
//! [`row`]: SynapticMemory::row
//! [`dense`]: SynapticMemory::dense
//! [`load_dense`]: SynapticMemory::load_dense
//! [`load_packed`]: SynapticMemory::load_packed
//! [`synapses`]: SynapticMemory::synapses

use crate::config::{MemKind, Topology};
use crate::fixed::QSpec;
use crate::hdl::integrity::{Guard, IntegrityMode, ScrubOutcome};

#[derive(Debug, PartialEq)]
pub enum MemError {
    BadAddress { pre: usize, post: usize, m: usize, n: usize },
    OutOfRange { value: i32, q: String },
    Pruned { pre: usize, post: usize, topo: String },
    /// A bulk payload had the wrong length. `expect` is the payload size of
    /// the rejecting path for *this* memory's topology: the dense `M × N`
    /// word count for [`SynapticMemory::load_dense`], the physical
    /// (α=1) word count for [`SynapticMemory::load_packed`].
    BulkSize { expect: usize, got: usize },
}

impl std::fmt::Display for MemError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemError::BadAddress { pre, post, m, n } => {
                write!(f, "weight address ({pre}, {post}) out of range for {m}x{n} memory")
            }
            MemError::OutOfRange { value, q } => write!(f, "weight {value} does not fit {q}"),
            MemError::Pruned { pre, post, topo } => write!(
                f,
                "connection ({pre}, {post}) is pruned by topology {topo} (α=0: no storage exists)"
            ),
            MemError::BulkSize { expect, got } => {
                write!(f, "expected {expect} weights for this memory, got {got}")
            }
        }
    }
}

impl std::error::Error for MemError {}

/// Physical weight storage, chosen per topology (see module docs).
#[derive(Debug, Clone)]
enum Store {
    /// All-to-all: dense row-major `[M × N]`.
    Dense(Vec<i32>),
    /// One-to-one: the diagonal only (`M == N` words).
    Diagonal(Vec<i32>),
    /// Gaussian: per-row contiguous column windows, concatenated.
    /// Row `i` covers columns `[starts[i], starts[i] + len_i)` with
    /// `len_i = offsets[i+1] - offsets[i]` and weights at
    /// `weights[offsets[i]..offsets[i+1]]`.
    Banded { starts: Vec<usize>, offsets: Vec<usize>, weights: Vec<i32> },
}

/// Iterator over one row's stored `(post, weight)` pairs — every α=1
/// position of the row, in ascending column order. All three topologies
/// store contiguous per-row windows, so this is a window walk.
pub struct RowNonzero<'a> {
    start: usize,
    k: usize,
    weights: &'a [i32],
}

impl<'a> Iterator for RowNonzero<'a> {
    type Item = (usize, i32);

    fn next(&mut self) -> Option<(usize, i32)> {
        let &w = self.weights.get(self.k)?;
        let item = (self.start + self.k, w);
        self.k += 1;
        Some(item)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = self.weights.len() - self.k;
        (rem, Some(rem))
    }
}

/// One layer's synaptic weight memory (i32 Qn.q raw words in a
/// topology-aware store — see module docs for the three layouts).
#[derive(Debug, Clone)]
pub struct SynapticMemory {
    m: usize,
    n: usize,
    qspec: QSpec,
    kind: MemKind,
    topology: Topology,
    store: Store,
    /// Accepted wt_in writes (interface telemetry).
    writes: u64,
    /// SEU-integrity codes over the physical word vector (see
    /// [`crate::hdl::integrity`]); `Off` by default and free when off.
    guard: Guard,
}

impl SynapticMemory {
    pub fn new(m: usize, n: usize, topology: Topology, qspec: QSpec, kind: MemKind) -> Self {
        // One mask pass: validates the shape for every topology and, for
        // the banded store, extracts (and asserts) the contiguous per-row
        // α=1 windows — the single implementation of the window invariant
        // lives in `Topology::row_windows`.
        let windows = topology.row_windows(m, n).expect("topology validated by ModelConfig");
        let store = match topology {
            Topology::AllToAll => Store::Dense(vec![0; m * n]),
            Topology::OneToOne => Store::Diagonal(vec![0; n]),
            Topology::Gaussian { .. } => {
                let mut starts = Vec::with_capacity(m);
                let mut offsets = Vec::with_capacity(m + 1);
                offsets.push(0usize);
                for win in windows {
                    let base = *offsets.last().unwrap();
                    match win {
                        Some((lo, hi)) => {
                            starts.push(lo);
                            offsets.push(base + (hi - lo + 1));
                        }
                        None => {
                            starts.push(0);
                            offsets.push(base);
                        }
                    }
                }
                let total = *offsets.last().unwrap();
                Store::Banded { starts, offsets, weights: vec![0; total] }
            }
        };
        SynapticMemory { m, n, qspec, kind, topology, store, writes: 0, guard: Guard::default() }
    }

    /// Enable (or disable) SEU-integrity codes over the physical words,
    /// rebuilding them from the current contents. Every subsequent write
    /// path — [`write`], [`load_dense`], [`load_packed`] — keeps the
    /// codes consistent incrementally.
    ///
    /// [`write`]: SynapticMemory::write
    /// [`load_dense`]: SynapticMemory::load_dense
    /// [`load_packed`]: SynapticMemory::load_packed
    pub fn set_integrity(&mut self, mode: IntegrityMode) {
        self.guard = Guard::new(mode, self.words());
    }

    pub fn integrity_mode(&self) -> IntegrityMode {
        self.guard.mode()
    }

    /// Scrub units covering this memory (0 when integrity is off).
    pub fn integrity_blocks(&self) -> usize {
        self.guard.blocks()
    }

    /// Verify up to `budget` blocks starting at `*cursor` (wrapping; the
    /// cursor advances). Correctable flips are repaired in place.
    pub fn scrub(&mut self, cursor: &mut usize, budget: usize) -> ScrubOutcome {
        let SynapticMemory { store, guard, .. } = self;
        let words: &mut [i32] = match store {
            Store::Dense(w) | Store::Diagonal(w) => w,
            Store::Banded { weights, .. } => weights,
        };
        guard.scrub(words, cursor, budget)
    }

    /// Flip one raw storage bit *without* updating the integrity codes —
    /// the SEU fault-injection hook (`word` wraps modulo the physical
    /// word count, `bit` modulo 32). A no-op on empty stores.
    pub fn integrity_flip(&mut self, word: usize, bit: u8) {
        let words = self.words_mut();
        if words.is_empty() {
            return;
        }
        let idx = word % words.len();
        words[idx] ^= 1i32 << (bit % 32);
    }

    /// Rebuild the integrity codes after a bulk store mutation.
    fn refresh_guard(&mut self) {
        let words: &[i32] = match &self.store {
            Store::Dense(w) | Store::Diagonal(w) => w,
            Store::Banded { weights, .. } => weights,
        };
        self.guard.rebuild(words);
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn kind(&self) -> MemKind {
        self.kind
    }

    pub fn qspec(&self) -> QSpec {
        self.qspec
    }

    pub fn topology(&self) -> Topology {
        self.topology
    }

    pub fn writes(&self) -> u64 {
        self.writes
    }

    /// Flat view of the physical word vector.
    fn words(&self) -> &[i32] {
        match &self.store {
            Store::Dense(w) | Store::Diagonal(w) => w,
            Store::Banded { weights, .. } => weights,
        }
    }

    fn words_mut(&mut self) -> &mut [i32] {
        match &mut self.store {
            Store::Dense(w) | Store::Diagonal(w) => w,
            Store::Banded { weights, .. } => weights,
        }
    }

    /// Row `pre`'s stored window: (first column, range into the word
    /// vector). Every stored position of the row is inside this window.
    fn row_range(&self, pre: usize) -> (usize, std::ops::Range<usize>) {
        match &self.store {
            Store::Dense(_) => (0, pre * self.n..(pre + 1) * self.n),
            Store::Diagonal(_) => (pre, pre..pre + 1),
            Store::Banded { starts, offsets, .. } => {
                (starts[pre], offsets[pre]..offsets[pre + 1])
            }
        }
    }

    /// Storage slot of (pre, post), or `None` for pruned (α=0) positions.
    /// Callers must have bounds-checked `pre`/`post`.
    fn slot(&self, pre: usize, post: usize) -> Option<usize> {
        let (lo, range) = self.row_range(pre);
        if post >= lo && post < lo + range.len() {
            Some(range.start + (post - lo))
        } else {
            None
        }
    }

    /// α=1 synapse count == physical storage words. This is the number the
    /// resource/power models charge for: it is what the core is made of.
    pub fn synapses(&self) -> usize {
        self.words().len()
    }

    /// Physical words stored for row `pre` (the row's α=1 count).
    #[inline]
    pub fn row_synapses(&self, pre: usize) -> usize {
        self.row_range(pre).1.len()
    }

    /// Row `pre`'s stored column window as `(first column, width)` — every
    /// α=1 position of the row lies inside it. The packed ActGen uses this
    /// to bound its post-accumulation wrap pass to the columns any firing
    /// row could have touched.
    #[inline]
    pub fn row_window(&self, pre: usize) -> (usize, usize) {
        let (lo, range) = self.row_range(pre);
        (lo, range.len())
    }

    /// wt_in transaction: program one synaptic weight. Rejects out-of-range
    /// addresses, values that don't fit the Qn.q word, and writes to pruned
    /// (α=0) connections — which have no physical storage in the hardware.
    pub fn write(&mut self, pre: usize, post: usize, value: i32) -> Result<(), MemError> {
        if pre >= self.m || post >= self.n {
            return Err(MemError::BadAddress { pre, post, m: self.m, n: self.n });
        }
        if !self.qspec.in_range(value) {
            return Err(MemError::OutOfRange { value, q: self.qspec.name() });
        }
        match self.slot(pre, post) {
            Some(s) => {
                let old = self.words()[s];
                self.words_mut()[s] = value;
                self.guard.record_write(s, old, value);
                self.writes += 1;
                Ok(())
            }
            None => Err(MemError::Pruned { pre, post, topo: self.topology.label() }),
        }
    }

    /// Read one weight; pruned (α=0) positions read as hardwired zero.
    #[inline]
    pub fn read(&self, pre: usize, post: usize) -> Result<i32, MemError> {
        if pre >= self.m || post >= self.n {
            return Err(MemError::BadAddress { pre, post, m: self.m, n: self.n });
        }
        Ok(self.slot(pre, post).map_or(0, |s| self.words()[s]))
    }

    /// Row `pre`'s stored window as `(first column, weight words)` —
    /// zero-copy. This is the **one weight fetch per row** of the
    /// lane-batched ActGen ([`crate::hdl::Layer::step_lanes`]): the slice
    /// is read once and scattered into every active lane, so weight-memory
    /// traffic is amortized across the whole batch.
    #[inline]
    pub fn row_slice(&self, pre: usize) -> (usize, &[i32]) {
        let (lo, range) = self.row_range(pre);
        (lo, &self.words()[range])
    }

    /// One full row (all N post-synaptic weights of pre-neuron `pre`),
    /// materialized on demand with zeros at pruned positions — the dense
    /// view artifacts and inspection tools expect.
    pub fn row(&self, pre: usize) -> Vec<i32> {
        let mut out = Vec::new();
        self.row_into(pre, &mut out);
        out
    }

    /// As [`SynapticMemory::row`], but materializing into `buf` (cleared
    /// and resized to N) so repeated callers — row sweeps, [`dense`] —
    /// reuse one scratch allocation instead of building a fresh `Vec` per
    /// row.
    ///
    /// [`dense`]: SynapticMemory::dense
    pub fn row_into(&self, pre: usize, buf: &mut Vec<i32>) {
        assert!(pre < self.m, "row {pre} out of range for {} rows", self.m);
        buf.clear();
        buf.resize(self.n, 0);
        let (lo, range) = self.row_range(pre);
        buf[lo..lo + range.len()].copy_from_slice(&self.words()[range]);
    }

    /// Iterate row `pre`'s stored `(post, weight)` pairs — the O(row nnz)
    /// sparse view over the same window [`accumulate_row`] walks (which is
    /// what the ActGen hot loop calls); use this for inspection, artifact
    /// tooling, and the conformance suites.
    ///
    /// [`accumulate_row`]: SynapticMemory::accumulate_row
    pub fn row_nonzero(&self, pre: usize) -> RowNonzero<'_> {
        assert!(pre < self.m, "row {pre} out of range for {} rows", self.m);
        let (lo, range) = self.row_range(pre);
        RowNonzero { start: lo, k: 0, weights: &self.words()[range] }
    }

    /// Accumulate row `pre` into the activation registers with wrapping
    /// adds (the hardware ActGen accumulate), touching only stored
    /// positions. Returns the number of synaptic accumulates performed
    /// (the row's α=1 count). `act` must have N entries.
    #[inline]
    pub fn accumulate_row(&self, pre: usize, act: &mut [i32]) -> u64 {
        debug_assert_eq!(act.len(), self.n, "activation register arity");
        let (lo, range) = self.row_range(pre);
        let w = &self.words()[range];
        for (a, &wi) in act[lo..lo + w.len()].iter_mut().zip(w) {
            *a = a.wrapping_add(wi);
        }
        w.len() as u64
    }

    /// Bulk-load a full dense `[M × N]` matrix (the artifact weight files).
    /// Entries at pruned positions must be zero; others must fit Qn.q.
    /// Validates the whole payload before mutating (never partially
    /// applies).
    pub fn load_dense(&mut self, weights: &[i32]) -> Result<(), MemError> {
        if weights.len() != self.m * self.n {
            return Err(MemError::BulkSize { expect: self.m * self.n, got: weights.len() });
        }
        for (idx, &w) in weights.iter().enumerate() {
            let (pre, post) = (idx / self.n, idx % self.n);
            match self.slot(pre, post) {
                None => {
                    if w != 0 {
                        return Err(MemError::Pruned {
                            pre,
                            post,
                            topo: self.topology.label(),
                        });
                    }
                }
                Some(_) => {
                    if !self.qspec.in_range(w) {
                        return Err(MemError::OutOfRange { value: w, q: self.qspec.name() });
                    }
                }
            }
        }
        for i in 0..self.m {
            let (lo, range) = self.row_range(i);
            let src_lo = i * self.n + lo;
            let src = &weights[src_lo..src_lo + range.len()];
            self.words_mut()[range].copy_from_slice(src);
        }
        self.refresh_guard();
        self.writes += self.synapses() as u64;
        Ok(())
    }

    /// Bulk-load the packed per-topology payload: exactly [`synapses`]
    /// words in canonical order (row-major over stored positions — for the
    /// diagonal store that is the diagonal itself; for banded rows the
    /// concatenated windows). Rejects wrong sizes with the *packed* size in
    /// [`MemError::BulkSize`]'s `expect` field and out-of-range words
    /// without mutating.
    ///
    /// [`synapses`]: SynapticMemory::synapses
    pub fn load_packed(&mut self, packed: &[i32]) -> Result<(), MemError> {
        let expect = self.synapses();
        if packed.len() != expect {
            return Err(MemError::BulkSize { expect, got: packed.len() });
        }
        for &w in packed {
            if !self.qspec.in_range(w) {
                return Err(MemError::OutOfRange { value: w, q: self.qspec.name() });
            }
        }
        self.words_mut().copy_from_slice(packed);
        self.refresh_guard();
        self.writes += expect as u64;
        Ok(())
    }

    /// The packed physical payload (see [`SynapticMemory::load_packed`] for
    /// the canonical order). Zero-copy; `packed().len() == synapses()`.
    pub fn packed(&self) -> &[i32] {
        self.words()
    }

    /// The full dense `[M × N]` matrix, materialized on demand with zeros
    /// at pruned positions — what the artifact writers serialize. One
    /// output allocation; each row's stored window is copied straight into
    /// place (row sweeps that want a per-row view should reuse a scratch
    /// buffer via [`SynapticMemory::row_into`] instead).
    pub fn dense(&self) -> Vec<i32> {
        let mut out = vec![0i32; self.m * self.n];
        for i in 0..self.m {
            let (lo, range) = self.row_range(i);
            let dst_lo = i * self.n + lo;
            let len = range.len();
            out[dst_lo..dst_lo + len].copy_from_slice(&self.words()[range]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q5_3;

    fn mem() -> SynapticMemory {
        SynapticMemory::new(4, 3, Topology::AllToAll, Q5_3, MemKind::Bram)
    }

    #[test]
    fn write_read_roundtrip() {
        let mut m = mem();
        m.write(2, 1, -17).unwrap();
        assert_eq!(m.read(2, 1).unwrap(), -17);
        assert_eq!(m.read(0, 0).unwrap(), 0);
        assert_eq!(m.writes(), 1);
    }

    #[test]
    fn rejects_bad_address_value() {
        let mut m = mem();
        assert!(matches!(m.write(4, 0, 1), Err(MemError::BadAddress { .. })));
        assert!(matches!(m.write(0, 3, 1), Err(MemError::BadAddress { .. })));
        assert!(matches!(m.write(0, 0, 400), Err(MemError::OutOfRange { .. })));
        assert!(matches!(m.read(9, 9), Err(MemError::BadAddress { .. })));
    }

    #[test]
    fn pruned_connections_have_no_storage() {
        let mut m = SynapticMemory::new(3, 3, Topology::OneToOne, Q5_3, MemKind::Bram);
        assert!(m.write(0, 0, 5).is_ok());
        assert!(matches!(m.write(0, 1, 5), Err(MemError::Pruned { .. })));
        assert_eq!(m.synapses(), 3);
    }

    #[test]
    fn load_dense_validates() {
        let mut m = SynapticMemory::new(2, 2, Topology::OneToOne, Q5_3, MemKind::Bram);
        assert!(m.load_dense(&[1, 0, 0, 2]).is_ok());
        assert!(matches!(m.load_dense(&[1, 9, 0, 2]), Err(MemError::Pruned { .. })));
        assert!(matches!(m.load_dense(&[1, 0, 0]), Err(MemError::BulkSize { .. })));
        assert!(matches!(m.load_dense(&[1, 0, 0, 4000]), Err(MemError::OutOfRange { .. })));
    }

    #[test]
    fn row_view() {
        let mut m = mem();
        m.write(1, 0, 3).unwrap();
        m.write(1, 2, -4).unwrap();
        assert_eq!(m.row(1), vec![3, 0, -4]);
    }

    #[test]
    fn row_into_reuses_buffer_and_matches_row() {
        // One scratch buffer swept over every row of every topology must
        // reproduce row() exactly, including stale-content overwrite.
        for topo in [Topology::AllToAll, Topology::OneToOne, Topology::Gaussian { radius: 1 }] {
            let mut m = SynapticMemory::new(6, 6, topo, Q5_3, MemKind::Bram);
            let payload: Vec<i32> = (0..m.synapses()).map(|k| (k as i32 % 7) - 3).collect();
            m.load_packed(&payload).unwrap();
            let mut buf = vec![99i32; 40]; // stale, oversized
            for pre in 0..6 {
                m.row_into(pre, &mut buf);
                assert_eq!(buf, m.row(pre), "{topo:?} row {pre}");
                // And the zero-copy window agrees with the dense row.
                let (lo, w) = m.row_slice(pre);
                assert_eq!(&buf[lo..lo + w.len()], w, "{topo:?} row {pre} window");
                assert!(buf[..lo].iter().chain(&buf[lo + w.len()..]).all(|&x| x == 0));
            }
        }
    }

    #[test]
    fn diagonal_store_is_n_words() {
        let mut m = SynapticMemory::new(4, 4, Topology::OneToOne, Q5_3, MemKind::Bram);
        assert_eq!(m.synapses(), 4);
        m.write(2, 2, 9).unwrap();
        assert_eq!(m.row(2), vec![0, 0, 9, 0]);
        assert_eq!(m.packed(), &[0, 0, 9, 0]);
        assert_eq!(m.row_nonzero(2).collect::<Vec<_>>(), vec![(2, 9)]);
        assert_eq!(m.row_synapses(2), 1);
    }

    #[test]
    fn banded_store_matches_mask() {
        // 6x6 radius-1 gaussian: tridiagonal, 3*6 - 2 = 16 words.
        let topo = Topology::Gaussian { radius: 1 };
        let mut m = SynapticMemory::new(6, 6, topo, Q5_3, MemKind::Bram);
        assert_eq!(m.synapses(), 16);
        let mask = topo.mask(6, 6).unwrap();
        for i in 0..6 {
            assert_eq!(
                m.row_synapses(i),
                mask[i * 6..(i + 1) * 6].iter().filter(|&&x| x == 1).count(),
                "row {i}"
            );
        }
        m.write(2, 1, -5).unwrap();
        m.write(2, 3, 7).unwrap();
        assert_eq!(m.row(2), vec![0, -5, 0, 7, 0, 0]);
        assert_eq!(m.read(2, 1).unwrap(), -5);
        assert_eq!(m.read(2, 5).unwrap(), 0); // pruned reads as zero
        assert_eq!(
            m.row_nonzero(2).collect::<Vec<_>>(),
            vec![(1, -5), (2, 0), (3, 7)]
        );
    }

    #[test]
    fn accumulate_row_equals_dense_row_add() {
        let topo = Topology::Gaussian { radius: 2 };
        let mut m = SynapticMemory::new(8, 8, topo, Q5_3, MemKind::Bram);
        let mask = topo.mask(8, 8).unwrap();
        let mut dense = vec![0i32; 64];
        for i in 0..8 {
            for j in 0..8 {
                if mask[i * 8 + j] == 1 {
                    let w = (i * 8 + j) as i32 % 11 - 5;
                    m.write(i, j, w).unwrap();
                    dense[i * 8 + j] = w;
                }
            }
        }
        for i in 0..8 {
            let mut act = vec![1i32; 8];
            let ops = m.accumulate_row(i, &mut act);
            let want: Vec<i32> = (0..8).map(|j| 1 + dense[i * 8 + j]).collect();
            assert_eq!(act, want, "row {i}");
            assert_eq!(ops, m.row_synapses(i) as u64);
        }
        assert_eq!(m.dense(), dense);
    }

    #[test]
    fn packed_roundtrip_all_topologies() {
        for topo in [
            Topology::AllToAll,
            Topology::OneToOne,
            Topology::Gaussian { radius: 1 },
        ] {
            let mut a = SynapticMemory::new(5, 5, topo, Q5_3, MemKind::Bram);
            let payload: Vec<i32> = (0..a.synapses()).map(|k| (k as i32 % 9) - 4).collect();
            a.load_packed(&payload).unwrap();
            assert_eq!(a.packed(), &payload[..], "{topo:?}");
            // dense -> load_dense into a twin -> identical packed view
            let mut b = SynapticMemory::new(5, 5, topo, Q5_3, MemKind::Bram);
            b.load_dense(&a.dense()).unwrap();
            assert_eq!(b.packed(), a.packed(), "{topo:?}");
            assert_eq!(b.writes(), a.synapses() as u64);
        }
    }

    #[test]
    fn bulk_size_reports_per_topology_payload() {
        // Regression: the packed path's BulkSize must carry the packed
        // (per-topology) size, not the dense M×N size.
        let mut d = SynapticMemory::new(8, 8, Topology::OneToOne, Q5_3, MemKind::Bram);
        assert_eq!(
            d.load_packed(&[1, 2, 3]).unwrap_err(),
            MemError::BulkSize { expect: 8, got: 3 }
        );
        let mut g = SynapticMemory::new(8, 8, Topology::Gaussian { radius: 1 }, Q5_3, MemKind::Bram);
        let nnz = g.synapses(); // 3*8 - 2
        assert_eq!(nnz, 22);
        assert_eq!(
            g.load_packed(&vec![0; nnz + 1]).unwrap_err(),
            MemError::BulkSize { expect: nnz, got: nnz + 1 }
        );
        // The dense path still reports the dense payload size.
        assert_eq!(
            g.load_dense(&[0; 3]).unwrap_err(),
            MemError::BulkSize { expect: 64, got: 3 }
        );
    }

    #[test]
    fn integrity_guard_tracks_every_write_path() {
        for topo in [Topology::AllToAll, Topology::OneToOne, Topology::Gaussian { radius: 1 }] {
            for mode in [IntegrityMode::Detect, IntegrityMode::Correct] {
                let mut m = SynapticMemory::new(6, 6, topo, Q5_3, MemKind::Bram);
                m.set_integrity(mode);
                assert_eq!(m.integrity_mode(), mode);
                let payload: Vec<i32> = (0..m.synapses()).map(|k| (k as i32 % 7) - 3).collect();
                m.load_packed(&payload).unwrap();
                m.write(2, 2, -9).unwrap();
                let dense = m.dense();
                m.load_dense(&dense).unwrap();
                let blocks = m.integrity_blocks();
                assert!(blocks > 0, "{topo:?} {mode:?}");
                let mut cursor = 0;
                assert!(m.scrub(&mut cursor, blocks).clean(), "{topo:?} {mode:?}");
            }
        }
    }

    #[test]
    fn integrity_flip_is_corrected_or_detected_by_scrub() {
        let mut m = mem();
        let payload: Vec<i32> = (0..m.synapses()).map(|k| (k as i32 % 9) - 4).collect();
        m.load_packed(&payload).unwrap();
        m.set_integrity(IntegrityMode::Correct);
        m.integrity_flip(7, 4);
        assert_ne!(m.packed(), &payload[..], "flip bypasses the guard");
        let mut cursor = 0;
        let out = m.scrub(&mut cursor, m.integrity_blocks());
        assert_eq!((out.corrected, out.detected), (1, 0));
        assert_eq!(m.packed(), &payload[..], "repaired in place");
        // Detect mode flags the same flip but cannot repair it.
        m.set_integrity(IntegrityMode::Detect);
        m.integrity_flip(2, 0);
        let mut cursor = 0;
        let out = m.scrub(&mut cursor, m.integrity_blocks());
        assert_eq!((out.corrected, out.detected), (0, 1));
    }
}
