//! Clock domains and activity accounting.
//!
//! QUANTISENC has two clocks (§II): `spk_clk` (the main design clock — one
//! edge per SNN timestep at the spike frequency f) and `mem_clk` (the
//! synaptic-memory/register clock; the address generator spends M mem_clk
//! cycles accumulating a fan-in-M activation, §III-A).
//!
//! [`ActivityStats`] is the toggle-rate ledger: the cycle-accurate layers
//! record how many accumulate operations actually fired (clock gating skips
//! pre-synaptic rows with no spike — "we gate the clock when there is no
//! input spike", §VI-E) and how many register toggles occurred. The power
//! model (`hwmodel::power`) converts this ledger into dynamic power the same
//! way the paper converts Vivado toggle rates.

/// Frequencies of the two clock domains.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClockConfig {
    /// Spike frequency f (Hz) — the paper sweeps 100 kHz … 1.2 MHz.
    pub spk_hz: f64,
    /// Memory clock (Hz) — 100 MHz in the paper's LIF characterisation.
    pub mem_hz: f64,
}

impl Default for ClockConfig {
    fn default() -> Self {
        // The paper's baseline operating point (§VI-D): 600 kHz spike clock
        // gives the best perf/W; mem_clk at 100 MHz (§VI-B).
        ClockConfig { spk_hz: 600_000.0, mem_hz: 100_000_000.0 }
    }
}

impl ClockConfig {
    /// mem_clk cycles available within one spk_clk period.
    pub fn mem_cycles_per_step(&self) -> f64 {
        self.mem_hz / self.spk_hz
    }
}

/// Activity ledger accumulated by the cycle-accurate simulation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ActivityStats {
    /// spk_clk edges simulated.
    pub spk_steps: u64,
    /// mem_clk cycles consumed by address generators (M per layer per step).
    pub mem_cycles: u64,
    /// Synaptic accumulates that actually fired (input spike present).
    /// Charged per *physical* (α=1) slot of the topology-aware store, so a
    /// Gaussian radius-1 row adds ≤ 2r+1 here, not N; per step,
    /// `synaptic_ops + gated_ops` equals the layer's stored synapse count.
    pub synaptic_ops: u64,
    /// Physical synaptic slots skipped by clock gating (no input spike).
    pub gated_ops: u64,
    /// Neuron vmem-register toggles.
    pub vmem_toggles: u64,
    /// Neuron datapath evaluations (one per neuron per step, refractory or not).
    pub neuron_updates: u64,
    /// Spikes emitted by neurons.
    pub spikes: u64,
}

impl ActivityStats {
    pub fn add(&mut self, other: &ActivityStats) {
        self.spk_steps += other.spk_steps;
        self.mem_cycles += other.mem_cycles;
        self.synaptic_ops += other.synaptic_ops;
        self.gated_ops += other.gated_ops;
        self.vmem_toggles += other.vmem_toggles;
        self.neuron_updates += other.neuron_updates;
        self.spikes += other.spikes;
    }

    /// Fraction of synaptic accumulate slots that were clock-gated away.
    pub fn gating_ratio(&self) -> f64 {
        let total = self.synaptic_ops + self.gated_ops;
        if total == 0 {
            0.0
        } else {
            self.gated_ops as f64 / total as f64
        }
    }

    /// Average spikes per neuron-step (drives Table X's power trend).
    pub fn spike_rate(&self) -> f64 {
        if self.neuron_updates == 0 {
            0.0
        } else {
            self.spikes as f64 / self.neuron_updates as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_operating_point() {
        let c = ClockConfig::default();
        assert_eq!(c.spk_hz, 600_000.0);
        assert_eq!(c.mem_hz, 100_000_000.0);
        assert!((c.mem_cycles_per_step() - 166.666).abs() < 1.0);
    }

    #[test]
    fn stats_accumulate() {
        let mut a = ActivityStats { spk_steps: 1, synaptic_ops: 10, gated_ops: 30, ..Default::default() };
        let b = ActivityStats { spk_steps: 2, synaptic_ops: 5, spikes: 7, ..Default::default() };
        a.add(&b);
        assert_eq!(a.spk_steps, 3);
        assert_eq!(a.synaptic_ops, 15);
        assert_eq!(a.spikes, 7);
    }

    #[test]
    fn gating_ratio() {
        let s = ActivityStats { synaptic_ops: 25, gated_ops: 75, ..Default::default() };
        assert_eq!(s.gating_ratio(), 0.75);
        assert_eq!(ActivityStats::default().gating_ratio(), 0.0);
    }

    #[test]
    fn spike_rate() {
        let s = ActivityStats { neuron_updates: 100, spikes: 26, ..Default::default() };
        assert!((s.spike_rate() - 0.26).abs() < 1e-12);
    }
}
