//! Memory-integrity codes for the quantized state memories (SEU defence).
//!
//! QUANTISENC's state lives in distributed SRAMs — per-layer synaptic
//! memories plus the neuron-state register banks — and on real FPGA/ASIC
//! deployments those arrays are exactly where single-event upsets (SEUs)
//! silently corrupt inference. This module provides the two classic
//! word-level protection schemes, selected per [`IntegrityMode`]:
//!
//! * **Detect** — interleaved column parity: one `u32` per
//!   [`PARITY_BLOCK`]-word block holding the XOR of the block's words.
//!   Any single bit flip anywhere in the block flips exactly one bit of
//!   the XOR, so it is always detected (but cannot be located). Overhead
//!   is 1/32 ≈ 3% of the protected words.
//! * **Correct** — per-word SECDED, Hamming(38,32) plus an overall parity
//!   bit packed into one `u8` per word (6 Hamming check bits + 1 parity).
//!   Single-bit flips are located and repaired in place; double-bit flips
//!   are detected as uncorrectable. Overhead is 8/32 = 25%.
//!
//! Both schemes cover the full 32-bit storage word, so they protect any
//! Qn.q fixed-point format the core is configured with — the code does
//! not care where the binary point sits.
//!
//! [`Guard`] owns the code words for one flat `i32` bank and keeps them
//! consistent incrementally ([`Guard::record_write`]) or in bulk
//! ([`Guard::rebuild`]); [`Guard::scrub`] walks a bounded budget of
//! blocks per call with a wrapping cursor, which is how the serving
//! stage loop amortizes verification across sample-group boundaries.
//! [`Ledger`] is the thread-safe tally the serving engine aggregates
//! scrub activity into.

use std::sync::atomic::{AtomicU64, Ordering};

/// Words per parity block (and per scrub unit in both modes).
pub const PARITY_BLOCK: usize = 32;

/// Protection level for a state memory. `Off` is free; see the module
/// docs for the cost/coverage trade of the other two.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IntegrityMode {
    /// No codes stored, no checking (the pre-PR-10 behavior).
    #[default]
    Off,
    /// Interleaved block parity: every single-bit flip detected, none
    /// correctable — corruption quarantines the shard.
    Detect,
    /// Per-word SECDED: single-bit flips repaired in place, double-bit
    /// flips detected as uncorrectable.
    Correct,
}

impl IntegrityMode {
    /// Parse a CLI flag value (`off` / `detect` / `correct`).
    pub fn parse(s: &str) -> Option<IntegrityMode> {
        match s {
            "off" => Some(IntegrityMode::Off),
            "detect" => Some(IntegrityMode::Detect),
            "correct" => Some(IntegrityMode::Correct),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            IntegrityMode::Off => "off",
            IntegrityMode::Detect => "detect",
            IntegrityMode::Correct => "correct",
        }
    }
}

/// Codeword positions (1-indexed Hamming layout over positions `1..=38`)
/// assigned to the 32 data bits: every position that is not a power of
/// two, in ascending order. Powers of two hold the check bits.
const fn data_positions() -> [u32; 32] {
    let mut out = [0u32; 32];
    let mut pos = 1u32;
    let mut j = 0;
    while j < 32 {
        if pos & (pos - 1) != 0 {
            out[j] = pos;
            j += 1;
        }
        pos += 1;
    }
    out
}

const POS: [u32; 32] = data_positions();

/// Inverse map: codeword position → data bit index, or -1 for check-bit
/// positions. Indexed by syndrome value `1..=38`.
const fn position_bits() -> [i8; 39] {
    let mut out = [-1i8; 39];
    let mut j = 0;
    while j < 32 {
        out[POS[j] as usize] = j as i8;
        j += 1;
    }
    out
}

const POS_BIT: [i8; 39] = position_bits();

/// XOR of the codeword positions of the word's set data bits — equals
/// the 6 Hamming check bits the word should carry.
#[inline]
fn hamming_checks(word: u32) -> u32 {
    let mut syn = 0u32;
    let mut w = word;
    while w != 0 {
        let j = w.trailing_zeros() as usize;
        syn ^= POS[j];
        w &= w - 1;
    }
    syn
}

/// Encode the SECDED code byte for one 32-bit word: bits 0..=5 are the
/// Hamming check bits, bit 6 is the overall (even) parity over data +
/// check bits.
pub fn secded_encode(word: u32) -> u8 {
    let checks = hamming_checks(word);
    let parity = (word.count_ones() + checks.count_ones()) & 1;
    (checks | (parity << 6)) as u8
}

/// Outcome of checking one word against its SECDED code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WordVerdict {
    /// Word and code agree.
    Clean,
    /// A single bit flipped (in the word, a check bit, or the parity
    /// bit); carries the repaired data word. When the flip was outside
    /// the data bits the word is returned unchanged — the caller should
    /// still refresh the stored code.
    Corrected(u32),
    /// Two or more bits flipped — detected but not locatable.
    Uncorrectable,
}

/// Check one word against its code byte, locating single-bit errors.
pub fn secded_check(word: u32, code: u8) -> WordVerdict {
    let stored_checks = (code & 0x3f) as u32;
    let stored_parity = ((code >> 6) & 1) as u32;
    let syndrome = hamming_checks(word) ^ stored_checks;
    let parity_err = (word.count_ones() + stored_checks.count_ones() + stored_parity) & 1 != 0;
    match (syndrome, parity_err) {
        (0, false) => WordVerdict::Clean,
        // Only the overall parity bit flipped; data intact.
        (0, true) => WordVerdict::Corrected(word),
        (s, true) => {
            if let Some(&bit) = POS_BIT.get(s as usize) {
                if bit >= 0 {
                    WordVerdict::Corrected(word ^ (1u32 << bit))
                } else {
                    // A check-bit position flipped; data intact.
                    WordVerdict::Corrected(word)
                }
            } else {
                WordVerdict::Uncorrectable
            }
        }
        // Non-zero syndrome with even parity: double-bit error.
        (_, false) => WordVerdict::Uncorrectable,
    }
}

/// Which state memory an injected SEU ([`crate::hdl::Layer::integrity_flip`])
/// lands in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlipTarget {
    /// The layer's synaptic weight memory (any topology store).
    Weights,
    /// A membrane register (lane-major bank when the lane datapath is
    /// active, else the single-sample bank).
    Vmem,
    /// A refractory counter (same bank selection as `Vmem`).
    Refcnt,
}

/// Tally of one scrub pass (or one verified block).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScrubOutcome {
    /// Blocks whose codes were verified.
    pub checked_blocks: u64,
    /// Single-bit flips repaired in place (Correct mode only).
    pub corrected: u64,
    /// Uncorrectable corruption events: parity mismatches in Detect
    /// mode, double-bit SECDED errors in Correct mode.
    pub detected: u64,
}

impl ScrubOutcome {
    pub fn merge(&mut self, other: ScrubOutcome) {
        self.checked_blocks += other.checked_blocks;
        self.corrected += other.corrected;
        self.detected += other.detected;
    }

    /// True when nothing was corrected or detected.
    pub fn clean(&self) -> bool {
        self.corrected == 0 && self.detected == 0
    }
}

/// The integrity codes guarding one flat `i32` word bank. `Off` guards
/// store nothing and every operation is a no-op, so an un-enabled bank
/// pays only a branch.
#[derive(Debug, Clone, Default)]
pub struct Guard {
    mode: IntegrityMode,
    /// Detect: one XOR word per [`PARITY_BLOCK`]-word block.
    parity: Vec<u32>,
    /// Correct: one SECDED code byte per word.
    secded: Vec<u8>,
}

impl Guard {
    pub fn new(mode: IntegrityMode, words: &[i32]) -> Guard {
        let mut g = Guard { mode, ..Guard::default() };
        g.rebuild(words);
        g
    }

    pub fn mode(&self) -> IntegrityMode {
        self.mode
    }

    /// Recompute every code from scratch — the bulk-load / restore /
    /// resize path.
    pub fn rebuild(&mut self, words: &[i32]) {
        match self.mode {
            IntegrityMode::Off => {}
            IntegrityMode::Detect => {
                self.parity.clear();
                self.parity.resize(words.len().div_ceil(PARITY_BLOCK), 0);
                for (k, &w) in words.iter().enumerate() {
                    self.parity[k / PARITY_BLOCK] ^= w as u32;
                }
            }
            IntegrityMode::Correct => {
                self.secded.clear();
                self.secded.extend(words.iter().map(|&w| secded_encode(w as u32)));
            }
        }
    }

    /// Recompute the codes for an all-zero bank of `len` words without
    /// reading it — `secded_encode(0) == 0` and the XOR of zeros is zero,
    /// so both code vectors are just zero-filled. This keeps the
    /// per-sample `Layer::reset` cheap.
    pub fn rebuild_zeroed(&mut self, len: usize) {
        match self.mode {
            IntegrityMode::Off => {}
            IntegrityMode::Detect => {
                self.parity.clear();
                self.parity.resize(len.div_ceil(PARITY_BLOCK), 0);
            }
            IntegrityMode::Correct => {
                self.secded.clear();
                self.secded.resize(len, 0);
            }
        }
    }

    /// Incrementally account one word write (`old` → `new`) — O(1) for
    /// parity, one encode for SECDED.
    #[inline]
    pub fn record_write(&mut self, idx: usize, old: i32, new: i32) {
        match self.mode {
            IntegrityMode::Off => {}
            IntegrityMode::Detect => {
                self.parity[idx / PARITY_BLOCK] ^= (old as u32) ^ (new as u32)
            }
            IntegrityMode::Correct => self.secded[idx] = secded_encode(new as u32),
        }
    }

    /// Scrub units covering the guarded bank (0 when `Off`).
    pub fn blocks(&self) -> usize {
        match self.mode {
            IntegrityMode::Off => 0,
            IntegrityMode::Detect => self.parity.len(),
            IntegrityMode::Correct => self.secded.len().div_ceil(PARITY_BLOCK),
        }
    }

    /// Verify one block; in Correct mode single-bit flips are repaired
    /// in `words` and the stored code refreshed. `words` must be the
    /// bank the guard was built over.
    pub fn verify_block(&mut self, words: &mut [i32], block: usize) -> ScrubOutcome {
        let mut out = ScrubOutcome { checked_blocks: 1, ..ScrubOutcome::default() };
        let lo = block * PARITY_BLOCK;
        let hi = (lo + PARITY_BLOCK).min(words.len());
        match self.mode {
            IntegrityMode::Off => out.checked_blocks = 0,
            IntegrityMode::Detect => {
                let mut xor = 0u32;
                for &w in &words[lo..hi] {
                    xor ^= w as u32;
                }
                if xor != self.parity[block] {
                    out.detected += 1;
                }
            }
            IntegrityMode::Correct => {
                for idx in lo..hi {
                    match secded_check(words[idx] as u32, self.secded[idx]) {
                        WordVerdict::Clean => {}
                        WordVerdict::Corrected(fixed) => {
                            words[idx] = fixed as i32;
                            self.secded[idx] = secded_encode(fixed);
                            out.corrected += 1;
                        }
                        WordVerdict::Uncorrectable => out.detected += 1,
                    }
                }
            }
        }
        out
    }

    /// Verify up to `budget` blocks starting at `*cursor`, wrapping, and
    /// advance the cursor — the amortized background-scrub step. Covers
    /// each block at most once per call.
    pub fn scrub(&mut self, words: &mut [i32], cursor: &mut usize, budget: usize) -> ScrubOutcome {
        let nblocks = self.blocks();
        let mut out = ScrubOutcome::default();
        if nblocks == 0 || budget == 0 {
            return out;
        }
        for _ in 0..budget.min(nblocks) {
            if *cursor >= nblocks {
                *cursor = 0;
            }
            out.merge(self.verify_block(words, *cursor));
            *cursor += 1;
        }
        out
    }

    /// Verify (and repair) the whole bank in one pass.
    pub fn verify_all(&mut self, words: &mut [i32]) -> ScrubOutcome {
        let mut cursor = 0;
        let budget = self.blocks();
        self.scrub(words, &mut cursor, budget)
    }
}

/// Thread-safe scrub tally shared by every stage of a serving engine;
/// mirrored into `ServerStats` / `Telemetry` / the wire `Health` frame.
#[derive(Debug, Default)]
pub struct Ledger {
    scrubbed_blocks: AtomicU64,
    corrected: AtomicU64,
    detected: AtomicU64,
}

impl Ledger {
    pub fn absorb(&self, o: ScrubOutcome) {
        self.scrubbed_blocks.fetch_add(o.checked_blocks, Ordering::Relaxed);
        self.corrected.fetch_add(o.corrected, Ordering::Relaxed);
        self.detected.fetch_add(o.detected, Ordering::Relaxed);
    }

    /// Blocks verified by background scrubbing so far.
    pub fn scrubbed_blocks(&self) -> u64 {
        self.scrubbed_blocks.load(Ordering::Relaxed)
    }

    /// Single-bit flips repaired in place.
    pub fn corrected(&self) -> u64 {
        self.corrected.load(Ordering::Relaxed)
    }

    /// Uncorrectable corruption events (each one quarantines a shard).
    pub fn detected(&self) -> u64 {
        self.detected.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic LCG so property-style sweeps need no external crate.
    fn lcg(state: &mut u64) -> u32 {
        *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (*state >> 33) as u32
    }

    #[test]
    fn secded_roundtrip_is_clean() {
        let mut s = 0x5EED_u64;
        let mut words = vec![0u32, 1, u32::MAX, 0x8000_0000, 0xDEAD_BEEF];
        for _ in 0..200 {
            words.push(lcg(&mut s));
        }
        for w in words {
            assert_eq!(secded_check(w, secded_encode(w)), WordVerdict::Clean, "word {w:#x}");
        }
    }

    #[test]
    fn secded_corrects_every_single_bit_flip() {
        let mut s = 0xC0DE_u64;
        for _ in 0..50 {
            let w = lcg(&mut s);
            let code = secded_encode(w);
            for bit in 0..32 {
                let bad = w ^ (1u32 << bit);
                assert_eq!(
                    secded_check(bad, code),
                    WordVerdict::Corrected(w),
                    "word {w:#x} bit {bit}"
                );
            }
        }
    }

    #[test]
    fn secded_detects_double_bit_flips() {
        let mut s = 0xD0D0_u64;
        for _ in 0..50 {
            let w = lcg(&mut s);
            let code = secded_encode(w);
            let b1 = lcg(&mut s) % 32;
            let b2 = (b1 + 1 + lcg(&mut s) % 31) % 32;
            assert_ne!(b1, b2);
            let bad = w ^ (1u32 << b1) ^ (1u32 << b2);
            assert_eq!(secded_check(bad, code), WordVerdict::Uncorrectable, "word {w:#x}");
        }
    }

    #[test]
    fn parity_guard_detects_any_single_flip() {
        let mut s = 0xFA11_u64;
        let mut words: Vec<i32> = (0..100).map(|_| lcg(&mut s) as i32).collect();
        let mut g = Guard::new(IntegrityMode::Detect, &words);
        assert_eq!(g.blocks(), 4, "100 words -> 4 parity blocks");
        assert!(g.verify_all(&mut words).clean());
        for k in [0usize, 31, 32, 99] {
            for bit in [0u32, 13, 31] {
                words[k] ^= 1i32 << bit;
                let out = g.verify_all(&mut words);
                assert_eq!(out.detected, 1, "word {k} bit {bit}");
                assert_eq!(out.corrected, 0, "parity cannot correct");
                words[k] ^= 1i32 << bit; // undo; codes still match
                assert!(g.verify_all(&mut words).clean());
            }
        }
    }

    #[test]
    fn correct_guard_repairs_in_place() {
        let mut s = 0xFEED_u64;
        let mut words: Vec<i32> = (0..70).map(|_| lcg(&mut s) as i32).collect();
        let original = words.clone();
        let mut g = Guard::new(IntegrityMode::Correct, &words);
        assert_eq!(g.blocks(), 3);
        words[5] ^= 1 << 7;
        words[69] ^= 1 << 30;
        let out = g.verify_all(&mut words);
        assert_eq!(out.corrected, 2);
        assert_eq!(out.detected, 0);
        assert_eq!(words, original, "both flips repaired in place");
        assert!(g.verify_all(&mut words).clean());
        // A double flip in one word is detected, not mis-corrected.
        words[10] ^= (1 << 3) | (1 << 19);
        let out = g.verify_all(&mut words);
        assert_eq!(out.detected, 1);
        assert_eq!(words[10], original[10] ^ ((1 << 3) | (1 << 19)), "left untouched");
    }

    #[test]
    fn incremental_writes_match_rebuild() {
        for mode in [IntegrityMode::Detect, IntegrityMode::Correct] {
            let mut s = 0xAB1E_u64;
            let mut words: Vec<i32> = (0..64).map(|_| lcg(&mut s) as i32).collect();
            let mut g = Guard::new(mode, &words);
            for _ in 0..500 {
                let idx = lcg(&mut s) as usize % words.len();
                let new = lcg(&mut s) as i32;
                let old = words[idx];
                words[idx] = new;
                g.record_write(idx, old, new);
            }
            assert!(g.verify_all(&mut words).clean(), "{mode:?} codes stayed consistent");
            let fresh = Guard::new(mode, &words);
            assert_eq!(format!("{g:?}"), format!("{fresh:?}"), "{mode:?} equals rebuild");
        }
    }

    #[test]
    fn scrub_cursor_wraps_and_bounds_budget() {
        let mut words = vec![0i32; PARITY_BLOCK * 5];
        let mut g = Guard::new(IntegrityMode::Detect, &words);
        let mut cursor = 0usize;
        let out = g.scrub(&mut words, &mut cursor, 2);
        assert_eq!((out.checked_blocks, cursor), (2, 2));
        let out = g.scrub(&mut words, &mut cursor, 2);
        assert_eq!((out.checked_blocks, cursor), (2, 4));
        // Budget larger than the bank covers each block once, wrapping.
        let out = g.scrub(&mut words, &mut cursor, 100);
        assert_eq!(out.checked_blocks, 5);
        // A flip is found within one full sweep regardless of phase.
        words[PARITY_BLOCK * 3 + 7] ^= 1 << 2;
        let out = g.scrub(&mut words, &mut cursor, 5);
        assert_eq!(out.detected, 1);
    }

    #[test]
    fn rebuild_zeroed_matches_full_rebuild() {
        for mode in [IntegrityMode::Detect, IntegrityMode::Correct] {
            let mut zeros = vec![0i32; 77];
            let mut g = Guard::new(mode, &[1i32; 5]);
            g.rebuild_zeroed(zeros.len());
            assert!(g.verify_all(&mut zeros).clean(), "{mode:?}");
            assert_eq!(format!("{g:?}"), format!("{:?}", Guard::new(mode, &zeros)), "{mode:?}");
        }
    }

    #[test]
    fn off_guard_is_free_and_silent() {
        let mut words = vec![3i32; 40];
        let mut g = Guard::new(IntegrityMode::Off, &words);
        assert_eq!(g.blocks(), 0);
        words[0] ^= 1;
        let mut cursor = 9;
        assert_eq!(g.scrub(&mut words, &mut cursor, 8), ScrubOutcome::default());
        g.record_write(0, 3, words[0]);
        assert!(g.verify_all(&mut words).clean());
    }

    #[test]
    fn ledger_accumulates_outcomes() {
        let l = Ledger::default();
        l.absorb(ScrubOutcome { checked_blocks: 4, corrected: 1, detected: 0 });
        l.absorb(ScrubOutcome { checked_blocks: 2, corrected: 0, detected: 3 });
        assert_eq!((l.scrubbed_blocks(), l.corrected(), l.detected()), (6, 1, 3));
    }

    #[test]
    fn mode_parse_roundtrips_labels() {
        for mode in [IntegrityMode::Off, IntegrityMode::Detect, IntegrityMode::Correct] {
            assert_eq!(IntegrityMode::parse(mode.label()), Some(mode));
        }
        assert_eq!(IntegrityMode::parse("ecc"), None);
    }
}
