//! The LIF neuron datapath — paper Fig. 2 (VmemDyn, SpkGen, VmemSel blocks;
//! ActGen lives in [`super::layer`] because the accumulator walks the
//! layer's synaptic memory).
//!
//! One call to [`LifNeuron::step`] is one `spk_clk` edge. The update order
//! is the documented cross-language semantics (DESIGN.md §2):
//!
//! 1. refractory hold (counter > 0 ⇒ vmem held, no spike, counter--),
//! 2. VmemDyn: v' = v − decay·v + growth·act (wrapping Qn.q, Eq. 3),
//! 3. SpkGen: spike ⇔ v' ≥ Vth,
//! 4. VmemSel: reset per Eq. 7 and refractory arm on spike.

use crate::config::registers::{RegisterFile, ResetMode};
use crate::fixed::QSpec;

/// Decoded control registers, snapshotted once per timestep — the register
/// file's values don't change inside a step, so the per-neuron hot loop
/// reads this flat struct instead of going through the register file's
/// accessors (see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy)]
pub struct RegSnapshot {
    pub decay: i32,
    pub growth: i32,
    pub vth: i32,
    pub vreset: i32,
    pub mode: ResetMode,
    pub refractory: i32,
}

impl From<&RegisterFile> for RegSnapshot {
    fn from(r: &RegisterFile) -> RegSnapshot {
        RegSnapshot {
            decay: r.decay(),
            growth: r.growth(),
            vth: r.vth(),
            vreset: r.vreset(),
            mode: r.reset_mode(),
            refractory: r.refractory(),
        }
    }
}

/// Architectural state of one neuron (the two registers of Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LifNeuron {
    pub vmem: i32,
    pub refcnt: i32,
}

impl Default for LifNeuron {
    fn default() -> Self {
        LifNeuron { vmem: 0, refcnt: 0 }
    }
}

/// Outcome of one spk_clk step (spike bit + activity for the power model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepOut {
    pub spike: bool,
    /// Whether the vmem register toggled this cycle (clock-gating model:
    /// an unchanged register burns no dynamic energy).
    pub vmem_toggled: bool,
}

impl LifNeuron {
    pub fn new() -> LifNeuron {
        Self::default()
    }

    /// Reset to resting state (the pipeline's inter-stream settle, Fig. 8).
    pub fn reset(&mut self) {
        self.vmem = 0;
        self.refcnt = 0;
    }

    /// One spk_clk edge given this neuron's activation `act` (already
    /// accumulated by the layer's ActGen).
    #[inline]
    pub fn step(&mut self, act: i32, regs: &RegisterFile, qspec: QSpec) -> StepOut {
        self.step_snap(act, &RegSnapshot::from(regs), qspec)
    }

    /// Hot-path variant taking a pre-decoded register snapshot.
    #[inline]
    pub fn step_snap(&mut self, act: i32, regs: &RegSnapshot, qspec: QSpec) -> StepOut {
        step_soa(&mut self.vmem, &mut self.refcnt, act, regs, qspec)
    }
}

/// The LIF datapath on bare (vmem, refcnt) registers — the single
/// implementation behind both [`LifNeuron::step_snap`] and the layer's
/// struct-of-arrays neuron bank (`vmem[]`/`refcnt[]` slices), so the scalar
/// reference path and the packed event-driven path run bit-identical
/// arithmetic by construction.
#[inline]
pub fn step_soa(
    vmem: &mut i32,
    refcnt: &mut i32,
    act: i32,
    regs: &RegSnapshot,
    qspec: QSpec,
) -> StepOut {
    let old_vmem = *vmem;

    if *refcnt > 0 {
        // Refractory: hold vmem, suppress spiking, count down (§III-A.2).
        *refcnt -= 1;
        return StepOut { spike: false, vmem_toggled: false };
    }

    // VmemDyn (Eq. 3): v - decay*v + growth*act, all wrapping Qn.q.
    let dv = qspec.mul(regs.decay, *vmem);
    let gi = qspec.mul(regs.growth, act);
    let v_new = qspec.add(qspec.sub(*vmem, dv), gi);

    // SpkGen: threshold comparator.
    let spike = v_new >= regs.vth;

    // VmemSel (Eq. 7): reset mux + refractory arm.
    *vmem = if spike {
        *refcnt = regs.refractory;
        match regs.mode {
            ResetMode::Default => qspec.sub(v_new, qspec.mul(regs.decay, v_new)),
            ResetMode::ToZero => 0,
            ResetMode::BySubtraction => qspec.sub(v_new, regs.vth),
            ResetMode::ToConstant => regs.vreset,
        }
    } else {
        v_new
    };

    StepOut { spike, vmem_toggled: *vmem != old_vmem }
}

/// Per-lane outcome of one neuron's lane-batched step: bit `l` of each
/// word refers to lane `l` (mirroring the [`crate::hdl::SpikeMatrix`]
/// lane-word layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LaneStepOut {
    /// Lanes in which this neuron spiked.
    pub spikes: u64,
    /// Lanes in which the vmem register toggled.
    pub toggles: u64,
}

/// One spk_clk edge for a single neuron across up to 64 independent lanes
/// (samples): `vmem`/`refcnt`/`act` are the neuron's lane-major slices
/// (`slice[l]` = lane `l`'s register), and only lanes set in `active` are
/// evaluated — masked-out lanes (finished streams) keep their state
/// untouched and charge nothing. Each active lane runs the exact
/// [`step_soa`] datapath, with the same quiescence fast path the packed
/// single-sample hot loop uses (`hold` is the precomputed
/// [`quiescent_hold_range`]; the skip is re-checked against the full
/// datapath in debug builds), so every lane is bit-identical to a
/// single-sample run by construction.
#[inline]
pub fn step_soa_lanes(
    vmem: &mut [i32],
    refcnt: &mut [i32],
    act: &[i32],
    active: u64,
    hold: (i32, i32),
    regs: &RegSnapshot,
    qspec: QSpec,
) -> LaneStepOut {
    let mut out = LaneStepOut::default();
    let mut bits = active;
    while bits != 0 {
        let l = bits.trailing_zeros() as usize;
        bits &= bits - 1;
        let a = act[l];
        if a == 0 && refcnt[l] == 0 && vmem[l] >= hold.0 && vmem[l] <= hold.1 {
            #[cfg(debug_assertions)]
            {
                let (mut v2, mut r2) = (vmem[l], refcnt[l]);
                let o = step_soa(&mut v2, &mut r2, a, regs, qspec);
                debug_assert!(
                    !o.spike && !o.vmem_toggled && v2 == vmem[l] && r2 == 0,
                    "lane quiescence fast path diverged at lane {l} (vmem {})",
                    vmem[l]
                );
            }
            continue;
        }
        let o = step_soa(&mut vmem[l], &mut refcnt[l], a, regs, qspec);
        if o.spike {
            out.spikes |= 1 << l;
        }
        if o.vmem_toggled {
            out.toggles |= 1 << l;
        }
    }
    out
}

/// Inclusive `vmem` range `[lo, hi]` inside which a neuron with `act == 0`
/// and `refcnt == 0` is **provably inert** for one step: the full datapath
/// would leave `vmem` unchanged, emit no spike, and toggle no register.
/// The layer's packed hot path skips such neurons exactly
/// ([`crate::hdl::Layer::step_plane`]), and the skip is re-checked against
/// the real datapath by a `debug_assert` there.
///
/// Proof sketch (all ops are the wrapping Qn.q of [`QSpec`]):
/// with `act == 0`, `gi = mul(growth, 0) = 0` and
/// `v' = add(sub(v, mul(decay, v)), 0)`. If `0 <= decay·v <= 2^q − 1` the
/// arithmetic-shift truncation makes `mul(decay, v) == 0`, so
/// `v' = wrap(wrap(v)) = v` (stored vmem is always W-bit representable).
/// Requiring additionally `v < vth` makes the SpkGen comparator false, so
/// VmemSel passes `v'` through and the refractory counter stays 0. The
/// range is conservative (a wrapped product that lands on 0 also holds but
/// is not claimed) — neurons outside it simply take the full datapath.
pub fn quiescent_hold_range(regs: &RegSnapshot, qspec: QSpec) -> (i32, i32) {
    let max_prod: i64 = qspec.scale() - 1; // decay·v must stay in [0, 2^q − 1]
    let (lo, hi) = if regs.decay == 0 {
        (i32::MIN, i32::MAX)
    } else if regs.decay > 0 {
        (0, (max_prod / regs.decay as i64) as i32)
    } else {
        // decay < 0: 0 <= decay·v needs v <= 0; truncating division of a
        // positive by a negative yields -floor(max_prod/|decay|).
        ((max_prod / regs.decay as i64) as i32, 0)
    };
    if regs.vth == i32::MIN {
        return (1, 0); // no v satisfies v < vth: empty range
    }
    (lo, hi.min(regs.vth - 1))
}

/// Single-neuron dynamics probe — drives one neuron with a constant input
/// current for `steps` spk_clk cycles and records the membrane trace.
/// This regenerates the paper's Fig. 3 (R/C settings) and Fig. 4 (reset
/// mechanisms); also used by Table X's per-setting spike counts.
pub struct DynamicsProbe {
    pub qspec: QSpec,
    pub regs: RegisterFile,
}

#[derive(Debug, Clone)]
pub struct Trace {
    /// Membrane potential per step, in value units (Qn.q → float).
    pub vmem: Vec<f64>,
    pub spikes: Vec<bool>,
}

impl Trace {
    pub fn spike_count(&self) -> usize {
        self.spikes.iter().filter(|&&s| s).count()
    }
}

impl DynamicsProbe {
    pub fn new(qspec: QSpec, regs: RegisterFile) -> DynamicsProbe {
        DynamicsProbe { qspec, regs }
    }

    /// Apply a constant current `i_in` (value units) for `steps` cycles —
    /// the paper's "step input of 40 ms" with Δt = 1 ms per cycle.
    pub fn step_input(&self, i_in: f64, steps: usize) -> Trace {
        let act = self.qspec.from_float(i_in);
        let mut n = LifNeuron::new();
        let mut vmem = Vec::with_capacity(steps);
        let mut spikes = Vec::with_capacity(steps);
        for _ in 0..steps {
            let out = n.step(act, &self.regs, self.qspec);
            vmem.push(self.qspec.to_float(n.vmem));
            spikes.push(out.spike);
        }
        Trace { vmem, spikes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::registers::{RegisterFile, ResetMode};
    use crate::fixed::{Q5_3, Q9_7};

    fn regs(qs: crate::fixed::QSpec) -> RegisterFile {
        RegisterFile::new(qs)
    }

    #[test]
    fn silent_neuron_stays_at_rest() {
        let mut n = LifNeuron::new();
        let r = regs(Q5_3);
        for _ in 0..10 {
            let out = n.step(0, &r, Q5_3);
            assert!(!out.spike);
            assert_eq!(n.vmem, 0);
        }
    }

    #[test]
    fn decay_pulls_vmem_down() {
        let mut n = LifNeuron { vmem: 80, refcnt: 0 };
        let mut r = regs(Q5_3);
        r.set_decay(0.25).unwrap();
        r.set_vth(15.0).unwrap();
        n.step(0, &r, Q5_3);
        assert_eq!(n.vmem, 60); // 80 - 0.25*80
    }

    #[test]
    fn spike_and_reset_modes() {
        // act = 2.0 with vth = 1.0 fires; v_new = 16 raw (Q5.3).
        for (mode, expect) in [
            (ResetMode::ToZero, 0),
            (ResetMode::BySubtraction, 8),
            (ResetMode::ToConstant, Q5_3.from_float(0.5)),
            (ResetMode::Default, 16 - Q5_3.mul(Q5_3.from_float(0.2), 16)),
        ] {
            let mut n = LifNeuron::new();
            let mut r = regs(Q5_3);
            r.set_reset_mode(mode).unwrap();
            r.set_vreset(0.5).unwrap();
            let out = n.step(Q5_3.from_float(2.0), &r, Q5_3);
            assert!(out.spike);
            assert_eq!(n.vmem, expect, "{mode:?}");
        }
    }

    #[test]
    fn refractory_blocks_and_holds() {
        let mut n = LifNeuron::new();
        let mut r = regs(Q5_3);
        r.set_reset_mode(ResetMode::ToZero).unwrap();
        r.set_refractory(3).unwrap();
        let drive = Q5_3.from_float(2.0);
        let pattern: Vec<bool> = (0..8).map(|_| n.step(drive, &r, Q5_3).spike).collect();
        assert_eq!(pattern, vec![true, false, false, false, true, false, false, false]);
    }

    #[test]
    fn fig4_reset_ordering() {
        // Default ≥ subtraction ≥ zero spike counts over a step input.
        let mut counts = Vec::new();
        for mode in [ResetMode::Default, ResetMode::BySubtraction, ResetMode::ToZero] {
            let mut r = regs(Q9_7);
            r.set_vth(10.0).unwrap();
            r.set_reset_mode(mode).unwrap();
            let probe = DynamicsProbe::new(Q9_7, r);
            counts.push(probe.step_input(20.0, 40).spike_count());
        }
        assert!(counts[0] >= counts[1] && counts[1] >= counts[2]);
        assert!(counts[2] > 0);
    }

    #[test]
    fn fig3_rc_ordering() {
        // growth 1.0 / 0.2 / 0.1 / 0.02 (R = 500/100/50/10 MΩ at τ = 5 ms).
        let mut counts = Vec::new();
        for growth in [1.0, 0.2, 0.1, 0.02] {
            let mut r = regs(Q9_7);
            r.set_vth(10.0).unwrap();
            r.set_growth(growth).unwrap();
            let probe = DynamicsProbe::new(Q9_7, r);
            counts.push(probe.step_input(20.0, 40).spike_count());
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[2] && counts[2] >= counts[3]);
        assert_eq!(*counts.last().unwrap(), 0, "R=10MΩ must never cross Vth");
    }

    #[test]
    fn quiescent_hold_range_is_sound_exhaustively() {
        // Every Q5.3 vmem value inside the claimed hold range must be a
        // true fixed point of the zero-activation datapath: state, spike
        // output, and toggle flag all unchanged. Sweeps positive, zero and
        // negative raw decay (the latter only reachable via raw cfg_in
        // writes, but the fast path must stay sound there too) and low /
        // negative thresholds.
        let qs = Q5_3;
        for decay in [0i32, 1, 2, Q5_3.from_float(0.2), Q5_3.from_float(0.875), 127, -3, -128] {
            for vth in [Q5_3.from_float(1.0), 1, 0, -16, 127] {
                let snap = RegSnapshot {
                    decay,
                    growth: qs.from_float(1.0),
                    vth,
                    vreset: 0,
                    mode: ResetMode::Default,
                    refractory: 2,
                };
                let (lo, hi) = quiescent_hold_range(&snap, qs);
                for v in qs.min_raw()..=qs.max_raw() {
                    if v < lo || v > hi {
                        continue;
                    }
                    let (mut v2, mut r2) = (v, 0);
                    let out = step_soa(&mut v2, &mut r2, 0, &snap, qs);
                    assert!(
                        !out.spike && !out.vmem_toggled && v2 == v && r2 == 0,
                        "hold range unsound at v={v} decay={decay} vth={vth}"
                    );
                }
            }
        }
    }

    #[test]
    fn hold_range_excludes_threshold_crossers() {
        // A vmem sitting at/above vth is never claimed quiescent (it would
        // fire), and an empty range is returned for vth == i32::MIN.
        let qs = Q5_3;
        let snap = RegSnapshot {
            decay: 0,
            growth: 8,
            vth: 4,
            vreset: 0,
            mode: ResetMode::ToZero,
            refractory: 0,
        };
        let (lo, hi) = quiescent_hold_range(&snap, qs);
        assert!(lo <= hi && hi == 3, "decay 0 holds everything below vth: [{lo}, {hi}]");
        let snap = RegSnapshot { vth: i32::MIN, ..snap };
        let (lo, hi) = quiescent_hold_range(&snap, qs);
        assert!(lo > hi, "vth == i32::MIN must yield an empty hold range");
    }

    #[test]
    fn step_soa_lanes_matches_per_lane_step_soa() {
        // 64 lanes with distinct (vmem, refcnt, act) states: the lane-word
        // step must equal calling step_soa independently per lane, and
        // masked-out lanes must be left byte-identical.
        let qs = Q5_3;
        let snap = RegSnapshot {
            decay: qs.from_float(0.2),
            growth: qs.from_float(1.0),
            vth: qs.from_float(1.0),
            vreset: 0,
            mode: ResetMode::BySubtraction,
            refractory: 2,
        };
        let hold = quiescent_hold_range(&snap, qs);
        let lanes = 64usize;
        let mut vmem: Vec<i32> = (0..lanes).map(|l| (l as i32 * 5) % 40 - 10).collect();
        let mut refcnt: Vec<i32> = (0..lanes).map(|l| (l as i32) % 3).collect();
        let act: Vec<i32> = (0..lanes).map(|l| ((l as i32 * 7) % 30) - 6).collect();
        let active: u64 = 0xF0F0_F0F0_F0F0_F0F3;
        let (v0, r0) = (vmem.clone(), refcnt.clone());

        let mut want_spikes = 0u64;
        let mut want_toggles = 0u64;
        let mut want_v = v0.clone();
        let mut want_r = r0.clone();
        for l in 0..lanes {
            if (active >> l) & 1 == 0 {
                continue;
            }
            let o = step_soa(&mut want_v[l], &mut want_r[l], act[l], &snap, qs);
            if o.spike {
                want_spikes |= 1 << l;
            }
            if o.vmem_toggled {
                want_toggles |= 1 << l;
            }
        }

        let out = step_soa_lanes(&mut vmem, &mut refcnt, &act, active, hold, &snap, qs);
        assert_eq!(out.spikes, want_spikes);
        assert_eq!(out.toggles, want_toggles);
        assert_eq!(vmem, want_v);
        assert_eq!(refcnt, want_r);
        for l in 0..lanes {
            if (active >> l) & 1 == 0 {
                assert_eq!((vmem[l], refcnt[l]), (v0[l], r0[l]), "masked lane {l} mutated");
            }
        }
    }

    #[test]
    fn step_soa_lanes_inactive_mask_is_inert() {
        let qs = Q5_3;
        let snap = RegSnapshot::from(&regs(qs));
        let hold = quiescent_hold_range(&snap, qs);
        let mut vmem = vec![30i32; 4];
        let mut refcnt = vec![0i32; 4];
        let act = vec![qs.from_float(2.0); 4];
        let out = step_soa_lanes(&mut vmem, &mut refcnt, &act, 0, hold, &snap, qs);
        assert_eq!(out, LaneStepOut::default());
        assert_eq!(vmem, vec![30; 4]);
    }

    #[test]
    fn toggle_flag_tracks_vmem_change() {
        let mut n = LifNeuron::new();
        let r = regs(Q5_3);
        assert!(!n.step(0, &r, Q5_3).vmem_toggled);
        assert!(n.step(Q5_3.from_float(0.5), &r, Q5_3).vmem_toggled);
    }
}
