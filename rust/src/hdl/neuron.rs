//! The LIF neuron datapath — paper Fig. 2 (VmemDyn, SpkGen, VmemSel blocks;
//! ActGen lives in [`super::layer`] because the accumulator walks the
//! layer's synaptic memory).
//!
//! One call to [`LifNeuron::step`] is one `spk_clk` edge. The update order
//! is the documented cross-language semantics (DESIGN.md §2):
//!
//! 1. refractory hold (counter > 0 ⇒ vmem held, no spike, counter--),
//! 2. VmemDyn: v' = v − decay·v + growth·act (wrapping Qn.q, Eq. 3),
//! 3. SpkGen: spike ⇔ v' ≥ Vth,
//! 4. VmemSel: reset per Eq. 7 and refractory arm on spike.

use crate::config::registers::{RegisterFile, ResetMode};
use crate::fixed::QSpec;

/// Decoded control registers, snapshotted once per timestep — the register
/// file's values don't change inside a step, so the per-neuron hot loop
/// reads this flat struct instead of going through the register file's
/// accessors (see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy)]
pub struct RegSnapshot {
    pub decay: i32,
    pub growth: i32,
    pub vth: i32,
    pub vreset: i32,
    pub mode: ResetMode,
    pub refractory: i32,
}

impl From<&RegisterFile> for RegSnapshot {
    fn from(r: &RegisterFile) -> RegSnapshot {
        RegSnapshot {
            decay: r.decay(),
            growth: r.growth(),
            vth: r.vth(),
            vreset: r.vreset(),
            mode: r.reset_mode(),
            refractory: r.refractory(),
        }
    }
}

/// Architectural state of one neuron (the two registers of Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LifNeuron {
    pub vmem: i32,
    pub refcnt: i32,
}

impl Default for LifNeuron {
    fn default() -> Self {
        LifNeuron { vmem: 0, refcnt: 0 }
    }
}

/// Outcome of one spk_clk step (spike bit + activity for the power model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepOut {
    pub spike: bool,
    /// Whether the vmem register toggled this cycle (clock-gating model:
    /// an unchanged register burns no dynamic energy).
    pub vmem_toggled: bool,
}

impl LifNeuron {
    pub fn new() -> LifNeuron {
        Self::default()
    }

    /// Reset to resting state (the pipeline's inter-stream settle, Fig. 8).
    pub fn reset(&mut self) {
        self.vmem = 0;
        self.refcnt = 0;
    }

    /// One spk_clk edge given this neuron's activation `act` (already
    /// accumulated by the layer's ActGen).
    #[inline]
    pub fn step(&mut self, act: i32, regs: &RegisterFile, qspec: QSpec) -> StepOut {
        self.step_snap(act, &RegSnapshot::from(regs), qspec)
    }

    /// Hot-path variant taking a pre-decoded register snapshot.
    #[inline]
    pub fn step_snap(&mut self, act: i32, regs: &RegSnapshot, qspec: QSpec) -> StepOut {
        step_soa(&mut self.vmem, &mut self.refcnt, act, regs, qspec)
    }
}

/// The LIF datapath on bare (vmem, refcnt) registers — the single
/// implementation behind both [`LifNeuron::step_snap`] and the layer's
/// struct-of-arrays neuron bank (`vmem[]`/`refcnt[]` slices), so the scalar
/// reference path and the packed event-driven path run bit-identical
/// arithmetic by construction.
#[inline]
pub fn step_soa(
    vmem: &mut i32,
    refcnt: &mut i32,
    act: i32,
    regs: &RegSnapshot,
    qspec: QSpec,
) -> StepOut {
    let old_vmem = *vmem;

    if *refcnt > 0 {
        // Refractory: hold vmem, suppress spiking, count down (§III-A.2).
        *refcnt -= 1;
        return StepOut { spike: false, vmem_toggled: false };
    }

    // VmemDyn (Eq. 3): v - decay*v + growth*act, all wrapping Qn.q.
    let dv = qspec.mul(regs.decay, *vmem);
    let gi = qspec.mul(regs.growth, act);
    let v_new = qspec.add(qspec.sub(*vmem, dv), gi);

    // SpkGen: threshold comparator.
    let spike = v_new >= regs.vth;

    // VmemSel (Eq. 7): reset mux + refractory arm.
    *vmem = if spike {
        *refcnt = regs.refractory;
        match regs.mode {
            ResetMode::Default => qspec.sub(v_new, qspec.mul(regs.decay, v_new)),
            ResetMode::ToZero => 0,
            ResetMode::BySubtraction => qspec.sub(v_new, regs.vth),
            ResetMode::ToConstant => regs.vreset,
        }
    } else {
        v_new
    };

    StepOut { spike, vmem_toggled: *vmem != old_vmem }
}

/// Per-lane outcome of one neuron's lane-batched step: bit `l` of each
/// word refers to lane `l` (mirroring the [`crate::hdl::SpikeMatrix`]
/// lane-word layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LaneStepOut {
    /// Lanes in which this neuron spiked.
    pub spikes: u64,
    /// Lanes in which the vmem register toggled.
    pub toggles: u64,
}

/// One spk_clk edge for a single neuron across up to 64 independent lanes
/// (samples): `vmem`/`refcnt`/`act` are the neuron's lane-major slices
/// (`slice[l]` = lane `l`'s register), and only lanes set in `active` are
/// evaluated — masked-out lanes (finished streams) keep their state
/// untouched and charge nothing. Each active lane runs the exact
/// [`step_soa`] datapath, with the same quiescence fast path the packed
/// single-sample hot loop uses (`hold` is the precomputed
/// [`quiescent_hold_range`]; the skip is re-checked against the full
/// datapath in debug builds), so every lane is bit-identical to a
/// single-sample run by construction.
#[inline]
pub fn step_soa_lanes(
    vmem: &mut [i32],
    refcnt: &mut [i32],
    act: &[i32],
    active: u64,
    hold: (i32, i32),
    regs: &RegSnapshot,
    qspec: QSpec,
) -> LaneStepOut {
    let mut out = LaneStepOut::default();
    let mut bits = active;
    while bits != 0 {
        let l = bits.trailing_zeros() as usize;
        bits &= bits - 1;
        let a = act[l];
        if a == 0 && refcnt[l] == 0 && vmem[l] >= hold.0 && vmem[l] <= hold.1 {
            #[cfg(debug_assertions)]
            {
                let (mut v2, mut r2) = (vmem[l], refcnt[l]);
                let o = step_soa(&mut v2, &mut r2, a, regs, qspec);
                debug_assert!(
                    !o.spike && !o.vmem_toggled && v2 == vmem[l] && r2 == 0,
                    "lane quiescence fast path diverged at lane {l} (vmem {})",
                    vmem[l]
                );
            }
            continue;
        }
        let o = step_soa(&mut vmem[l], &mut refcnt[l], a, regs, qspec);
        if o.spike {
            out.spikes |= 1 << l;
        }
        if o.vmem_toggled {
            out.toggles |= 1 << l;
        }
    }
    out
}

/// Which implementation services [`step_soa_lanes_with`] — the scalar
/// per-lane loop (always available; the conformance oracle) or one of the
/// x86-64 vector tiers that step 4 (SSE2) or 8 (AVX2) lanes per
/// instruction. The vector tiers compute the *full* datapath for every
/// active lane — including lanes the scalar path would skip via the
/// quiescence fast path — which is bit-identical because the skip is a
/// proven no-op ([`quiescent_hold_range`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneKernel {
    /// Per-lane scalar loop with the quiescence fast path.
    Scalar,
    /// 4 lanes per instruction (x86-64 baseline, no runtime detection
    /// needed).
    Sse2,
    /// 8 lanes per instruction (runtime `is_x86_feature_detected!`).
    Avx2,
}

#[cfg(target_arch = "x86_64")]
fn avx2_detected() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_detected() -> bool {
    false
}

impl LaneKernel {
    /// Whether the vector tiers are exact for `qspec`: every stored
    /// register/weight/vmem value of a W ≤ 16 spec fits in i16, so all
    /// Qn.q products fit a 32-bit SIMD lane exactly and the wrap formula
    /// never overflows i32. Q17.15 (W = 32) needs i64 products and takes
    /// the scalar path.
    pub fn simd_eligible(qspec: QSpec) -> bool {
        cfg!(target_arch = "x86_64") && qspec.width() <= 16
    }

    /// Widest kernel the running CPU supports for `qspec` (Scalar on
    /// non-x86 targets and for W > 16 specs).
    pub fn auto(qspec: QSpec) -> LaneKernel {
        if !Self::simd_eligible(qspec) {
            LaneKernel::Scalar
        } else if avx2_detected() {
            LaneKernel::Avx2
        } else {
            LaneKernel::Sse2
        }
    }

    /// True iff this kernel may legally run for `qspec` on this CPU.
    pub fn available(self, qspec: QSpec) -> bool {
        match self {
            LaneKernel::Scalar => true,
            LaneKernel::Sse2 => Self::simd_eligible(qspec),
            LaneKernel::Avx2 => Self::simd_eligible(qspec) && avx2_detected(),
        }
    }

    /// Lanes stepped per arithmetic instruction (1 for the scalar loop).
    pub fn lanes_per_op(self) -> usize {
        match self {
            LaneKernel::Scalar => 1,
            LaneKernel::Sse2 => 4,
            LaneKernel::Avx2 => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LaneKernel::Scalar => "scalar",
            LaneKernel::Sse2 => "sse2",
            LaneKernel::Avx2 => "avx2",
        }
    }
}

/// [`step_soa_lanes`] through an explicit kernel choice. An unavailable
/// kernel (wrong arch, W > 16, AVX2 absent) silently falls back to the
/// scalar loop — the result is bit-identical either way, so pinning a
/// kernel is a performance request, never a correctness hazard.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn step_soa_lanes_with(
    kernel: LaneKernel,
    vmem: &mut [i32],
    refcnt: &mut [i32],
    act: &[i32],
    active: u64,
    hold: (i32, i32),
    regs: &RegSnapshot,
    qspec: QSpec,
) -> LaneStepOut {
    match kernel {
        LaneKernel::Scalar => step_soa_lanes(vmem, refcnt, act, active, hold, regs, qspec),
        #[cfg(target_arch = "x86_64")]
        LaneKernel::Sse2 if LaneKernel::simd_eligible(qspec) => {
            // SAFETY: SSE2 is part of the x86_64 baseline ABI.
            unsafe { step_lanes_sse2(vmem, refcnt, act, active, hold, regs, qspec) }
        }
        #[cfg(target_arch = "x86_64")]
        LaneKernel::Avx2 if LaneKernel::Avx2.available(qspec) => {
            // SAFETY: `available` just confirmed AVX2 via runtime detection.
            unsafe { step_lanes_avx2(vmem, refcnt, act, active, hold, regs, qspec) }
        }
        _ => step_soa_lanes(vmem, refcnt, act, active, hold, regs, qspec),
    }
}

/// Vectorized [`step_soa_lanes`]: one spk_clk edge for a single neuron
/// across up to 64 lanes, 4–8 lanes per instruction, dispatching at
/// runtime to the widest available x86-64 tier (AVX2 → SSE2 → scalar; see
/// [`LaneKernel::auto`]). Bit-identical to the scalar loop in state,
/// spike bits, and toggle bits — `rust/tests/simd_parity.rs` is the
/// differential gate. Non-x86 targets and W > 16 specs take the scalar
/// fallback, so this is safe to call unconditionally.
#[inline]
pub fn step_soa_lanes_simd(
    vmem: &mut [i32],
    refcnt: &mut [i32],
    act: &[i32],
    active: u64,
    hold: (i32, i32),
    regs: &RegSnapshot,
    qspec: QSpec,
) -> LaneStepOut {
    step_soa_lanes_with(LaneKernel::auto(qspec), vmem, refcnt, act, active, hold, regs, qspec)
}

// --- x86-64 vector tiers ---------------------------------------------------
//
// Exactness argument (both tiers): `RegisterFile` validates every register
// into the W-bit range and the layer stores only wrapped W-bit values, so
// for W <= 16 every operand is in [-2^15, 2^15 - 1]. Hence
//   |a * b| <= 2^30            — the full product fits an i32 lane exactly,
//                                so a 32-bit low-half multiply IS the exact
//                                product and `>> q` (arithmetic) matches the
//                                scalar i64 shift;
//   |x + half| <= 2^30 + 2^15  — the wrap formula ((x + half) & mask) - half
//                                never overflows an i32 lane.
// The spike comparator `v_new >= vth` is computed as NOT(vth > v_new) so
// vth == i32::MIN (raw cfg writes can't produce it, but RegSnapshot is a
// plain struct) needs no vth - 1 rewrite. Reset mode and all registers are
// core-global, so the mode branch is scalar and uniform across lanes.

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{step_soa_lanes, LaneStepOut, RegSnapshot};
    use crate::config::registers::ResetMode;
    use crate::fixed::QSpec;
    use core::arch::x86_64::*;

    /// Low 32 bits of the four lanewise products — exact for W <= 16
    /// operands (see the module-level argument). SSE2 has no mullo_epi32
    /// (that's SSE4.1); emulate with two widening unsigned multiplies:
    /// the low 32 bits of an unsigned product equal the low 32 bits of
    /// the signed product mod 2^32.
    #[inline(always)]
    unsafe fn mullo_sse2(a: __m128i, b: __m128i) -> __m128i {
        let even = _mm_mul_epu32(a, b); // 64-bit products of lanes 0, 2
        let odd = _mm_mul_epu32(_mm_srli_si128(a, 4), _mm_srli_si128(b, 4)); // lanes 1, 3
        let even_lo = _mm_shuffle_epi32(even, 0b0000_1000); // [p0.lo, p2.lo, _, _]
        let odd_lo = _mm_shuffle_epi32(odd, 0b0000_1000); // [p1.lo, p3.lo, _, _]
        _mm_unpacklo_epi32(even_lo, odd_lo) // [p0, p1, p2, p3]
    }

    /// Lanewise `QSpec::wrap`: ((x + half) & mask) - half, exact in i32.
    #[inline(always)]
    unsafe fn wrap4(x: __m128i, half: __m128i, mask: __m128i) -> __m128i {
        _mm_sub_epi32(_mm_and_si128(_mm_add_epi32(x, half), mask), half)
    }

    /// `mask ? a : b` per 32-bit lane (mask lanes are all-ones/all-zeros).
    #[inline(always)]
    unsafe fn sel4(mask: __m128i, a: __m128i, b: __m128i) -> __m128i {
        _mm_or_si128(_mm_and_si128(mask, a), _mm_andnot_si128(mask, b))
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn step_lanes_sse2(
        vmem: &mut [i32],
        refcnt: &mut [i32],
        act: &[i32],
        active: u64,
        hold: (i32, i32),
        regs: &RegSnapshot,
        qspec: QSpec,
    ) -> LaneStepOut {
        debug_assert!(qspec.width() <= 16, "SSE2 tier requires W <= 16");
        let lanes = vmem.len();
        let w = qspec.width();
        let q_shift = _mm_cvtsi32_si128(qspec.q() as i32);
        let half = _mm_set1_epi32(1i32 << (w - 1));
        let wmask = _mm_set1_epi32(((1i64 << w) - 1) as i32);
        let decay = _mm_set1_epi32(regs.decay);
        let growth = _mm_set1_epi32(regs.growth);
        let vth = _mm_set1_epi32(regs.vth);
        let refr = _mm_set1_epi32(regs.refractory);
        let one = _mm_set1_epi32(1);
        let zero = _mm_setzero_si128();
        let all = _mm_set1_epi32(-1);

        let mut out = LaneStepOut::default();
        let mut base = 0usize;
        while base + 4 <= lanes {
            let abits = ((active >> base) & 0xF) as i32;
            if abits == 0 {
                base += 4;
                continue;
            }
            let amask = _mm_set_epi32(
                -((abits >> 3) & 1),
                -((abits >> 2) & 1),
                -((abits >> 1) & 1),
                -(abits & 1),
            );
            let vp = vmem.as_mut_ptr().add(base);
            let rp = refcnt.as_mut_ptr().add(base);
            let v_old = _mm_loadu_si128(vp as *const __m128i);
            let r_old = _mm_loadu_si128(rp as *const __m128i);
            let a_in = _mm_loadu_si128(act.as_ptr().add(base) as *const __m128i);

            // Refractory hold: vmem kept, spike suppressed, counter--.
            let hold_m = _mm_cmpgt_epi32(r_old, zero);

            // VmemDyn: v' = wrap(wrap(v - dv) + gi).
            let dv = wrap4(_mm_sra_epi32(mullo_sse2(decay, v_old), q_shift), half, wmask);
            let gi = wrap4(_mm_sra_epi32(mullo_sse2(growth, a_in), q_shift), half, wmask);
            let v1 = wrap4(_mm_sub_epi32(v_old, dv), half, wmask);
            let v_new = wrap4(_mm_add_epi32(v1, gi), half, wmask);

            // SpkGen: v_new >= vth == NOT(vth > v_new); held lanes never fire.
            let spike_m = _mm_andnot_si128(hold_m, _mm_xor_si128(_mm_cmpgt_epi32(vth, v_new), all));

            // VmemSel (Eq. 7): the reset mux, uniform across lanes.
            let v_reset = match regs.mode {
                ResetMode::Default => {
                    let dvn = wrap4(_mm_sra_epi32(mullo_sse2(decay, v_new), q_shift), half, wmask);
                    wrap4(_mm_sub_epi32(v_new, dvn), half, wmask)
                }
                ResetMode::ToZero => zero,
                ResetMode::BySubtraction => wrap4(_mm_sub_epi32(v_new, vth), half, wmask),
                ResetMode::ToConstant => _mm_set1_epi32(regs.vreset),
            };

            let v_step = sel4(hold_m, v_old, sel4(spike_m, v_reset, v_new));
            let r_step = sel4(hold_m, _mm_sub_epi32(r_old, one), sel4(spike_m, refr, r_old));

            // Masked-out lanes (finished streams) keep their state untouched.
            let v_fin = sel4(amask, v_step, v_old);
            let r_fin = sel4(amask, r_step, r_old);
            _mm_storeu_si128(vp as *mut __m128i, v_fin);
            _mm_storeu_si128(rp as *mut __m128i, r_fin);

            let toggle_m = _mm_xor_si128(_mm_cmpeq_epi32(v_fin, v_old), all);
            let sb = _mm_movemask_ps(_mm_castsi128_ps(spike_m)) as u64;
            let tb = _mm_movemask_ps(_mm_castsi128_ps(toggle_m)) as u64;
            out.spikes |= (sb & abits as u64) << base;
            out.toggles |= (tb & abits as u64) << base;
            base += 4;
        }
        if base < lanes {
            let tail_active = (active >> base) & ((1u64 << (lanes - base)) - 1);
            let t = step_soa_lanes(
                &mut vmem[base..],
                &mut refcnt[base..],
                &act[base..],
                tail_active,
                hold,
                regs,
                qspec,
            );
            out.spikes |= t.spikes << base;
            out.toggles |= t.toggles << base;
        }
        out
    }

    /// Lanewise wrap, 8 lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn wrap8(x: __m256i, half: __m256i, mask: __m256i) -> __m256i {
        _mm256_sub_epi32(_mm256_and_si256(_mm256_add_epi32(x, half), mask), half)
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn sel8(mask: __m256i, a: __m256i, b: __m256i) -> __m256i {
        _mm256_or_si256(_mm256_and_si256(mask, a), _mm256_andnot_si256(mask, b))
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn step_lanes_avx2(
        vmem: &mut [i32],
        refcnt: &mut [i32],
        act: &[i32],
        active: u64,
        hold: (i32, i32),
        regs: &RegSnapshot,
        qspec: QSpec,
    ) -> LaneStepOut {
        debug_assert!(qspec.width() <= 16, "AVX2 tier requires W <= 16");
        let lanes = vmem.len();
        let w = qspec.width();
        let q_shift = _mm_cvtsi32_si128(qspec.q() as i32);
        let half = _mm256_set1_epi32(1i32 << (w - 1));
        let wmask = _mm256_set1_epi32(((1i64 << w) - 1) as i32);
        let decay = _mm256_set1_epi32(regs.decay);
        let growth = _mm256_set1_epi32(regs.growth);
        let vth = _mm256_set1_epi32(regs.vth);
        let refr = _mm256_set1_epi32(regs.refractory);
        let one = _mm256_set1_epi32(1);
        let zero = _mm256_setzero_si256();
        let all = _mm256_set1_epi32(-1);

        let mut out = LaneStepOut::default();
        let mut base = 0usize;
        while base + 8 <= lanes {
            let abits = ((active >> base) & 0xFF) as i32;
            if abits == 0 {
                base += 8;
                continue;
            }
            let amask = _mm256_set_epi32(
                -((abits >> 7) & 1),
                -((abits >> 6) & 1),
                -((abits >> 5) & 1),
                -((abits >> 4) & 1),
                -((abits >> 3) & 1),
                -((abits >> 2) & 1),
                -((abits >> 1) & 1),
                -(abits & 1),
            );
            let vp = vmem.as_mut_ptr().add(base);
            let rp = refcnt.as_mut_ptr().add(base);
            let v_old = _mm256_loadu_si256(vp as *const __m256i);
            let r_old = _mm256_loadu_si256(rp as *const __m256i);
            let a_in = _mm256_loadu_si256(act.as_ptr().add(base) as *const __m256i);

            let hold_m = _mm256_cmpgt_epi32(r_old, zero);

            let dv = wrap8(
                _mm256_sra_epi32(_mm256_mullo_epi32(decay, v_old), q_shift),
                half,
                wmask,
            );
            let gi = wrap8(
                _mm256_sra_epi32(_mm256_mullo_epi32(growth, a_in), q_shift),
                half,
                wmask,
            );
            let v1 = wrap8(_mm256_sub_epi32(v_old, dv), half, wmask);
            let v_new = wrap8(_mm256_add_epi32(v1, gi), half, wmask);

            let spike_m =
                _mm256_andnot_si256(hold_m, _mm256_xor_si256(_mm256_cmpgt_epi32(vth, v_new), all));

            let v_reset = match regs.mode {
                ResetMode::Default => {
                    let dvn = wrap8(
                        _mm256_sra_epi32(_mm256_mullo_epi32(decay, v_new), q_shift),
                        half,
                        wmask,
                    );
                    wrap8(_mm256_sub_epi32(v_new, dvn), half, wmask)
                }
                ResetMode::ToZero => zero,
                ResetMode::BySubtraction => wrap8(_mm256_sub_epi32(v_new, vth), half, wmask),
                ResetMode::ToConstant => _mm256_set1_epi32(regs.vreset),
            };

            let v_step = sel8(hold_m, v_old, sel8(spike_m, v_reset, v_new));
            let r_step = sel8(hold_m, _mm256_sub_epi32(r_old, one), sel8(spike_m, refr, r_old));

            let v_fin = sel8(amask, v_step, v_old);
            let r_fin = sel8(amask, r_step, r_old);
            _mm256_storeu_si256(vp as *mut __m256i, v_fin);
            _mm256_storeu_si256(rp as *mut __m256i, r_fin);

            let toggle_m = _mm256_xor_si256(_mm256_cmpeq_epi32(v_fin, v_old), all);
            let sb = _mm256_movemask_ps(_mm256_castsi256_ps(spike_m)) as u32 as u64;
            let tb = _mm256_movemask_ps(_mm256_castsi256_ps(toggle_m)) as u32 as u64;
            out.spikes |= (sb & abits as u64) << base;
            out.toggles |= (tb & abits as u64) << base;
            base += 8;
        }
        if base < lanes {
            let tail_active = (active >> base) & ((1u64 << (lanes - base)) - 1);
            let t = step_soa_lanes(
                &mut vmem[base..],
                &mut refcnt[base..],
                &act[base..],
                tail_active,
                hold,
                regs,
                qspec,
            );
            out.spikes |= t.spikes << base;
            out.toggles |= t.toggles << base;
        }
        out
    }
}

#[cfg(target_arch = "x86_64")]
use x86::{step_lanes_avx2, step_lanes_sse2};

/// Inclusive `vmem` range `[lo, hi]` inside which a neuron with `act == 0`
/// and `refcnt == 0` is **provably inert** for one step: the full datapath
/// would leave `vmem` unchanged, emit no spike, and toggle no register.
/// The layer's packed hot path skips such neurons exactly
/// ([`crate::hdl::Layer::step_plane`]), and the skip is re-checked against
/// the real datapath by a `debug_assert` there.
///
/// Proof sketch (all ops are the wrapping Qn.q of [`QSpec`]):
/// with `act == 0`, `gi = mul(growth, 0) = 0` and
/// `v' = add(sub(v, mul(decay, v)), 0)`. If `0 <= decay·v <= 2^q − 1` the
/// arithmetic-shift truncation makes `mul(decay, v) == 0`, so
/// `v' = wrap(wrap(v)) = v` (stored vmem is always W-bit representable).
/// Requiring additionally `v < vth` makes the SpkGen comparator false, so
/// VmemSel passes `v'` through and the refractory counter stays 0. The
/// range is conservative (a wrapped product that lands on 0 also holds but
/// is not claimed) — neurons outside it simply take the full datapath.
pub fn quiescent_hold_range(regs: &RegSnapshot, qspec: QSpec) -> (i32, i32) {
    let max_prod: i64 = qspec.scale() - 1; // decay·v must stay in [0, 2^q − 1]
    let (lo, hi) = if regs.decay == 0 {
        (i32::MIN, i32::MAX)
    } else if regs.decay > 0 {
        (0, (max_prod / regs.decay as i64) as i32)
    } else {
        // decay < 0: 0 <= decay·v needs v <= 0; truncating division of a
        // positive by a negative yields -floor(max_prod/|decay|).
        ((max_prod / regs.decay as i64) as i32, 0)
    };
    if regs.vth == i32::MIN {
        return (1, 0); // no v satisfies v < vth: empty range
    }
    (lo, hi.min(regs.vth - 1))
}

/// Single-neuron dynamics probe — drives one neuron with a constant input
/// current for `steps` spk_clk cycles and records the membrane trace.
/// This regenerates the paper's Fig. 3 (R/C settings) and Fig. 4 (reset
/// mechanisms); also used by Table X's per-setting spike counts.
pub struct DynamicsProbe {
    pub qspec: QSpec,
    pub regs: RegisterFile,
}

#[derive(Debug, Clone)]
pub struct Trace {
    /// Membrane potential per step, in value units (Qn.q → float).
    pub vmem: Vec<f64>,
    pub spikes: Vec<bool>,
}

impl Trace {
    pub fn spike_count(&self) -> usize {
        self.spikes.iter().filter(|&&s| s).count()
    }
}

impl DynamicsProbe {
    pub fn new(qspec: QSpec, regs: RegisterFile) -> DynamicsProbe {
        DynamicsProbe { qspec, regs }
    }

    /// Apply a constant current `i_in` (value units) for `steps` cycles —
    /// the paper's "step input of 40 ms" with Δt = 1 ms per cycle.
    pub fn step_input(&self, i_in: f64, steps: usize) -> Trace {
        let act = self.qspec.from_float(i_in);
        let mut n = LifNeuron::new();
        let mut vmem = Vec::with_capacity(steps);
        let mut spikes = Vec::with_capacity(steps);
        for _ in 0..steps {
            let out = n.step(act, &self.regs, self.qspec);
            vmem.push(self.qspec.to_float(n.vmem));
            spikes.push(out.spike);
        }
        Trace { vmem, spikes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::registers::{RegisterFile, ResetMode};
    use crate::fixed::{Q5_3, Q9_7};

    fn regs(qs: crate::fixed::QSpec) -> RegisterFile {
        RegisterFile::new(qs)
    }

    #[test]
    fn silent_neuron_stays_at_rest() {
        let mut n = LifNeuron::new();
        let r = regs(Q5_3);
        for _ in 0..10 {
            let out = n.step(0, &r, Q5_3);
            assert!(!out.spike);
            assert_eq!(n.vmem, 0);
        }
    }

    #[test]
    fn decay_pulls_vmem_down() {
        let mut n = LifNeuron { vmem: 80, refcnt: 0 };
        let mut r = regs(Q5_3);
        r.set_decay(0.25).unwrap();
        r.set_vth(15.0).unwrap();
        n.step(0, &r, Q5_3);
        assert_eq!(n.vmem, 60); // 80 - 0.25*80
    }

    #[test]
    fn spike_and_reset_modes() {
        // act = 2.0 with vth = 1.0 fires; v_new = 16 raw (Q5.3).
        for (mode, expect) in [
            (ResetMode::ToZero, 0),
            (ResetMode::BySubtraction, 8),
            (ResetMode::ToConstant, Q5_3.from_float(0.5)),
            (ResetMode::Default, 16 - Q5_3.mul(Q5_3.from_float(0.2), 16)),
        ] {
            let mut n = LifNeuron::new();
            let mut r = regs(Q5_3);
            r.set_reset_mode(mode).unwrap();
            r.set_vreset(0.5).unwrap();
            let out = n.step(Q5_3.from_float(2.0), &r, Q5_3);
            assert!(out.spike);
            assert_eq!(n.vmem, expect, "{mode:?}");
        }
    }

    #[test]
    fn refractory_blocks_and_holds() {
        let mut n = LifNeuron::new();
        let mut r = regs(Q5_3);
        r.set_reset_mode(ResetMode::ToZero).unwrap();
        r.set_refractory(3).unwrap();
        let drive = Q5_3.from_float(2.0);
        let pattern: Vec<bool> = (0..8).map(|_| n.step(drive, &r, Q5_3).spike).collect();
        assert_eq!(pattern, vec![true, false, false, false, true, false, false, false]);
    }

    #[test]
    fn fig4_reset_ordering() {
        // Default ≥ subtraction ≥ zero spike counts over a step input.
        let mut counts = Vec::new();
        for mode in [ResetMode::Default, ResetMode::BySubtraction, ResetMode::ToZero] {
            let mut r = regs(Q9_7);
            r.set_vth(10.0).unwrap();
            r.set_reset_mode(mode).unwrap();
            let probe = DynamicsProbe::new(Q9_7, r);
            counts.push(probe.step_input(20.0, 40).spike_count());
        }
        assert!(counts[0] >= counts[1] && counts[1] >= counts[2]);
        assert!(counts[2] > 0);
    }

    #[test]
    fn fig3_rc_ordering() {
        // growth 1.0 / 0.2 / 0.1 / 0.02 (R = 500/100/50/10 MΩ at τ = 5 ms).
        let mut counts = Vec::new();
        for growth in [1.0, 0.2, 0.1, 0.02] {
            let mut r = regs(Q9_7);
            r.set_vth(10.0).unwrap();
            r.set_growth(growth).unwrap();
            let probe = DynamicsProbe::new(Q9_7, r);
            counts.push(probe.step_input(20.0, 40).spike_count());
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[2] && counts[2] >= counts[3]);
        assert_eq!(*counts.last().unwrap(), 0, "R=10MΩ must never cross Vth");
    }

    #[test]
    fn quiescent_hold_range_is_sound_exhaustively() {
        // Every Q5.3 vmem value inside the claimed hold range must be a
        // true fixed point of the zero-activation datapath: state, spike
        // output, and toggle flag all unchanged. Sweeps positive, zero and
        // negative raw decay (the latter only reachable via raw cfg_in
        // writes, but the fast path must stay sound there too) and low /
        // negative thresholds.
        let qs = Q5_3;
        for decay in [0i32, 1, 2, Q5_3.from_float(0.2), Q5_3.from_float(0.875), 127, -3, -128] {
            for vth in [Q5_3.from_float(1.0), 1, 0, -16, 127] {
                let snap = RegSnapshot {
                    decay,
                    growth: qs.from_float(1.0),
                    vth,
                    vreset: 0,
                    mode: ResetMode::Default,
                    refractory: 2,
                };
                let (lo, hi) = quiescent_hold_range(&snap, qs);
                for v in qs.min_raw()..=qs.max_raw() {
                    if v < lo || v > hi {
                        continue;
                    }
                    let (mut v2, mut r2) = (v, 0);
                    let out = step_soa(&mut v2, &mut r2, 0, &snap, qs);
                    assert!(
                        !out.spike && !out.vmem_toggled && v2 == v && r2 == 0,
                        "hold range unsound at v={v} decay={decay} vth={vth}"
                    );
                }
            }
        }
    }

    #[test]
    fn hold_range_excludes_threshold_crossers() {
        // A vmem sitting at/above vth is never claimed quiescent (it would
        // fire), and an empty range is returned for vth == i32::MIN.
        let qs = Q5_3;
        let snap = RegSnapshot {
            decay: 0,
            growth: 8,
            vth: 4,
            vreset: 0,
            mode: ResetMode::ToZero,
            refractory: 0,
        };
        let (lo, hi) = quiescent_hold_range(&snap, qs);
        assert!(lo <= hi && hi == 3, "decay 0 holds everything below vth: [{lo}, {hi}]");
        let snap = RegSnapshot { vth: i32::MIN, ..snap };
        let (lo, hi) = quiescent_hold_range(&snap, qs);
        assert!(lo > hi, "vth == i32::MIN must yield an empty hold range");
    }

    #[test]
    fn step_soa_lanes_matches_per_lane_step_soa() {
        // 64 lanes with distinct (vmem, refcnt, act) states: the lane-word
        // step must equal calling step_soa independently per lane, and
        // masked-out lanes must be left byte-identical.
        let qs = Q5_3;
        let snap = RegSnapshot {
            decay: qs.from_float(0.2),
            growth: qs.from_float(1.0),
            vth: qs.from_float(1.0),
            vreset: 0,
            mode: ResetMode::BySubtraction,
            refractory: 2,
        };
        let hold = quiescent_hold_range(&snap, qs);
        let lanes = 64usize;
        let mut vmem: Vec<i32> = (0..lanes).map(|l| (l as i32 * 5) % 40 - 10).collect();
        let mut refcnt: Vec<i32> = (0..lanes).map(|l| (l as i32) % 3).collect();
        let act: Vec<i32> = (0..lanes).map(|l| ((l as i32 * 7) % 30) - 6).collect();
        let active: u64 = 0xF0F0_F0F0_F0F0_F0F3;
        let (v0, r0) = (vmem.clone(), refcnt.clone());

        let mut want_spikes = 0u64;
        let mut want_toggles = 0u64;
        let mut want_v = v0.clone();
        let mut want_r = r0.clone();
        for l in 0..lanes {
            if (active >> l) & 1 == 0 {
                continue;
            }
            let o = step_soa(&mut want_v[l], &mut want_r[l], act[l], &snap, qs);
            if o.spike {
                want_spikes |= 1 << l;
            }
            if o.vmem_toggled {
                want_toggles |= 1 << l;
            }
        }

        let out = step_soa_lanes(&mut vmem, &mut refcnt, &act, active, hold, &snap, qs);
        assert_eq!(out.spikes, want_spikes);
        assert_eq!(out.toggles, want_toggles);
        assert_eq!(vmem, want_v);
        assert_eq!(refcnt, want_r);
        for l in 0..lanes {
            if (active >> l) & 1 == 0 {
                assert_eq!((vmem[l], refcnt[l]), (v0[l], r0[l]), "masked lane {l} mutated");
            }
        }
    }

    #[test]
    fn step_soa_lanes_inactive_mask_is_inert() {
        let qs = Q5_3;
        let snap = RegSnapshot::from(&regs(qs));
        let hold = quiescent_hold_range(&snap, qs);
        let mut vmem = vec![30i32; 4];
        let mut refcnt = vec![0i32; 4];
        let act = vec![qs.from_float(2.0); 4];
        let out = step_soa_lanes(&mut vmem, &mut refcnt, &act, 0, hold, &snap, qs);
        assert_eq!(out, LaneStepOut::default());
        assert_eq!(vmem, vec![30; 4]);
    }

    #[test]
    fn lane_kernel_auto_is_available_and_scalar_for_wide_specs() {
        use crate::fixed::{Q17_15, Q3_1};
        for qs in [Q3_1, Q5_3, Q9_7] {
            let k = LaneKernel::auto(qs);
            assert!(k.available(qs), "auto kernel {k:?} must be runnable for {qs}");
        }
        assert_eq!(LaneKernel::auto(Q17_15), LaneKernel::Scalar, "W=32 needs i64 products");
        assert!(!LaneKernel::Sse2.available(Q17_15));
        assert!(LaneKernel::Scalar.available(Q17_15));
    }

    /// Every kernel tier (including unavailable ones, which must fall back)
    /// is bit-identical to the scalar loop on a state sweep that hits
    /// refractory holds, spikes, saturation extremes, and masked lanes, for
    /// every reset mode, lane count, and shipped narrow QSpec.
    #[test]
    fn simd_kernels_match_scalar_oracle() {
        use crate::fixed::Q3_1;
        let kernels = [LaneKernel::Scalar, LaneKernel::Sse2, LaneKernel::Avx2];
        for qs in [Q3_1, Q5_3, Q9_7] {
            for mode in [
                ResetMode::Default,
                ResetMode::ToZero,
                ResetMode::BySubtraction,
                ResetMode::ToConstant,
            ] {
                let snap = RegSnapshot {
                    decay: qs.from_float(0.2),
                    growth: qs.from_float(1.0),
                    vth: qs.from_float(1.0),
                    vreset: qs.from_float(-0.5),
                    mode,
                    refractory: 2,
                };
                let hold = quiescent_hold_range(&snap, qs);
                for lanes in [1usize, 3, 4, 5, 8, 37, 64] {
                    let (lo, hi) = (qs.min_raw(), qs.max_raw());
                    let vmem0: Vec<i32> = (0..lanes)
                        .map(|l| match l % 5 {
                            0 => lo,
                            1 => hi,
                            2 => 0,
                            3 => hi - (l as i32 % 7),
                            _ => lo + (l as i32 * 3) % 17,
                        })
                        .collect();
                    let refcnt0: Vec<i32> = (0..lanes).map(|l| (l as i32) % 4).collect();
                    let act: Vec<i32> = (0..lanes)
                        .map(|l| match l % 4 {
                            0 => 0,
                            1 => hi,
                            2 => lo,
                            _ => (l as i32 * 11) % 23 - 11,
                        })
                        .collect();
                    let active = if lanes == 64 {
                        0xF0F0_F0F0_F0F0_F0F3u64
                    } else {
                        ((1u64 << lanes) - 1) & 0xAAAA_AAAA_AAAA_AAAB
                    };

                    let (mut sv, mut sr) = (vmem0.clone(), refcnt0.clone());
                    let want =
                        step_soa_lanes(&mut sv, &mut sr, &act, active, hold, &snap, qs);
                    for k in kernels {
                        let (mut v, mut r) = (vmem0.clone(), refcnt0.clone());
                        let got = step_soa_lanes_with(
                            k, &mut v, &mut r, &act, active, hold, &snap, qs,
                        );
                        assert_eq!(got, want, "{k:?} {qs} {mode:?} lanes={lanes}");
                        assert_eq!(v, sv, "{k:?} {qs} {mode:?} lanes={lanes} vmem");
                        assert_eq!(r, sr, "{k:?} {qs} {mode:?} lanes={lanes} refcnt");
                    }
                    let (mut v, mut r) = (vmem0.clone(), refcnt0.clone());
                    let got =
                        step_soa_lanes_simd(&mut v, &mut r, &act, active, hold, &snap, qs);
                    assert_eq!(got, want, "auto-dispatch {qs} {mode:?} lanes={lanes}");
                    assert_eq!((v, r), (sv.clone(), sr.clone()));
                }
            }
        }
    }

    /// Multi-step parity: iterate the kernels over many steps so reset
    /// products, refractory wraps, and toggle accounting accumulate.
    #[test]
    fn simd_kernels_match_scalar_over_time() {
        let qs = Q9_7;
        let snap = RegSnapshot {
            decay: qs.from_float(0.2),
            growth: qs.from_float(1.0),
            vth: qs.from_float(1.0),
            vreset: 0,
            mode: ResetMode::BySubtraction,
            refractory: 3,
        };
        let hold = quiescent_hold_range(&snap, qs);
        let lanes = 37usize;
        let active = (1u64 << lanes) - 1;
        for k in [LaneKernel::Sse2, LaneKernel::Avx2] {
            let mut sv: Vec<i32> = (0..lanes).map(|l| (l as i32 * 97) % 256 - 128).collect();
            let mut sr = vec![0i32; lanes];
            let mut kv = sv.clone();
            let mut kr = sr.clone();
            for step in 0..220 {
                let act: Vec<i32> =
                    (0..lanes).map(|l| ((l + step) as i32 * 13) % 300 - 50).collect();
                let want = step_soa_lanes(&mut sv, &mut sr, &act, active, hold, &snap, qs);
                let got =
                    step_soa_lanes_with(k, &mut kv, &mut kr, &act, active, hold, &snap, qs);
                assert_eq!(got, want, "{k:?} diverged at step {step}");
                assert_eq!(kv, sv, "{k:?} vmem diverged at step {step}");
                assert_eq!(kr, sr, "{k:?} refcnt diverged at step {step}");
            }
        }
    }

    #[test]
    fn toggle_flag_tracks_vmem_change() {
        let mut n = LifNeuron::new();
        let r = regs(Q5_3);
        assert!(!n.step(0, &r, Q5_3).vmem_toggled);
        assert!(n.step(Q5_3.from_float(0.5), &r, Q5_3).vmem_toggled);
    }
}
