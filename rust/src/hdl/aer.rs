//! Address-Event Representation (AER) — the spk_in/spk_out encoding (§II).
//!
//! Each spike is one event `(timestep, neuron address)`; the stream is
//! ordered by timestep then address, which is what the spk_in interface
//! consumes and spk_out produces. Encode/decode between dense per-step
//! spike vectors and the event stream, with validation of malformed streams
//! (out-of-range addresses, unordered timestamps) — the failure-injection
//! tests exercise these paths.

use super::spikes::SpikePlane;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AerEvent {
    pub t: u32,
    pub addr: u32,
}

#[derive(Debug, PartialEq)]
pub enum AerError {
    BadAddress { addr: u32, width: usize },
    BadTime { t: u32, t_steps: usize },
    Unordered { index: usize, prev: (u32, u32), cur: (u32, u32) },
}

impl std::fmt::Display for AerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AerError::BadAddress { addr, width } => {
                write!(f, "event address {addr} out of range (layer width {width})")
            }
            AerError::BadTime { t, t_steps } => {
                write!(f, "event timestamp {t} out of range (stream has {t_steps} steps)")
            }
            AerError::Unordered { index, prev, cur } => {
                write!(f, "event stream not ordered at index {index} ({prev:?} then {cur:?})")
            }
        }
    }
}

impl std::error::Error for AerError {}

/// Dense row-major [T × N] spike matrix → ordered AER events.
pub fn encode(spikes: &[u8], t_steps: usize, width: usize) -> Vec<AerEvent> {
    assert_eq!(spikes.len(), t_steps * width);
    let mut out = Vec::new();
    for t in 0..t_steps {
        for i in 0..width {
            if spikes[t * width + i] != 0 {
                out.push(AerEvent { t: t as u32, addr: i as u32 });
            }
        }
    }
    out
}

/// The one validating walk over an event stream (shared by [`decode`] and
/// [`decode_planes`] so the two decoders can never diverge): checks
/// addresses, timestamps, and (t, addr) ordering, and hands each valid
/// event's `(t, addr)` to `sink`.
fn validate_events(
    events: &[AerEvent],
    t_steps: usize,
    width: usize,
    mut sink: impl FnMut(usize, usize),
) -> Result<(), AerError> {
    let mut prev: Option<(u32, u32)> = None;
    for (index, ev) in events.iter().enumerate() {
        if ev.addr as usize >= width {
            return Err(AerError::BadAddress { addr: ev.addr, width });
        }
        if ev.t as usize >= t_steps {
            return Err(AerError::BadTime { t: ev.t, t_steps });
        }
        if let Some(p) = prev {
            if (ev.t, ev.addr) < p {
                return Err(AerError::Unordered { index, prev: p, cur: (ev.t, ev.addr) });
            }
        }
        prev = Some((ev.t, ev.addr));
        sink(ev.t as usize, ev.addr as usize);
    }
    Ok(())
}

/// Ordered AER events → dense [T × N] spike matrix, with validation.
pub fn decode(events: &[AerEvent], t_steps: usize, width: usize) -> Result<Vec<u8>, AerError> {
    let mut out = vec![0u8; t_steps * width];
    validate_events(events, t_steps, width, |t, addr| out[t * width + addr] = 1)?;
    Ok(out)
}

/// Append timestep `t`'s firing addresses from a bit-packed plane —
/// [`SpikePlane::iter_ones`] yields ascending addresses, so a stream built
/// timestep-by-timestep is ordered by construction. This is the
/// event-driven spk_out path (`Device::infer_aer` streams output events
/// straight off the core's output plane): cost is O(events), never
/// O(width).
pub fn extend_from_plane(out: &mut Vec<AerEvent>, t: u32, plane: &SpikePlane) {
    for addr in plane.iter_ones() {
        out.push(AerEvent { t, addr: addr as u32 });
    }
}

/// Ordered AER events → bit-packed planes (one per timestep), with the
/// same validation as [`decode`] (one shared walk — see
/// `validate_events`).
pub fn decode_planes(
    events: &[AerEvent],
    t_steps: usize,
    width: usize,
) -> Result<Vec<SpikePlane>, AerError> {
    let mut out = vec![SpikePlane::new(width); t_steps];
    validate_events(events, t_steps, width, |t, addr| out[t].set(addr))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let spikes = vec![0, 1, 0, 1, 1, 0];
        let ev = encode(&spikes, 2, 3);
        assert_eq!(
            ev,
            vec![
                AerEvent { t: 0, addr: 1 },
                AerEvent { t: 1, addr: 0 },
                AerEvent { t: 1, addr: 1 }
            ]
        );
        assert_eq!(decode(&ev, 2, 3).unwrap(), spikes);
    }

    #[test]
    fn empty_stream() {
        assert_eq!(decode(&[], 2, 3).unwrap(), vec![0; 6]);
        assert!(encode(&vec![0; 6], 2, 3).is_empty());
    }

    #[test]
    fn rejects_malformed() {
        let bad_addr = [AerEvent { t: 0, addr: 9 }];
        assert!(matches!(decode(&bad_addr, 2, 3), Err(AerError::BadAddress { .. })));
        let bad_t = [AerEvent { t: 5, addr: 0 }];
        assert!(matches!(decode(&bad_t, 2, 3), Err(AerError::BadTime { .. })));
        let unordered = [AerEvent { t: 1, addr: 0 }, AerEvent { t: 0, addr: 0 }];
        assert!(matches!(decode(&unordered, 2, 3), Err(AerError::Unordered { .. })));
    }

    #[test]
    fn plane_codecs_match_byte_codecs() {
        let spikes = vec![0u8, 1, 0, 1, 1, 0, 0, 0, 1];
        let (t_steps, width) = (3, 3);
        let byte_ev = encode(&spikes, t_steps, width);
        let planes: Vec<SpikePlane> = (0..t_steps)
            .map(|t| SpikePlane::from_bytes(&spikes[t * width..(t + 1) * width]))
            .collect();
        // Re-encoding each decoded plane reproduces the stream (ordering by
        // construction), and decode agrees with the dense decoder.
        let decoded = decode_planes(&byte_ev, t_steps, width).unwrap();
        assert_eq!(decoded, planes);
        let mut re_encoded = Vec::new();
        for (t, p) in decoded.iter().enumerate() {
            extend_from_plane(&mut re_encoded, t as u32, p);
        }
        assert_eq!(re_encoded, byte_ev);
        // Same validation as the dense decoder (one shared walk).
        let bad = [AerEvent { t: 0, addr: 9 }];
        assert!(matches!(decode_planes(&bad, 2, 3), Err(AerError::BadAddress { .. })));
        let unordered = [AerEvent { t: 1, addr: 0 }, AerEvent { t: 0, addr: 0 }];
        assert!(matches!(decode_planes(&unordered, 2, 3), Err(AerError::Unordered { .. })));
    }

    #[test]
    fn event_count_equals_nnz() {
        use crate::datasets::{Dataset, Split};
        let s = Dataset::Smnist.sample(0, Split::Test, 8);
        let ev = encode(&s.spikes, s.t_steps, s.inputs);
        assert_eq!(ev.len(), s.nnz());
        assert_eq!(decode(&ev, s.t_steps, s.inputs).unwrap(), s.spikes);
    }
}
