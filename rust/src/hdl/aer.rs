//! Address-Event Representation (AER) — the spk_in/spk_out encoding (§II).
//!
//! Each spike is one event `(timestep, neuron address)`; the stream is
//! ordered by timestep then address, which is what the spk_in interface
//! consumes and spk_out produces. Encode/decode between dense per-step
//! spike vectors and the event stream, with validation of malformed streams
//! (out-of-range addresses, unordered timestamps) — the failure-injection
//! tests exercise these paths.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AerEvent {
    pub t: u32,
    pub addr: u32,
}

#[derive(Debug, PartialEq)]
pub enum AerError {
    BadAddress { addr: u32, width: usize },
    BadTime { t: u32, t_steps: usize },
    Unordered { index: usize, prev: (u32, u32), cur: (u32, u32) },
}

impl std::fmt::Display for AerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AerError::BadAddress { addr, width } => {
                write!(f, "event address {addr} out of range (layer width {width})")
            }
            AerError::BadTime { t, t_steps } => {
                write!(f, "event timestamp {t} out of range (stream has {t_steps} steps)")
            }
            AerError::Unordered { index, prev, cur } => {
                write!(f, "event stream not ordered at index {index} ({prev:?} then {cur:?})")
            }
        }
    }
}

impl std::error::Error for AerError {}

/// Dense row-major [T × N] spike matrix → ordered AER events.
pub fn encode(spikes: &[u8], t_steps: usize, width: usize) -> Vec<AerEvent> {
    assert_eq!(spikes.len(), t_steps * width);
    let mut out = Vec::new();
    for t in 0..t_steps {
        for i in 0..width {
            if spikes[t * width + i] != 0 {
                out.push(AerEvent { t: t as u32, addr: i as u32 });
            }
        }
    }
    out
}

/// Ordered AER events → dense [T × N] spike matrix, with validation.
pub fn decode(events: &[AerEvent], t_steps: usize, width: usize) -> Result<Vec<u8>, AerError> {
    let mut out = vec![0u8; t_steps * width];
    let mut prev: Option<(u32, u32)> = None;
    for (index, ev) in events.iter().enumerate() {
        if ev.addr as usize >= width {
            return Err(AerError::BadAddress { addr: ev.addr, width });
        }
        if ev.t as usize >= t_steps {
            return Err(AerError::BadTime { t: ev.t, t_steps });
        }
        if let Some(p) = prev {
            if (ev.t, ev.addr) < p {
                return Err(AerError::Unordered { index, prev: p, cur: (ev.t, ev.addr) });
            }
        }
        prev = Some((ev.t, ev.addr));
        out[ev.t as usize * width + ev.addr as usize] = 1;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let spikes = vec![0, 1, 0, 1, 1, 0];
        let ev = encode(&spikes, 2, 3);
        assert_eq!(
            ev,
            vec![
                AerEvent { t: 0, addr: 1 },
                AerEvent { t: 1, addr: 0 },
                AerEvent { t: 1, addr: 1 }
            ]
        );
        assert_eq!(decode(&ev, 2, 3).unwrap(), spikes);
    }

    #[test]
    fn empty_stream() {
        assert_eq!(decode(&[], 2, 3).unwrap(), vec![0; 6]);
        assert!(encode(&vec![0; 6], 2, 3).is_empty());
    }

    #[test]
    fn rejects_malformed() {
        let bad_addr = [AerEvent { t: 0, addr: 9 }];
        assert!(matches!(decode(&bad_addr, 2, 3), Err(AerError::BadAddress { .. })));
        let bad_t = [AerEvent { t: 5, addr: 0 }];
        assert!(matches!(decode(&bad_t, 2, 3), Err(AerError::BadTime { .. })));
        let unordered = [AerEvent { t: 1, addr: 0 }, AerEvent { t: 0, addr: 0 }];
        assert!(matches!(decode(&unordered, 2, 3), Err(AerError::Unordered { .. })));
    }

    #[test]
    fn event_count_equals_nnz() {
        use crate::datasets::{Dataset, Split};
        let s = Dataset::Smnist.sample(0, Split::Test, 8);
        let ev = encode(&s.spikes, s.t_steps, s.inputs);
        assert_eq!(ev.len(), s.nnz());
        assert_eq!(decode(&ev, s.t_steps, s.inputs).unwrap(), s.spikes);
    }
}
