//! One hardware layer — N LIF neurons + their distributed synaptic memory +
//! the ActGen address generator (paper Fig. 1b / Fig. 2 ActGen box).
//!
//! Per spk_clk timestep the address generator walks the M pre-synaptic rows
//! (M mem_clk cycles). For each row with an input spike, every *stored*
//! synapse (i, j) adds w[i][j] into neuron j's act register — a *wrapping*
//! Qn.q add, exactly the hardware accumulator. The walk goes through the
//! topology-aware store ([`SynapticMemory::accumulate_row`]), so synaptic
//! work is O(row nnz), not O(N): a Gaussian radius-1 row touches ≤ 3
//! registers, a one-to-one row exactly 1. Rows without a spike are
//! clock-gated: the adds are skipped and only the gating ledger is charged
//! with the row's stored-synapse count (§VI-E "we gate the clock in the
//! design when there is no input spike"). `synaptic_ops + gated_ops` per
//! step therefore equals the layer's physical synapse count — the α=1
//! words — for every topology.

use crate::config::registers::RegisterFile;
use crate::config::{LayerConfig, MemKind};
use crate::fixed::QSpec;

use super::clock::ActivityStats;
use super::memory::SynapticMemory;
use super::neuron::LifNeuron;

#[derive(Debug, Clone)]
pub struct Layer {
    mem: SynapticMemory,
    neurons: Vec<LifNeuron>,
    qspec: QSpec,
    /// Scratch activation registers (one act_reg per neuron, Fig. 2).
    act: Vec<i32>,
}

impl Layer {
    pub fn new(cfg: &LayerConfig, qspec: QSpec, mem_kind: MemKind) -> Layer {
        Layer {
            mem: SynapticMemory::new(cfg.fan_in, cfg.neurons, cfg.topology, qspec, mem_kind),
            neurons: vec![LifNeuron::new(); cfg.neurons],
            qspec,
            act: vec![0; cfg.neurons],
        }
    }

    pub fn fan_in(&self) -> usize {
        self.mem.m()
    }

    pub fn neurons(&self) -> usize {
        self.mem.n()
    }

    pub fn memory(&self) -> &SynapticMemory {
        &self.mem
    }

    pub fn memory_mut(&mut self) -> &mut SynapticMemory {
        &mut self.mem
    }

    /// Bulk wt_in reprogramming: swap this layer's synaptic memory for a
    /// packed payload (exactly [`SynapticMemory::synapses`] words in stored
    /// order). Membrane state is untouched — the paper's run-time weight
    /// path programs memory while the neurons keep their dynamics. This is
    /// what a serving-engine stage applies when a control-plane program
    /// addresses its layer.
    pub fn load_packed(&mut self, packed: &[i32]) -> Result<(), super::memory::MemError> {
        self.mem.load_packed(packed)
    }

    pub fn neuron_state(&self, j: usize) -> LifNeuron {
        self.neurons[j]
    }

    pub fn vmem(&self) -> Vec<i32> {
        self.neurons.iter().map(|n| n.vmem).collect()
    }

    pub fn reset(&mut self) {
        for n in &mut self.neurons {
            n.reset();
        }
    }

    /// One spk_clk timestep. `spikes_in` has M entries (0/1);
    /// `spikes_out` is filled with N entries. Returns activity stats.
    pub fn step(&mut self, spikes_in: &[u8], spikes_out: &mut Vec<u8>) -> ActivityStats {
        self.step_with(spikes_in, spikes_out, None)
    }

    /// As [`Layer::step`], with explicit registers (per-core register file is
    /// borrowed by the core; `None` is only used in unit tests via the
    /// default register values).
    pub fn step_regs(
        &mut self,
        spikes_in: &[u8],
        spikes_out: &mut Vec<u8>,
        regs: &RegisterFile,
    ) -> ActivityStats {
        self.step_with(spikes_in, spikes_out, Some(regs))
    }

    fn step_with(
        &mut self,
        spikes_in: &[u8],
        spikes_out: &mut Vec<u8>,
        regs: Option<&RegisterFile>,
    ) -> ActivityStats {
        assert_eq!(spikes_in.len(), self.mem.m(), "fan-in mismatch");
        let default_regs;
        let regs = match regs {
            Some(r) => r,
            None => {
                default_regs = RegisterFile::new(self.qspec);
                &default_regs
            }
        };

        let m = self.mem.m();
        let n = self.mem.n();
        let mut stats = ActivityStats { spk_steps: 1, mem_cycles: m as u64, ..Default::default() };

        // --- ActGen: M mem_clk cycles over the weight rows.
        //
        // Hot path (see EXPERIMENTS.md §Perf): the hardware wraps the act
        // register after every add, but addition mod 2^W is associative, so
        // accumulating with plain i32 `wrapping_add` and wrapping once per
        // timestep is bit-identical — for W < 32 the partial sums provably
        // fit in i32 (M ≤ 2^15 rows × |w| < 2^15), and for W = 32 the i32
        // wraparound *is* the mod-2^32 semantics. Accumulation goes through
        // the topology-aware store: only stored (α=1) synapses are touched
        // and charged, so sparse topologies do O(nnz) work per active row.
        self.act.fill(0);
        for (i, &spk) in spikes_in.iter().enumerate() {
            if spk == 0 {
                // Clock-gated row: no accumulates happen; the ledger is
                // charged for the row's physical synapse slots only.
                stats.gated_ops += self.mem.row_synapses(i) as u64;
                continue;
            }
            stats.synaptic_ops += self.mem.accumulate_row(i, &mut self.act);
        }
        if self.qspec.width() < 32 {
            for a in &mut self.act {
                *a = self.qspec.wrap(*a as i64);
            }
        }

        // --- Neuron updates (VmemDyn/SpkGen/VmemSel), parallel across j.
        let snap = super::neuron::RegSnapshot::from(regs);
        spikes_out.clear();
        spikes_out.reserve(n);
        for j in 0..n {
            let out = self.neurons[j].step_snap(self.act[j], &snap, self.qspec);
            stats.neuron_updates += 1;
            if out.vmem_toggled {
                stats.vmem_toggles += 1;
            }
            if out.spike {
                stats.spikes += 1;
            }
            spikes_out.push(out.spike as u8);
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Topology;
    use crate::fixed::Q5_3;

    fn layer(m: usize, n: usize) -> Layer {
        let cfg = LayerConfig { fan_in: m, neurons: n, topology: Topology::AllToAll };
        Layer::new(&cfg, Q5_3, MemKind::Bram)
    }

    #[test]
    fn weighted_sum_drives_spike() {
        let mut l = layer(3, 1);
        // Weights 3+7 = 10 = vth 1.25 in raw ⇒ spike (vth default = 8 raw).
        l.memory_mut().write(0, 0, 3).unwrap();
        l.memory_mut().write(2, 0, 7).unwrap();
        let mut out = Vec::new();
        let stats = l.step(&[1, 0, 1], &mut out);
        assert_eq!(out, vec![1]);
        assert_eq!(stats.spikes, 1);
        assert_eq!(stats.mem_cycles, 3);
    }

    #[test]
    fn clock_gating_ledger() {
        let mut l = layer(4, 8);
        let mut out = Vec::new();
        let stats = l.step(&[1, 0, 0, 1], &mut out);
        assert_eq!(stats.synaptic_ops, 16); // 2 active rows × 8 neurons
        assert_eq!(stats.gated_ops, 16); // 2 gated rows × 8 neurons
        assert_eq!(stats.gating_ratio(), 0.5);
    }

    #[test]
    fn activation_wraps_like_hardware() {
        let mut l = layer(4, 1);
        for i in 0..4 {
            l.memory_mut().write(i, 0, 100).unwrap();
        }
        let mut out = Vec::new();
        l.step(&[1, 1, 1, 1], &mut out);
        // 400 wraps to -112 in 8 bits; growth 1.0 ⇒ vmem = wrap(400) raw…
        // (vmem must equal the wrapped activation, not saturate)
        assert_eq!(l.vmem()[0], Q5_3.wrap(400));
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn reset_clears_state() {
        let mut l = layer(2, 2);
        l.memory_mut().write(0, 0, 4).unwrap();
        let mut out = Vec::new();
        l.step(&[1, 1], &mut out);
        assert_ne!(l.vmem(), vec![0, 0]);
        l.reset();
        assert_eq!(l.vmem(), vec![0, 0]);
    }

    #[test]
    #[should_panic(expected = "fan-in mismatch")]
    fn input_arity_checked() {
        let mut l = layer(3, 1);
        let mut out = Vec::new();
        l.step(&[1, 0], &mut out);
    }

    #[test]
    fn sparse_topologies_charge_only_stored_synapses() {
        // One-to-one 4x4: 1 synapse per row.
        let cfg = LayerConfig { fan_in: 4, neurons: 4, topology: Topology::OneToOne };
        let mut l = Layer::new(&cfg, Q5_3, MemKind::Bram);
        let mut out = Vec::new();
        let stats = l.step(&[1, 0, 1, 0], &mut out);
        assert_eq!(stats.synaptic_ops, 2);
        assert_eq!(stats.gated_ops, 2);
        assert_eq!(stats.mem_cycles, 4);

        // Gaussian radius-1 6x6: tridiagonal, rows have 2/3/3/3/3/2 words.
        let cfg = LayerConfig { fan_in: 6, neurons: 6, topology: Topology::Gaussian { radius: 1 } };
        let mut l = Layer::new(&cfg, Q5_3, MemKind::Bram);
        let stats = l.step(&[1, 1, 1, 1, 1, 1], &mut out);
        assert_eq!(stats.synaptic_ops, 16);
        assert_eq!(stats.gated_ops, 0);
        let stats = l.step(&[0, 0, 0, 0, 0, 0], &mut out);
        assert_eq!(stats.synaptic_ops, 0);
        assert_eq!(stats.gated_ops, 16);
    }

    #[test]
    fn one_to_one_accumulates_diagonal_only() {
        let cfg = LayerConfig { fan_in: 3, neurons: 3, topology: Topology::OneToOne };
        let mut l = Layer::new(&cfg, Q5_3, MemKind::Bram);
        for i in 0..3 {
            l.memory_mut().write(i, i, 10).unwrap(); // 1.25 > vth 1.0
        }
        let mut out = Vec::new();
        let stats = l.step(&[0, 1, 0], &mut out);
        assert_eq!(out, vec![0, 1, 0]);
        assert_eq!(stats.synaptic_ops, 1);
    }
}
