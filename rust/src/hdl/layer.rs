//! One hardware layer — N LIF neurons + their distributed synaptic memory +
//! the ActGen address generator (paper Fig. 1b / Fig. 2 ActGen box).
//!
//! Per spk_clk timestep the address generator walks the M pre-synaptic rows
//! (M mem_clk cycles). For each row with an input spike, every *stored*
//! synapse (i, j) adds w[i][j] into neuron j's act register — a *wrapping*
//! Qn.q add, exactly the hardware accumulator. The walk goes through the
//! topology-aware store ([`SynapticMemory::accumulate_row`]), so synaptic
//! work is O(row nnz), not O(N). Rows without a spike are clock-gated: the
//! adds are skipped and only the gating ledger is charged with the row's
//! stored-synapse count (§VI-E "we gate the clock in the design when there
//! is no input spike"). `synaptic_ops + gated_ops` per step therefore
//! equals the layer's physical synapse count — the α=1 words — for every
//! topology.
//!
//! # Event-driven hot path
//!
//! The production datapath is **packed**: [`Layer::step_plane`] takes a
//! bit-packed [`SpikePlane`] and
//!
//! * iterates only the *firing* rows via `trailing_zeros` (O(popcount)
//!   instead of an O(M) branch-per-row scan),
//! * charges `gated_ops` in bulk from a per-row physical-synapse prefix
//!   sum built at construction (total α=1 words minus the firing rows'
//!   words — identical to summing the gated rows one by one),
//! * keeps the neuron bank in struct-of-arrays form (`vmem[]`/`refcnt[]`
//!   slices) and skips every neuron that is *provably inert* this step:
//!   `act == 0`, `refcnt == 0`, and `vmem` inside the decay fixed-point
//!   hold range below threshold
//!   ([`neuron::quiescent_hold_range`] — bit-identical by construction,
//!   re-checked against the full datapath by a `debug_assert`).
//!
//! The byte-slice API ([`Layer::step`]/[`Layer::step_regs`]) survives as a
//! thin adapter over scratch planes, and [`Layer::step_scalar`] retains the
//! dense reference walk (branch per row, full LIF update per neuron) as
//! the differential-testing and benchmarking baseline — the
//! `sparse_parity` suite proves the two paths bit-identical in vmem,
//! spikes, and activity ledgers across all topologies and Q formats.
//!
//! # Lane-batched datapath
//!
//! [`Layer::step_lanes`] steps up to 64 *independent samples* per call
//! over a [`SpikeMatrix`] (one `u64` lane-word per pre-synaptic line): any
//! line with a nonzero lane-word has its synaptic row fetched **once**
//! and scattered into every firing lane, so the dominant weight-memory
//! traffic is amortized across the whole batch — the software counterpart
//! of QUANTISENC streaming many samples through its layer pipeline while
//! each synaptic word is read once per spike (§V). Neuron state sits in a
//! lane-major SoA bank; per-lane activity ledgers and dynamics are
//! bit-identical to single-sample [`Layer::step_plane`] runs, including
//! masked-out (finished) lanes of ragged batches.

use crate::config::registers::RegisterFile;
use crate::config::{LayerConfig, MemKind};
use crate::fixed::QSpec;

use super::clock::ActivityStats;
use super::integrity::{FlipTarget, Guard, IntegrityMode, ScrubOutcome};
use super::memory::SynapticMemory;
use super::neuron::{self, LifNeuron, RegSnapshot};
use super::spikes::{SpikeMatrix, SpikePlane};

#[derive(Debug, Clone)]
pub struct Layer {
    mem: SynapticMemory,
    qspec: QSpec,
    /// Struct-of-arrays neuron bank: membrane registers…
    vmem: Vec<i32>,
    /// …and refractory counters, one lane per neuron (Fig. 2's two
    /// registers, laid out for the linear sweep of the hot loop).
    refcnt: Vec<i32>,
    /// Scratch activation registers (one act_reg per neuron, Fig. 2).
    act: Vec<i32>,
    /// Whether `act` holds residue from the previous step (lets a step with
    /// zero firing rows skip the O(N) clear entirely).
    act_dirty: bool,
    /// `row_words_prefix[i]` = physical (α=1) synapse words stored in rows
    /// `[0, i)`; the last entry is the layer's total word count. Charges the
    /// clock-gating ledger in bulk on the packed path.
    row_words_prefix: Vec<u64>,
    /// Lazily-built default register snapshot for `step`'s `None`-regs path
    /// (unit-driven layers) — built once, not per timestep.
    default_snap: Option<RegSnapshot>,
    /// Scratch planes backing the byte-slice adapter API.
    in_scratch: SpikePlane,
    out_scratch: SpikePlane,
    /// Lane-batched neuron bank, **lane-major** (`lane_vmem[j * lanes +
    /// l]` is neuron `j`'s membrane in lane `l`) so one neuron's lanes are
    /// contiguous for [`neuron::step_soa_lanes`]. Allocated on the first
    /// [`Layer::step_lanes`] call; `lanes == 0` until then.
    lanes: usize,
    lane_vmem: Vec<i32>,
    lane_refcnt: Vec<i32>,
    /// Lane-major activation registers (`[j * lanes + l]`), with the same
    /// dirty-flag clear protocol as the single-sample `act` scratch.
    lane_act: Vec<i32>,
    lane_act_dirty: bool,
    /// Lane-step kernel override: `Some(k)` pins the kernel (how the
    /// conformance suite builds scalar-vs-SIMD twins); `None` selects per
    /// step via the firing-rate-aware auto policy below. Purely a
    /// performance knob — every kernel is bit-identical.
    lane_kernel: Option<neuron::LaneKernel>,
    /// EMA of input spike density on the lane path (firing (line, lane)
    /// pairs over M × active lanes), driving the auto kernel policy:
    /// sparse streams stay on the scalar loop, whose per-lane quiescence
    /// skip does near-zero work per inert neuron, while dense streams take
    /// the widest vector tier.
    lane_density_ema: f32,
    /// SEU-integrity level for this layer's state memories. `Off` skips
    /// all code maintenance; otherwise the synaptic memory's guard lives
    /// in [`SynapticMemory`] and the four neuron-bank guards below are
    /// refreshed at every bank boundary (reset / restore / resize) —
    /// cheap, since banks are zeroed or bulk-copied exactly there.
    integrity: IntegrityMode,
    guard_vmem: Guard,
    guard_refcnt: Guard,
    guard_lane_vmem: Guard,
    guard_lane_refcnt: Guard,
    /// Wrapping scrub cursor over the synaptic memory's blocks (the
    /// neuron banks are small and verified in full per scrub call).
    scrub_cursor: usize,
}

/// EMA smoothing factor for the lane-path input-density estimate (1/8 —
/// a few steps of history, so one dense timestep doesn't flip a sparse
/// stream off its fast path).
const LANE_DENSITY_ALPHA: f32 = 0.125;

/// Auto-policy threshold: below ~2% input density the quiescence skip in
/// the scalar loop beats computing the full vector datapath for lanes
/// that provably cannot change.
const LANE_SIMD_MIN_DENSITY: f32 = 0.02;

impl Layer {
    pub fn new(cfg: &LayerConfig, qspec: QSpec, mem_kind: MemKind) -> Layer {
        let mem = SynapticMemory::new(cfg.fan_in, cfg.neurons, cfg.topology, qspec, mem_kind);
        let mut row_words_prefix = Vec::with_capacity(cfg.fan_in + 1);
        row_words_prefix.push(0u64);
        for i in 0..cfg.fan_in {
            let prev = *row_words_prefix.last().unwrap();
            row_words_prefix.push(prev + mem.row_synapses(i) as u64);
        }
        Layer {
            mem,
            qspec,
            vmem: vec![0; cfg.neurons],
            refcnt: vec![0; cfg.neurons],
            act: vec![0; cfg.neurons],
            act_dirty: false,
            row_words_prefix,
            default_snap: None,
            in_scratch: SpikePlane::default(),
            out_scratch: SpikePlane::default(),
            lanes: 0,
            lane_vmem: Vec::new(),
            lane_refcnt: Vec::new(),
            lane_act: Vec::new(),
            lane_act_dirty: false,
            lane_kernel: None,
            lane_density_ema: 0.0,
            integrity: IntegrityMode::Off,
            guard_vmem: Guard::default(),
            guard_refcnt: Guard::default(),
            guard_lane_vmem: Guard::default(),
            guard_lane_refcnt: Guard::default(),
            scrub_cursor: 0,
        }
    }

    /// Enable (or disable) SEU-integrity codes over the synaptic memory
    /// and all four neuron banks, rebuilding every code from the current
    /// contents. See [`crate::hdl::integrity`] for the mode semantics.
    pub fn set_integrity(&mut self, mode: IntegrityMode) {
        self.integrity = mode;
        self.mem.set_integrity(mode);
        self.refresh_bank_guards();
    }

    pub fn integrity_mode(&self) -> IntegrityMode {
        self.integrity
    }

    /// Rebuild the neuron-bank guards from the banks' current contents
    /// (bulk-restore boundary).
    fn refresh_bank_guards(&mut self) {
        self.guard_vmem = Guard::new(self.integrity, &self.vmem);
        self.guard_refcnt = Guard::new(self.integrity, &self.refcnt);
        self.guard_lane_vmem = Guard::new(self.integrity, &self.lane_vmem);
        self.guard_lane_refcnt = Guard::new(self.integrity, &self.lane_refcnt);
    }

    /// Re-code the neuron-bank guards for all-zero banks without reading
    /// them (reset / resize boundary).
    fn zero_bank_guards(&mut self) {
        self.guard_vmem.rebuild_zeroed(self.vmem.len());
        self.guard_refcnt.rebuild_zeroed(self.refcnt.len());
        self.guard_lane_vmem.rebuild_zeroed(self.lane_vmem.len());
        self.guard_lane_refcnt.rebuild_zeroed(self.lane_refcnt.len());
    }

    /// Verify the four neuron banks in full plus up to `budget` synaptic
    /// memory blocks (wrapping cursor — successive calls sweep the whole
    /// weight store). Correctable flips are repaired in place; the tally
    /// reports what happened. Only meaningful at a sample boundary, where
    /// the bank guards are freshly synced. No-op when integrity is off.
    pub fn scrub(&mut self, budget: usize) -> ScrubOutcome {
        if self.integrity == IntegrityMode::Off {
            return ScrubOutcome::default();
        }
        let mut out = self.guard_vmem.verify_all(&mut self.vmem);
        out.merge(self.guard_refcnt.verify_all(&mut self.refcnt));
        out.merge(self.guard_lane_vmem.verify_all(&mut self.lane_vmem));
        out.merge(self.guard_lane_refcnt.verify_all(&mut self.lane_refcnt));
        out.merge(self.mem.scrub(&mut self.scrub_cursor, budget));
        out
    }

    /// Flip one raw storage bit in the targeted state memory *without*
    /// updating the integrity codes — the SEU fault-injection hook.
    /// Neuron-bank flips land in the lane-major bank when the lane
    /// datapath has run, else in the single-sample bank; `word` wraps
    /// modulo the bank size and `bit` modulo 32.
    pub fn integrity_flip(&mut self, target: FlipTarget, word: usize, bit: u8) {
        fn flip(bank: &mut [i32], word: usize, bit: u8) {
            if !bank.is_empty() {
                let idx = word % bank.len();
                bank[idx] ^= 1i32 << (bit % 32);
            }
        }
        match target {
            FlipTarget::Weights => self.mem.integrity_flip(word, bit),
            FlipTarget::Vmem => {
                if self.lanes > 0 {
                    flip(&mut self.lane_vmem, word, bit);
                } else {
                    flip(&mut self.vmem, word, bit);
                }
            }
            FlipTarget::Refcnt => {
                if self.lanes > 0 {
                    flip(&mut self.lane_refcnt, word, bit);
                } else {
                    flip(&mut self.refcnt, word, bit);
                }
            }
        }
    }

    /// Pin the lane-step kernel, or `None` to restore the firing-rate-aware
    /// auto policy. An unavailable pinned kernel falls back to the scalar
    /// loop inside [`neuron::step_soa_lanes_with`]; either way the results
    /// are bit-identical, so this is a performance request, never a
    /// correctness hazard (the `simd_parity` suite pins twins through it).
    pub fn set_lane_kernel(&mut self, kernel: Option<neuron::LaneKernel>) {
        self.lane_kernel = kernel;
    }

    /// The current lane-kernel override (`None` = auto policy).
    pub fn lane_kernel(&self) -> Option<neuron::LaneKernel> {
        self.lane_kernel
    }

    pub fn fan_in(&self) -> usize {
        self.mem.m()
    }

    pub fn neurons(&self) -> usize {
        self.mem.n()
    }

    pub fn memory(&self) -> &SynapticMemory {
        &self.mem
    }

    pub fn memory_mut(&mut self) -> &mut SynapticMemory {
        &mut self.mem
    }

    /// Bulk wt_in reprogramming: swap this layer's synaptic memory for a
    /// packed payload (exactly [`SynapticMemory::synapses`] words in stored
    /// order). Membrane state is untouched — the paper's run-time weight
    /// path programs memory while the neurons keep their dynamics. This is
    /// what a serving-engine stage applies when a control-plane program
    /// addresses its layer.
    pub fn load_packed(&mut self, packed: &[i32]) -> Result<(), super::memory::MemError> {
        self.mem.load_packed(packed)
    }

    pub fn neuron_state(&self, j: usize) -> LifNeuron {
        LifNeuron { vmem: self.vmem[j], refcnt: self.refcnt[j] }
    }

    /// Borrow the membrane registers of the struct-of-arrays neuron bank —
    /// the zero-copy probe view (prefer this over [`Layer::vmem`]).
    pub fn vmem_slice(&self) -> &[i32] {
        &self.vmem
    }

    /// Membrane registers as a fresh `Vec` (allocating; kept for artifact
    /// writers and older callers — prefer [`Layer::vmem_slice`]).
    pub fn vmem(&self) -> Vec<i32> {
        self.vmem.clone()
    }

    /// Reset every membrane register to rest — the single-sample bank and
    /// (if allocated) every lane of the lane-batched bank.
    pub fn reset(&mut self) {
        self.vmem.fill(0);
        self.refcnt.fill(0);
        self.lane_vmem.fill(0);
        self.lane_refcnt.fill(0);
        if self.integrity != IntegrityMode::Off {
            self.zero_bank_guards();
        }
    }

    /// Current lane-bank width (0 until the first [`Layer::step_lanes`]).
    pub fn lane_width(&self) -> usize {
        self.lanes
    }

    /// Lane `lane`'s architectural state of neuron `j` (lane-batched bank).
    pub fn lane_neuron_state(&self, j: usize, lane: usize) -> LifNeuron {
        assert!(lane < self.lanes, "lane {lane} out of range for {} lanes", self.lanes);
        LifNeuron {
            vmem: self.lane_vmem[j * self.lanes + lane],
            refcnt: self.lane_refcnt[j * self.lanes + lane],
        }
    }

    /// Gather lane `lane`'s membrane registers out of the lane-major bank
    /// (allocating probe view for conformance tests — the lane twin of
    /// [`Layer::vmem_slice`]).
    pub fn lane_vmem(&self, lane: usize) -> Vec<i32> {
        assert!(lane < self.lanes, "lane {lane} out of range for {} lanes", self.lanes);
        (0..self.mem.n()).map(|j| self.lane_vmem[j * self.lanes + lane]).collect()
    }

    /// Borrow the refractory countdowns of the struct-of-arrays neuron
    /// bank — the snapshot twin of [`Layer::vmem_slice`].
    pub fn refcnt_slice(&self) -> &[i32] {
        &self.refcnt
    }

    /// Overwrite the single-sample neuron bank from a connectome section.
    /// Arity is the caller's contract: the snapshot decoder validates both
    /// banks against the layer width before anything reaches a stage.
    pub fn restore_state(&mut self, vmem: &[i32], refcnt: &[i32]) {
        assert_eq!(vmem.len(), self.vmem.len(), "vmem bank arity validated by decoder");
        assert_eq!(refcnt.len(), self.refcnt.len(), "refcnt bank arity validated by decoder");
        self.vmem.copy_from_slice(vmem);
        self.refcnt.copy_from_slice(refcnt);
        if self.integrity != IntegrityMode::Off {
            self.guard_vmem.rebuild(&self.vmem);
            self.guard_refcnt.rebuild(&self.refcnt);
        }
    }

    /// Export the lane-batched bank for a snapshot:
    /// `(width, lane-major vmem, lane-major refcnt)`. Width 0 means the
    /// lane datapath never ran on this layer.
    pub fn lane_state(&self) -> (usize, Vec<i32>, Vec<i32>) {
        (self.lanes, self.lane_vmem.clone(), self.lane_refcnt.clone())
    }

    /// Restore the lane-batched bank from a connectome section. The
    /// activity scratch is not architectural state — it is resized and
    /// zeroed, exactly as a fresh lane-bank sizing would leave it.
    pub fn restore_lanes(&mut self, lanes: usize, lane_vmem: &[i32], lane_refcnt: &[i32]) {
        let n = self.mem.n();
        assert_eq!(lane_vmem.len(), n * lanes, "lane vmem arity validated by decoder");
        assert_eq!(lane_refcnt.len(), n * lanes, "lane refcnt arity validated by decoder");
        self.lanes = lanes;
        self.lane_vmem.clear();
        self.lane_vmem.extend_from_slice(lane_vmem);
        self.lane_refcnt.clear();
        self.lane_refcnt.extend_from_slice(lane_refcnt);
        self.lane_act.clear();
        self.lane_act.resize(n * lanes, 0);
        self.lane_act_dirty = false;
        if self.integrity != IntegrityMode::Off {
            self.guard_lane_vmem.rebuild(&self.lane_vmem);
            self.guard_lane_refcnt.rebuild(&self.lane_refcnt);
        }
    }

    /// Size the lane-batched bank for `lanes` concurrent samples. Changing
    /// the width resets all lane state (a new batch geometry cannot
    /// continue old streams).
    fn ensure_lanes(&mut self, lanes: usize) {
        if self.lanes != lanes {
            let n = self.mem.n();
            self.lanes = lanes;
            self.lane_vmem.clear();
            self.lane_vmem.resize(n * lanes, 0);
            self.lane_refcnt.clear();
            self.lane_refcnt.resize(n * lanes, 0);
            self.lane_act.clear();
            self.lane_act.resize(n * lanes, 0);
            self.lane_act_dirty = false;
            if self.integrity != IntegrityMode::Off {
                self.guard_lane_vmem.rebuild_zeroed(self.lane_vmem.len());
                self.guard_lane_refcnt.rebuild_zeroed(self.lane_refcnt.len());
            }
        }
    }

    /// One spk_clk timestep. `spikes_in` has M entries (0/1);
    /// `spikes_out` is filled with N entries. Returns activity stats.
    pub fn step(&mut self, spikes_in: &[u8], spikes_out: &mut Vec<u8>) -> ActivityStats {
        self.step_with(spikes_in, spikes_out, None)
    }

    /// As [`Layer::step`], with explicit registers (per-core register file is
    /// borrowed by the core; `None` is only used in unit tests via the
    /// default register values).
    pub fn step_regs(
        &mut self,
        spikes_in: &[u8],
        spikes_out: &mut Vec<u8>,
        regs: &RegisterFile,
    ) -> ActivityStats {
        self.step_with(spikes_in, spikes_out, Some(regs))
    }

    /// Byte-slice adapter over the packed datapath: packs `spikes_in` into
    /// a recycled scratch plane, runs [`Layer::step_plane`], and expands the
    /// output plane back to 0/1 bytes. Zero allocation once the scratch
    /// planes have seen this layer's widths.
    fn step_with(
        &mut self,
        spikes_in: &[u8],
        spikes_out: &mut Vec<u8>,
        regs: Option<&RegisterFile>,
    ) -> ActivityStats {
        let snap = match regs {
            Some(r) => RegSnapshot::from(r),
            None => self.default_snapshot(),
        };
        self.in_scratch.load_bytes(spikes_in);
        let in_plane = std::mem::take(&mut self.in_scratch);
        let mut out_plane = std::mem::take(&mut self.out_scratch);
        let stats = self.step_plane_snap(&in_plane, &mut out_plane, &snap);
        spikes_out.clear();
        out_plane.append_bytes_to(spikes_out);
        self.in_scratch = in_plane;
        self.out_scratch = out_plane;
        stats
    }

    /// The default-register snapshot, built on first use and cached (this
    /// sits on the per-timestep path for unit-driven layers).
    fn default_snapshot(&mut self) -> RegSnapshot {
        if self.default_snap.is_none() {
            self.default_snap = Some(RegSnapshot::from(&RegisterFile::new(self.qspec)));
        }
        self.default_snap.unwrap()
    }

    /// One spk_clk timestep over packed planes — the event-driven hot path
    /// (see the module docs for what makes it fast). `spikes_in` must have
    /// M lines; `spikes_out` is resized to N lines with the firing neurons
    /// set. Bit-identical to [`Layer::step_scalar`] in dynamics *and*
    /// activity ledger.
    pub fn step_plane(
        &mut self,
        spikes_in: &SpikePlane,
        spikes_out: &mut SpikePlane,
        regs: &RegisterFile,
    ) -> ActivityStats {
        self.step_plane_snap(spikes_in, spikes_out, &RegSnapshot::from(regs))
    }

    fn step_plane_snap(
        &mut self,
        spikes_in: &SpikePlane,
        spikes_out: &mut SpikePlane,
        snap: &RegSnapshot,
    ) -> ActivityStats {
        assert_eq!(spikes_in.len(), self.mem.m(), "fan-in mismatch");
        let m = self.mem.m();
        let n = self.mem.n();
        let total_words = *self.row_words_prefix.last().unwrap();
        let mut stats = ActivityStats { spk_steps: 1, mem_cycles: m as u64, ..Default::default() };

        // --- ActGen, event-driven: visit only the firing rows (the
        // hardware's clock gating as control flow). Accumulation is the
        // same once-per-step wrapping scheme as the scalar reference (see
        // `step_scalar` for the associativity argument); gating is charged
        // in bulk: gated_ops = total α=1 words − the firing rows' words.
        if self.act_dirty {
            self.act.fill(0);
            self.act_dirty = false;
        }
        let mut syn = 0u64;
        let (mut touched_lo, mut touched_hi) = (usize::MAX, 0usize);
        for i in spikes_in.iter_ones() {
            let (lo, width) = self.mem.row_window(i);
            syn += self.mem.accumulate_row(i, &mut self.act);
            if width > 0 {
                touched_lo = touched_lo.min(lo);
                touched_hi = touched_hi.max(lo + width);
            }
        }
        if syn > 0 {
            self.act_dirty = true;
        }
        stats.synaptic_ops = syn;
        stats.gated_ops = total_words - syn;
        // Wrap only the column span the firing rows could have touched:
        // untouched act registers are zero by invariant and wrap(0) == 0,
        // so this is bit-identical to the scalar reference's full-width
        // wrap while costing O(touched) on sparse (banded/diagonal) rows.
        if self.qspec.width() < 32 && syn > 0 {
            for a in &mut self.act[touched_lo..touched_hi] {
                *a = self.qspec.wrap(*a as i64);
            }
        }

        // --- Neuron updates over the SoA bank, with the quiescence fast
        // path: a neuron with no input, no refractory hold, and a membrane
        // at its decay fixed point below threshold provably cannot change
        // state or fire — skip it. The ledger still charges one
        // neuron_update per neuron (the datapath is evaluated every cycle
        // in hardware; only *toggles* burn dynamic power).
        let (hold_lo, hold_hi) = neuron::quiescent_hold_range(snap, self.qspec);
        spikes_out.resize_clear(n);
        stats.neuron_updates += n as u64;
        for j in 0..n {
            let act = self.act[j];
            if act == 0 && self.refcnt[j] == 0 && self.vmem[j] >= hold_lo && self.vmem[j] <= hold_hi
            {
                #[cfg(debug_assertions)]
                {
                    // Differential check of the quiescence proof: the full
                    // datapath must agree that nothing happens.
                    let (mut v2, mut r2) = (self.vmem[j], self.refcnt[j]);
                    let out = neuron::step_soa(&mut v2, &mut r2, act, snap, self.qspec);
                    debug_assert!(
                        !out.spike && !out.vmem_toggled && v2 == self.vmem[j] && r2 == 0,
                        "quiescence fast path diverged at neuron {j} (vmem {})",
                        self.vmem[j]
                    );
                }
                continue;
            }
            let out =
                neuron::step_soa(&mut self.vmem[j], &mut self.refcnt[j], act, snap, self.qspec);
            if out.vmem_toggled {
                stats.vmem_toggles += 1;
            }
            if out.spike {
                stats.spikes += 1;
                spikes_out.set(j);
            }
        }
        stats
    }

    /// One spk_clk timestep for up to 64 independent samples at once — the
    /// **lane-batched** hot path. `spikes_in` is an M-line
    /// [`SpikeMatrix`] (bit `l` of line `i`'s word = lane `l` fired line
    /// `i`); `active` masks the lanes that are still streaming (finished
    /// lanes keep their state frozen and charge nothing); `step_stats[l]`
    /// is **overwritten** with lane `l`'s ledger for this step (all-zero
    /// for inactive lanes).
    ///
    /// What makes it fast: each line whose lane-word is nonzero has its
    /// synaptic row fetched from the topology store **once**
    /// ([`SynapticMemory::row_slice`]) and each stored weight scattered
    /// into every firing lane via `trailing_zeros` — weight-memory traffic
    /// drops from O(spikes × nnz) to O(lines-with-any-spike × nnz), which
    /// is the software mirror of QUANTISENC amortizing one distributed-
    /// memory read over a whole pipelined stream batch. Neuron state lives
    /// in a lane-major SoA bank (`vmem[j·L + l]`) stepped by
    /// [`neuron::step_soa_lanes`], so every lane is **bit-identical** —
    /// membrane trace, spikes, and complete activity ledger — to running
    /// that lane's stream alone through [`Layer::step_plane`] (proven in
    /// `rust/tests/sparse_parity.rs`, including ragged batches).
    pub fn step_lanes(
        &mut self,
        spikes_in: &SpikeMatrix,
        spikes_out: &mut SpikeMatrix,
        regs: &RegisterFile,
        active: u64,
        step_stats: &mut [ActivityStats],
    ) {
        self.step_lanes_snap(spikes_in, spikes_out, &RegSnapshot::from(regs), active, step_stats)
    }

    fn step_lanes_snap(
        &mut self,
        spikes_in: &SpikeMatrix,
        spikes_out: &mut SpikeMatrix,
        snap: &RegSnapshot,
        active: u64,
        step_stats: &mut [ActivityStats],
    ) {
        assert_eq!(spikes_in.lines(), self.mem.m(), "fan-in mismatch");
        let lanes = spikes_in.lanes();
        assert!((1..=64).contains(&lanes), "lane width {lanes} out of range");
        assert_eq!(step_stats.len(), lanes, "per-lane stats arity");
        assert_eq!(active & !spikes_in.lane_mask(), 0, "active mask wider than the matrix");
        self.ensure_lanes(lanes);
        let m = self.mem.m();
        let n = self.mem.n();
        let total_words = *self.row_words_prefix.last().unwrap();

        // --- ActGen, lane-batched: every line with any firing lane has its
        // row read once and scattered. Per lane the accumulated multiset of
        // weights equals the single-sample walk's (wrapping add is
        // commutative), and skipping stored zeros is the identity — the
        // ledger still charges the full α=1 row per firing lane.
        if self.lane_act_dirty {
            self.lane_act.fill(0);
            self.lane_act_dirty = false;
        }
        let mut syn = [0u64; 64];
        let mut any_syn = false;
        let mut fired_bits = 0u64;
        let (mut touched_lo, mut touched_hi) = (usize::MAX, 0usize);
        for (i, &word) in spikes_in.words().iter().enumerate() {
            let fired = word & active;
            if fired == 0 {
                continue;
            }
            fired_bits += fired.count_ones() as u64;
            let (lo, row) = self.mem.row_slice(i);
            if row.is_empty() {
                continue;
            }
            any_syn = true;
            touched_lo = touched_lo.min(lo);
            touched_hi = touched_hi.max(lo + row.len());
            let nnz = row.len() as u64;
            let mut bits = fired;
            while bits != 0 {
                syn[bits.trailing_zeros() as usize] += nnz;
                bits &= bits - 1;
            }
            for (k, &wt) in row.iter().enumerate() {
                if wt == 0 {
                    continue;
                }
                let base = (lo + k) * lanes;
                let mut bits = fired;
                while bits != 0 {
                    let l = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let a = &mut self.lane_act[base + l];
                    *a = a.wrapping_add(wt);
                }
            }
        }
        if any_syn {
            self.lane_act_dirty = true;
            // Wrap only the touched column span × all lanes: untouched
            // registers are zero and wrap(0) == 0, exactly as on the
            // single-sample packed path.
            if self.qspec.width() < 32 {
                for a in &mut self.lane_act[touched_lo * lanes..touched_hi * lanes] {
                    *a = self.qspec.wrap(*a as i64);
                }
            }
        }

        // --- Per-lane ledger: identical to what L separate single-sample
        // steps would charge (active lanes only).
        for (l, st) in step_stats.iter_mut().enumerate() {
            *st = if (active >> l) & 1 == 1 {
                ActivityStats {
                    spk_steps: 1,
                    mem_cycles: m as u64,
                    synaptic_ops: syn[l],
                    gated_ops: total_words - syn[l],
                    neuron_updates: n as u64,
                    ..Default::default()
                }
            } else {
                ActivityStats::default()
            };
        }

        // --- Kernel policy for the neuron sweep: pinned override, else
        // firing-rate-aware auto. The density EMA tracks firing
        // (line, lane) pairs over M × active lanes; below the threshold the
        // scalar loop wins (its quiescence skip touches nothing for inert
        // lanes), above it the widest vector tier wins (4–8 lanes per
        // instruction). Either choice is bit-identical (simd_parity suite),
        // so the EMA only steers throughput.
        let active_lanes = active.count_ones().max(1);
        let density = fired_bits as f32 / (m.max(1) as f32 * active_lanes as f32);
        self.lane_density_ema += LANE_DENSITY_ALPHA * (density - self.lane_density_ema);
        let kernel = self.lane_kernel.unwrap_or_else(|| {
            if self.lane_density_ema < LANE_SIMD_MIN_DENSITY {
                neuron::LaneKernel::Scalar
            } else {
                neuron::LaneKernel::auto(self.qspec)
            }
        });

        // --- Neuron updates over the lane-major SoA bank, one neuron's
        // lanes at a time (the scalar kernel applies the quiescence fast
        // path per lane inside step_soa_lanes; the vector tiers compute the
        // full datapath, which the hold-range proof makes bit-identical).
        let hold = neuron::quiescent_hold_range(snap, self.qspec);
        spikes_out.resize_clear(n, lanes);
        for j in 0..n {
            let base = j * lanes;
            let out = neuron::step_soa_lanes_with(
                kernel,
                &mut self.lane_vmem[base..base + lanes],
                &mut self.lane_refcnt[base..base + lanes],
                &self.lane_act[base..base + lanes],
                active,
                hold,
                snap,
                self.qspec,
            );
            if out.spikes != 0 {
                spikes_out.set_line_word(j, out.spikes);
                let mut bits = out.spikes;
                while bits != 0 {
                    step_stats[bits.trailing_zeros() as usize].spikes += 1;
                    bits &= bits - 1;
                }
            }
            let mut bits = out.toggles;
            while bits != 0 {
                step_stats[bits.trailing_zeros() as usize].vmem_toggles += 1;
                bits &= bits - 1;
            }
        }
    }

    /// The dense scalar reference datapath: branch over all M byte lanes,
    /// charge gated rows one at a time, run the full LIF update on every
    /// neuron. Semantically identical to [`Layer::step_plane`] (proven
    /// differentially in `rust/tests/sparse_parity.rs`); kept as the
    /// conformance oracle and the `BENCH_hotpath.json` baseline.
    pub fn step_scalar(
        &mut self,
        spikes_in: &[u8],
        spikes_out: &mut Vec<u8>,
        regs: &RegisterFile,
    ) -> ActivityStats {
        assert_eq!(spikes_in.len(), self.mem.m(), "fan-in mismatch");
        let snap = RegSnapshot::from(regs);
        let m = self.mem.m();
        let n = self.mem.n();
        let mut stats = ActivityStats { spk_steps: 1, mem_cycles: m as u64, ..Default::default() };

        // --- ActGen: M mem_clk cycles over the weight rows.
        //
        // Hot path (see EXPERIMENTS.md §Perf): the hardware wraps the act
        // register after every add, but addition mod 2^W is associative, so
        // accumulating with plain i32 `wrapping_add` and wrapping once per
        // timestep is bit-identical — for W < 32 the partial sums provably
        // fit in i32 (M ≤ 2^15 rows × |w| < 2^15), and for W = 32 the i32
        // wraparound *is* the mod-2^32 semantics. Accumulation goes through
        // the topology-aware store: only stored (α=1) synapses are touched
        // and charged, so sparse topologies do O(nnz) work per active row.
        self.act.fill(0);
        self.act_dirty = false;
        for (i, &spk) in spikes_in.iter().enumerate() {
            if spk == 0 {
                // Clock-gated row: no accumulates happen; the ledger is
                // charged for the row's physical synapse slots only.
                stats.gated_ops += self.mem.row_synapses(i) as u64;
                continue;
            }
            stats.synaptic_ops += self.mem.accumulate_row(i, &mut self.act);
        }
        if stats.synaptic_ops > 0 {
            self.act_dirty = true;
        }
        if self.qspec.width() < 32 {
            for a in &mut self.act {
                *a = self.qspec.wrap(*a as i64);
            }
        }

        // --- Neuron updates (VmemDyn/SpkGen/VmemSel), parallel across j.
        spikes_out.clear();
        spikes_out.reserve(n);
        for j in 0..n {
            let act = self.act[j];
            let out =
                neuron::step_soa(&mut self.vmem[j], &mut self.refcnt[j], act, &snap, self.qspec);
            stats.neuron_updates += 1;
            if out.vmem_toggled {
                stats.vmem_toggles += 1;
            }
            if out.spike {
                stats.spikes += 1;
            }
            spikes_out.push(out.spike as u8);
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Topology;
    use crate::fixed::Q5_3;

    fn layer(m: usize, n: usize) -> Layer {
        let cfg = LayerConfig { fan_in: m, neurons: n, topology: Topology::AllToAll };
        Layer::new(&cfg, Q5_3, MemKind::Bram)
    }

    #[test]
    fn weighted_sum_drives_spike() {
        let mut l = layer(3, 1);
        // Weights 3+7 = 10 = vth 1.25 in raw ⇒ spike (vth default = 8 raw).
        l.memory_mut().write(0, 0, 3).unwrap();
        l.memory_mut().write(2, 0, 7).unwrap();
        let mut out = Vec::new();
        let stats = l.step(&[1, 0, 1], &mut out);
        assert_eq!(out, vec![1]);
        assert_eq!(stats.spikes, 1);
        assert_eq!(stats.mem_cycles, 3);
    }

    #[test]
    fn clock_gating_ledger() {
        let mut l = layer(4, 8);
        let mut out = Vec::new();
        let stats = l.step(&[1, 0, 0, 1], &mut out);
        assert_eq!(stats.synaptic_ops, 16); // 2 active rows × 8 neurons
        assert_eq!(stats.gated_ops, 16); // 2 gated rows × 8 neurons
        assert_eq!(stats.gating_ratio(), 0.5);
    }

    #[test]
    fn activation_wraps_like_hardware() {
        let mut l = layer(4, 1);
        for i in 0..4 {
            l.memory_mut().write(i, 0, 100).unwrap();
        }
        let mut out = Vec::new();
        l.step(&[1, 1, 1, 1], &mut out);
        // 400 wraps to -112 in 8 bits; growth 1.0 ⇒ vmem = wrap(400) raw…
        // (vmem must equal the wrapped activation, not saturate)
        assert_eq!(l.vmem()[0], Q5_3.wrap(400));
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn reset_clears_state() {
        let mut l = layer(2, 2);
        l.memory_mut().write(0, 0, 4).unwrap();
        let mut out = Vec::new();
        l.step(&[1, 1], &mut out);
        assert_ne!(l.vmem(), vec![0, 0]);
        assert_eq!(l.vmem(), l.vmem_slice().to_vec());
        l.reset();
        assert_eq!(l.vmem_slice(), &[0, 0]);
    }

    #[test]
    #[should_panic(expected = "fan-in mismatch")]
    fn input_arity_checked() {
        let mut l = layer(3, 1);
        let mut out = Vec::new();
        l.step(&[1, 0], &mut out);
    }

    #[test]
    fn sparse_topologies_charge_only_stored_synapses() {
        // One-to-one 4x4: 1 synapse per row.
        let cfg = LayerConfig { fan_in: 4, neurons: 4, topology: Topology::OneToOne };
        let mut l = Layer::new(&cfg, Q5_3, MemKind::Bram);
        let mut out = Vec::new();
        let stats = l.step(&[1, 0, 1, 0], &mut out);
        assert_eq!(stats.synaptic_ops, 2);
        assert_eq!(stats.gated_ops, 2);
        assert_eq!(stats.mem_cycles, 4);

        // Gaussian radius-1 6x6: tridiagonal, rows have 2/3/3/3/3/2 words.
        let cfg = LayerConfig { fan_in: 6, neurons: 6, topology: Topology::Gaussian { radius: 1 } };
        let mut l = Layer::new(&cfg, Q5_3, MemKind::Bram);
        let stats = l.step(&[1, 1, 1, 1, 1, 1], &mut out);
        assert_eq!(stats.synaptic_ops, 16);
        assert_eq!(stats.gated_ops, 0);
        let stats = l.step(&[0, 0, 0, 0, 0, 0], &mut out);
        assert_eq!(stats.synaptic_ops, 0);
        assert_eq!(stats.gated_ops, 16);
    }

    #[test]
    fn one_to_one_accumulates_diagonal_only() {
        let cfg = LayerConfig { fan_in: 3, neurons: 3, topology: Topology::OneToOne };
        let mut l = Layer::new(&cfg, Q5_3, MemKind::Bram);
        for i in 0..3 {
            l.memory_mut().write(i, i, 10).unwrap(); // 1.25 > vth 1.0
        }
        let mut out = Vec::new();
        let stats = l.step(&[0, 1, 0], &mut out);
        assert_eq!(out, vec![0, 1, 0]);
        assert_eq!(stats.synaptic_ops, 1);
    }

    #[test]
    fn plane_and_scalar_paths_interleave_consistently() {
        // Alternating packed and scalar steps on the same layer must walk
        // the same trajectory as scalar-only on a twin (the act scratch /
        // dirty-flag handshake between the paths is state-free).
        let mut mixed = layer(16, 8);
        let mut scalar = layer(16, 8);
        let weights: Vec<i32> = (0..16 * 8).map(|k| (k as i32 % 13) - 6).collect();
        mixed.memory_mut().load_dense(&weights).unwrap();
        scalar.memory_mut().load_dense(&weights).unwrap();
        let regs = RegisterFile::new(Q5_3);
        let mut out_b = Vec::new();
        let mut ref_b = Vec::new();
        let mut plane_in = SpikePlane::default();
        let mut plane_out = SpikePlane::default();
        for t in 0..40usize {
            let spikes: Vec<u8> = (0..16).map(|i| ((t * 7 + i) % 5 == 0) as u8).collect();
            let ref_stats = scalar.step_scalar(&spikes, &mut ref_b, &regs);
            let stats = if t % 2 == 0 {
                plane_in.load_bytes(&spikes);
                let s = mixed.step_plane(&plane_in, &mut plane_out, &regs);
                out_b.clear();
                plane_out.append_bytes_to(&mut out_b);
                s
            } else {
                mixed.step_scalar(&spikes, &mut out_b, &regs)
            };
            assert_eq!(out_b, ref_b, "t={t}");
            assert_eq!(mixed.vmem_slice(), scalar.vmem_slice(), "t={t}");
            assert_eq!(stats, ref_stats, "t={t}");
        }
    }

    #[test]
    fn lane_step_matches_per_lane_plane_twins() {
        // 5 lanes with distinct spike streams on one lane-batched layer vs
        // 5 single-sample packed twins: every lane's spikes, vmem trace,
        // and per-step ledger must be bit-identical, with lane 3 finishing
        // early (masked out) and lane 1 all-silent.
        use crate::hdl::spikes::SpikeMatrix;
        let (m, n, lanes) = (12usize, 9usize, 5usize);
        let cfg = LayerConfig { fan_in: m, neurons: n, topology: Topology::AllToAll };
        let weights: Vec<i32> = (0..m * n).map(|k| (k as i32 % 15) - 7).collect();
        let mut batched = Layer::new(&cfg, Q5_3, MemKind::Bram);
        batched.memory_mut().load_dense(&weights).unwrap();
        let mut twins: Vec<Layer> = (0..lanes)
            .map(|_| {
                let mut l = Layer::new(&cfg, Q5_3, MemKind::Bram);
                l.memory_mut().load_dense(&weights).unwrap();
                l
            })
            .collect();
        let regs = RegisterFile::new(Q5_3);
        let lens = [30usize, 30, 30, 11, 24]; // ragged stream lengths
        let mut mat_in = SpikeMatrix::default();
        let mut mat_out = SpikeMatrix::default();
        let mut plane_in = SpikePlane::default();
        let mut plane_out = SpikePlane::default();
        let mut stats = vec![ActivityStats::default(); lanes];
        for t in 0..30usize {
            mat_in.resize_clear(m, lanes);
            let mut active = 0u64;
            let mut streams: Vec<Vec<u8>> = Vec::new();
            for (l, &len) in lens.iter().enumerate() {
                let spikes: Vec<u8> = (0..m)
                    .map(|i| (l != 1 && (t * 5 + i * 3 + l * 7) % 4 == 0) as u8)
                    .collect();
                if t < len {
                    mat_in.load_lane_bytes(l, &spikes);
                    active |= 1 << l;
                }
                streams.push(spikes);
            }
            batched.step_lanes(&mat_in, &mut mat_out, &regs, active, &mut stats);
            assert_eq!((mat_out.lines(), mat_out.lanes()), (n, lanes), "t={t}");
            for (l, twin) in twins.iter_mut().enumerate() {
                if t >= lens[l] {
                    assert_eq!(stats[l], ActivityStats::default(), "t={t} masked lane {l}");
                    continue;
                }
                plane_in.load_bytes(&streams[l]);
                let want = twin.step_plane(&plane_in, &mut plane_out, &regs);
                mat_out.lane_plane_into(l, &mut plane_in); // reuse as gather buf
                assert_eq!(plane_in, plane_out, "t={t} lane {l} spikes");
                assert_eq!(batched.lane_vmem(l), twin.vmem_slice(), "t={t} lane {l} vmem");
                assert_eq!(stats[l], want, "t={t} lane {l} ledger");
            }
        }
    }

    #[test]
    fn pinned_lane_kernels_are_bitexact_twins() {
        // Layers pinned to every kernel tier (plus the auto policy) must
        // walk identical lane-state / spike / ledger trajectories across a
        // dense-then-sparse stream that exercises the density EMA's policy
        // flip in auto mode.
        use crate::hdl::neuron::LaneKernel;
        use crate::hdl::spikes::SpikeMatrix;
        let (m, n, lanes) = (24usize, 17usize, 11usize);
        let cfg = LayerConfig { fan_in: m, neurons: n, topology: Topology::AllToAll };
        let weights: Vec<i32> = (0..m * n).map(|k| (k as i32 % 15) - 7).collect();
        let build = |kernel: Option<LaneKernel>| {
            let mut l = Layer::new(&cfg, Q5_3, MemKind::Bram);
            l.memory_mut().load_dense(&weights).unwrap();
            l.set_lane_kernel(kernel);
            l
        };
        let mut oracle = build(Some(LaneKernel::Scalar));
        assert_eq!(oracle.lane_kernel(), Some(LaneKernel::Scalar));
        let mut others = vec![
            build(Some(LaneKernel::Sse2)),
            build(Some(LaneKernel::Avx2)),
            build(None),
        ];
        let regs = RegisterFile::new(Q5_3);
        let active = (1u64 << lanes) - 1;
        let mut mat_in = SpikeMatrix::default();
        let mut mat_out = SpikeMatrix::default();
        let mut want_out = SpikeMatrix::default();
        let mut stats = vec![ActivityStats::default(); lanes];
        let mut want_stats = vec![ActivityStats::default(); lanes];
        for t in 0..60usize {
            mat_in.resize_clear(m, lanes);
            for l in 0..lanes {
                for i in 0..m {
                    // Dense for 30 steps, then near-silent: flips the auto
                    // policy from SIMD back to the scalar fast path.
                    let fire = if t < 30 {
                        (t + i * 3 + l * 7) % 3 == 0
                    } else {
                        (t + i + l) % 97 == 0
                    };
                    if fire {
                        mat_in.set(i, l);
                    }
                }
            }
            oracle.step_lanes(&mat_in, &mut want_out, &regs, active, &mut want_stats);
            for other in &mut others {
                let k = other.lane_kernel();
                other.step_lanes(&mat_in, &mut mat_out, &regs, active, &mut stats);
                assert_eq!(mat_out, want_out, "t={t} kernel {k:?} spikes");
                assert_eq!(stats, want_stats, "t={t} kernel {k:?} ledger");
                for lane in 0..lanes {
                    assert_eq!(
                        other.lane_vmem(lane),
                        oracle.lane_vmem(lane),
                        "t={t} kernel {k:?} lane {lane} vmem"
                    );
                }
            }
        }
    }

    #[test]
    fn lane_reset_and_width_switch_clear_state() {
        use crate::hdl::spikes::SpikeMatrix;
        let mut l = layer(4, 3);
        l.memory_mut().write(0, 0, 9).unwrap();
        let regs = RegisterFile::new(Q5_3);
        let mut mat_in = SpikeMatrix::new(4, 2);
        mat_in.set(0, 0);
        mat_in.set(0, 1);
        let mut mat_out = SpikeMatrix::default();
        let mut stats = vec![ActivityStats::default(); 2];
        l.step_lanes(&mat_in, &mut mat_out, &regs, 0b11, &mut stats);
        assert_eq!(l.lane_width(), 2);
        assert_ne!(l.lane_vmem(0), vec![0; 3]);
        assert_eq!(l.lane_vmem(0), l.lane_vmem(1));
        assert_eq!(l.lane_neuron_state(0, 0).vmem, l.lane_vmem(0)[0]);
        l.reset();
        assert_eq!(l.lane_vmem(0), vec![0; 3]);
        // A different lane width reallocates a fresh (zero) bank.
        let mat3 = SpikeMatrix::new(4, 3);
        let mut stats3 = vec![ActivityStats::default(); 3];
        l.step_lanes(&mat3, &mut mat_out, &regs, 0b111, &mut stats3);
        assert_eq!(l.lane_width(), 3);
        assert_eq!(l.lane_vmem(2), vec![0; 3]);
    }

    #[test]
    fn integrity_scrub_corrects_boundary_flips_per_target() {
        use crate::hdl::spikes::SpikeMatrix;
        let mut l = layer(4, 3);
        let weights: Vec<i32> = (0..12).map(|k| (k as i32 % 9) - 4).collect();
        l.memory_mut().load_dense(&weights).unwrap();
        l.set_integrity(IntegrityMode::Correct);
        assert_eq!(l.integrity_mode(), IntegrityMode::Correct);
        // Single-sample banks: run a step, reset (a sample boundary — the
        // guards re-sync there), flip, scrub.
        let mut out = Vec::new();
        l.step(&[1, 0, 1, 1], &mut out);
        l.reset();
        for target in [FlipTarget::Weights, FlipTarget::Vmem, FlipTarget::Refcnt] {
            l.integrity_flip(target, 5, 3);
            let o = l.scrub(usize::MAX);
            assert_eq!((o.corrected, o.detected), (1, 0), "{target:?}");
        }
        assert_eq!(l.memory().dense(), weights, "weight flip repaired in place");
        // Neuron-bank flips land in the lane-major bank once the lane
        // datapath has run.
        let regs = RegisterFile::new(Q5_3);
        let mat_in = SpikeMatrix::new(4, 2);
        let mut mat_out = SpikeMatrix::default();
        let mut stats = vec![ActivityStats::default(); 2];
        l.step_lanes(&mat_in, &mut mat_out, &regs, 0b11, &mut stats);
        l.reset();
        l.integrity_flip(FlipTarget::Vmem, 1, 30);
        let o = l.scrub(usize::MAX);
        assert_eq!((o.corrected, o.detected), (1, 0), "lane vmem");
        assert_eq!(l.lane_vmem(0), vec![0; 3], "lane bank repaired to rest");
        // Detect mode flags the corruption but cannot locate the bit.
        l.set_integrity(IntegrityMode::Detect);
        l.integrity_flip(FlipTarget::Weights, 0, 0);
        let o = l.scrub(usize::MAX);
        assert_eq!((o.corrected, o.detected), (0, 1), "detect-only mode");
    }

    #[test]
    fn zero_spike_step_skips_work_but_keeps_ledger() {
        let mut l = layer(8, 4);
        l.memory_mut().write(0, 0, 9).unwrap();
        let mut out = Vec::new();
        l.step(&[1; 8], &mut out); // dirty the act registers
        let stats = l.step(&[0; 8], &mut out);
        assert_eq!(stats.synaptic_ops, 0);
        assert_eq!(stats.gated_ops, 32);
        assert_eq!(stats.neuron_updates, 4);
        assert_eq!(out, vec![0, 0, 0, 0]);
    }
}
