//! One hardware layer — N LIF neurons + their distributed synaptic memory +
//! the ActGen address generator (paper Fig. 1b / Fig. 2 ActGen box).
//!
//! Per spk_clk timestep the address generator walks the M pre-synaptic rows
//! (M mem_clk cycles). For each row with an input spike, every *stored*
//! synapse (i, j) adds w[i][j] into neuron j's act register — a *wrapping*
//! Qn.q add, exactly the hardware accumulator. The walk goes through the
//! topology-aware store ([`SynapticMemory::accumulate_row`]), so synaptic
//! work is O(row nnz), not O(N). Rows without a spike are clock-gated: the
//! adds are skipped and only the gating ledger is charged with the row's
//! stored-synapse count (§VI-E "we gate the clock in the design when there
//! is no input spike"). `synaptic_ops + gated_ops` per step therefore
//! equals the layer's physical synapse count — the α=1 words — for every
//! topology.
//!
//! # Event-driven hot path
//!
//! The production datapath is **packed**: [`Layer::step_plane`] takes a
//! bit-packed [`SpikePlane`] and
//!
//! * iterates only the *firing* rows via `trailing_zeros` (O(popcount)
//!   instead of an O(M) branch-per-row scan),
//! * charges `gated_ops` in bulk from a per-row physical-synapse prefix
//!   sum built at construction (total α=1 words minus the firing rows'
//!   words — identical to summing the gated rows one by one),
//! * keeps the neuron bank in struct-of-arrays form (`vmem[]`/`refcnt[]`
//!   slices) and skips every neuron that is *provably inert* this step:
//!   `act == 0`, `refcnt == 0`, and `vmem` inside the decay fixed-point
//!   hold range below threshold
//!   ([`neuron::quiescent_hold_range`] — bit-identical by construction,
//!   re-checked against the full datapath by a `debug_assert`).
//!
//! The byte-slice API ([`Layer::step`]/[`Layer::step_regs`]) survives as a
//! thin adapter over scratch planes, and [`Layer::step_scalar`] retains the
//! dense reference walk (branch per row, full LIF update per neuron) as
//! the differential-testing and benchmarking baseline — the
//! `sparse_parity` suite proves the two paths bit-identical in vmem,
//! spikes, and activity ledgers across all topologies and Q formats.

use crate::config::registers::RegisterFile;
use crate::config::{LayerConfig, MemKind};
use crate::fixed::QSpec;

use super::clock::ActivityStats;
use super::memory::SynapticMemory;
use super::neuron::{self, LifNeuron, RegSnapshot};
use super::spikes::SpikePlane;

#[derive(Debug, Clone)]
pub struct Layer {
    mem: SynapticMemory,
    qspec: QSpec,
    /// Struct-of-arrays neuron bank: membrane registers…
    vmem: Vec<i32>,
    /// …and refractory counters, one lane per neuron (Fig. 2's two
    /// registers, laid out for the linear sweep of the hot loop).
    refcnt: Vec<i32>,
    /// Scratch activation registers (one act_reg per neuron, Fig. 2).
    act: Vec<i32>,
    /// Whether `act` holds residue from the previous step (lets a step with
    /// zero firing rows skip the O(N) clear entirely).
    act_dirty: bool,
    /// `row_words_prefix[i]` = physical (α=1) synapse words stored in rows
    /// `[0, i)`; the last entry is the layer's total word count. Charges the
    /// clock-gating ledger in bulk on the packed path.
    row_words_prefix: Vec<u64>,
    /// Lazily-built default register snapshot for `step`'s `None`-regs path
    /// (unit-driven layers) — built once, not per timestep.
    default_snap: Option<RegSnapshot>,
    /// Scratch planes backing the byte-slice adapter API.
    in_scratch: SpikePlane,
    out_scratch: SpikePlane,
}

impl Layer {
    pub fn new(cfg: &LayerConfig, qspec: QSpec, mem_kind: MemKind) -> Layer {
        let mem = SynapticMemory::new(cfg.fan_in, cfg.neurons, cfg.topology, qspec, mem_kind);
        let mut row_words_prefix = Vec::with_capacity(cfg.fan_in + 1);
        row_words_prefix.push(0u64);
        for i in 0..cfg.fan_in {
            let prev = *row_words_prefix.last().unwrap();
            row_words_prefix.push(prev + mem.row_synapses(i) as u64);
        }
        Layer {
            mem,
            qspec,
            vmem: vec![0; cfg.neurons],
            refcnt: vec![0; cfg.neurons],
            act: vec![0; cfg.neurons],
            act_dirty: false,
            row_words_prefix,
            default_snap: None,
            in_scratch: SpikePlane::default(),
            out_scratch: SpikePlane::default(),
        }
    }

    pub fn fan_in(&self) -> usize {
        self.mem.m()
    }

    pub fn neurons(&self) -> usize {
        self.mem.n()
    }

    pub fn memory(&self) -> &SynapticMemory {
        &self.mem
    }

    pub fn memory_mut(&mut self) -> &mut SynapticMemory {
        &mut self.mem
    }

    /// Bulk wt_in reprogramming: swap this layer's synaptic memory for a
    /// packed payload (exactly [`SynapticMemory::synapses`] words in stored
    /// order). Membrane state is untouched — the paper's run-time weight
    /// path programs memory while the neurons keep their dynamics. This is
    /// what a serving-engine stage applies when a control-plane program
    /// addresses its layer.
    pub fn load_packed(&mut self, packed: &[i32]) -> Result<(), super::memory::MemError> {
        self.mem.load_packed(packed)
    }

    pub fn neuron_state(&self, j: usize) -> LifNeuron {
        LifNeuron { vmem: self.vmem[j], refcnt: self.refcnt[j] }
    }

    /// Borrow the membrane registers of the struct-of-arrays neuron bank —
    /// the zero-copy probe view (prefer this over [`Layer::vmem`]).
    pub fn vmem_slice(&self) -> &[i32] {
        &self.vmem
    }

    /// Membrane registers as a fresh `Vec` (allocating; kept for artifact
    /// writers and older callers — prefer [`Layer::vmem_slice`]).
    pub fn vmem(&self) -> Vec<i32> {
        self.vmem.clone()
    }

    pub fn reset(&mut self) {
        self.vmem.fill(0);
        self.refcnt.fill(0);
    }

    /// One spk_clk timestep. `spikes_in` has M entries (0/1);
    /// `spikes_out` is filled with N entries. Returns activity stats.
    pub fn step(&mut self, spikes_in: &[u8], spikes_out: &mut Vec<u8>) -> ActivityStats {
        self.step_with(spikes_in, spikes_out, None)
    }

    /// As [`Layer::step`], with explicit registers (per-core register file is
    /// borrowed by the core; `None` is only used in unit tests via the
    /// default register values).
    pub fn step_regs(
        &mut self,
        spikes_in: &[u8],
        spikes_out: &mut Vec<u8>,
        regs: &RegisterFile,
    ) -> ActivityStats {
        self.step_with(spikes_in, spikes_out, Some(regs))
    }

    /// Byte-slice adapter over the packed datapath: packs `spikes_in` into
    /// a recycled scratch plane, runs [`Layer::step_plane`], and expands the
    /// output plane back to 0/1 bytes. Zero allocation once the scratch
    /// planes have seen this layer's widths.
    fn step_with(
        &mut self,
        spikes_in: &[u8],
        spikes_out: &mut Vec<u8>,
        regs: Option<&RegisterFile>,
    ) -> ActivityStats {
        let snap = match regs {
            Some(r) => RegSnapshot::from(r),
            None => self.default_snapshot(),
        };
        self.in_scratch.load_bytes(spikes_in);
        let in_plane = std::mem::take(&mut self.in_scratch);
        let mut out_plane = std::mem::take(&mut self.out_scratch);
        let stats = self.step_plane_snap(&in_plane, &mut out_plane, &snap);
        spikes_out.clear();
        out_plane.append_bytes_to(spikes_out);
        self.in_scratch = in_plane;
        self.out_scratch = out_plane;
        stats
    }

    /// The default-register snapshot, built on first use and cached (this
    /// sits on the per-timestep path for unit-driven layers).
    fn default_snapshot(&mut self) -> RegSnapshot {
        if self.default_snap.is_none() {
            self.default_snap = Some(RegSnapshot::from(&RegisterFile::new(self.qspec)));
        }
        self.default_snap.unwrap()
    }

    /// One spk_clk timestep over packed planes — the event-driven hot path
    /// (see the module docs for what makes it fast). `spikes_in` must have
    /// M lines; `spikes_out` is resized to N lines with the firing neurons
    /// set. Bit-identical to [`Layer::step_scalar`] in dynamics *and*
    /// activity ledger.
    pub fn step_plane(
        &mut self,
        spikes_in: &SpikePlane,
        spikes_out: &mut SpikePlane,
        regs: &RegisterFile,
    ) -> ActivityStats {
        self.step_plane_snap(spikes_in, spikes_out, &RegSnapshot::from(regs))
    }

    fn step_plane_snap(
        &mut self,
        spikes_in: &SpikePlane,
        spikes_out: &mut SpikePlane,
        snap: &RegSnapshot,
    ) -> ActivityStats {
        assert_eq!(spikes_in.len(), self.mem.m(), "fan-in mismatch");
        let m = self.mem.m();
        let n = self.mem.n();
        let total_words = *self.row_words_prefix.last().unwrap();
        let mut stats = ActivityStats { spk_steps: 1, mem_cycles: m as u64, ..Default::default() };

        // --- ActGen, event-driven: visit only the firing rows (the
        // hardware's clock gating as control flow). Accumulation is the
        // same once-per-step wrapping scheme as the scalar reference (see
        // `step_scalar` for the associativity argument); gating is charged
        // in bulk: gated_ops = total α=1 words − the firing rows' words.
        if self.act_dirty {
            self.act.fill(0);
            self.act_dirty = false;
        }
        let mut syn = 0u64;
        let (mut touched_lo, mut touched_hi) = (usize::MAX, 0usize);
        for i in spikes_in.iter_ones() {
            let (lo, width) = self.mem.row_window(i);
            syn += self.mem.accumulate_row(i, &mut self.act);
            if width > 0 {
                touched_lo = touched_lo.min(lo);
                touched_hi = touched_hi.max(lo + width);
            }
        }
        if syn > 0 {
            self.act_dirty = true;
        }
        stats.synaptic_ops = syn;
        stats.gated_ops = total_words - syn;
        // Wrap only the column span the firing rows could have touched:
        // untouched act registers are zero by invariant and wrap(0) == 0,
        // so this is bit-identical to the scalar reference's full-width
        // wrap while costing O(touched) on sparse (banded/diagonal) rows.
        if self.qspec.width() < 32 && syn > 0 {
            for a in &mut self.act[touched_lo..touched_hi] {
                *a = self.qspec.wrap(*a as i64);
            }
        }

        // --- Neuron updates over the SoA bank, with the quiescence fast
        // path: a neuron with no input, no refractory hold, and a membrane
        // at its decay fixed point below threshold provably cannot change
        // state or fire — skip it. The ledger still charges one
        // neuron_update per neuron (the datapath is evaluated every cycle
        // in hardware; only *toggles* burn dynamic power).
        let (hold_lo, hold_hi) = neuron::quiescent_hold_range(snap, self.qspec);
        spikes_out.resize_clear(n);
        stats.neuron_updates += n as u64;
        for j in 0..n {
            let act = self.act[j];
            if act == 0 && self.refcnt[j] == 0 && self.vmem[j] >= hold_lo && self.vmem[j] <= hold_hi
            {
                #[cfg(debug_assertions)]
                {
                    // Differential check of the quiescence proof: the full
                    // datapath must agree that nothing happens.
                    let (mut v2, mut r2) = (self.vmem[j], self.refcnt[j]);
                    let out = neuron::step_soa(&mut v2, &mut r2, act, snap, self.qspec);
                    debug_assert!(
                        !out.spike && !out.vmem_toggled && v2 == self.vmem[j] && r2 == 0,
                        "quiescence fast path diverged at neuron {j} (vmem {})",
                        self.vmem[j]
                    );
                }
                continue;
            }
            let out =
                neuron::step_soa(&mut self.vmem[j], &mut self.refcnt[j], act, snap, self.qspec);
            if out.vmem_toggled {
                stats.vmem_toggles += 1;
            }
            if out.spike {
                stats.spikes += 1;
                spikes_out.set(j);
            }
        }
        stats
    }

    /// The dense scalar reference datapath: branch over all M byte lanes,
    /// charge gated rows one at a time, run the full LIF update on every
    /// neuron. Semantically identical to [`Layer::step_plane`] (proven
    /// differentially in `rust/tests/sparse_parity.rs`); kept as the
    /// conformance oracle and the `BENCH_hotpath.json` baseline.
    pub fn step_scalar(
        &mut self,
        spikes_in: &[u8],
        spikes_out: &mut Vec<u8>,
        regs: &RegisterFile,
    ) -> ActivityStats {
        assert_eq!(spikes_in.len(), self.mem.m(), "fan-in mismatch");
        let snap = RegSnapshot::from(regs);
        let m = self.mem.m();
        let n = self.mem.n();
        let mut stats = ActivityStats { spk_steps: 1, mem_cycles: m as u64, ..Default::default() };

        // --- ActGen: M mem_clk cycles over the weight rows.
        //
        // Hot path (see EXPERIMENTS.md §Perf): the hardware wraps the act
        // register after every add, but addition mod 2^W is associative, so
        // accumulating with plain i32 `wrapping_add` and wrapping once per
        // timestep is bit-identical — for W < 32 the partial sums provably
        // fit in i32 (M ≤ 2^15 rows × |w| < 2^15), and for W = 32 the i32
        // wraparound *is* the mod-2^32 semantics. Accumulation goes through
        // the topology-aware store: only stored (α=1) synapses are touched
        // and charged, so sparse topologies do O(nnz) work per active row.
        self.act.fill(0);
        self.act_dirty = false;
        for (i, &spk) in spikes_in.iter().enumerate() {
            if spk == 0 {
                // Clock-gated row: no accumulates happen; the ledger is
                // charged for the row's physical synapse slots only.
                stats.gated_ops += self.mem.row_synapses(i) as u64;
                continue;
            }
            stats.synaptic_ops += self.mem.accumulate_row(i, &mut self.act);
        }
        if stats.synaptic_ops > 0 {
            self.act_dirty = true;
        }
        if self.qspec.width() < 32 {
            for a in &mut self.act {
                *a = self.qspec.wrap(*a as i64);
            }
        }

        // --- Neuron updates (VmemDyn/SpkGen/VmemSel), parallel across j.
        spikes_out.clear();
        spikes_out.reserve(n);
        for j in 0..n {
            let act = self.act[j];
            let out =
                neuron::step_soa(&mut self.vmem[j], &mut self.refcnt[j], act, &snap, self.qspec);
            stats.neuron_updates += 1;
            if out.vmem_toggled {
                stats.vmem_toggles += 1;
            }
            if out.spike {
                stats.spikes += 1;
            }
            spikes_out.push(out.spike as u8);
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Topology;
    use crate::fixed::Q5_3;

    fn layer(m: usize, n: usize) -> Layer {
        let cfg = LayerConfig { fan_in: m, neurons: n, topology: Topology::AllToAll };
        Layer::new(&cfg, Q5_3, MemKind::Bram)
    }

    #[test]
    fn weighted_sum_drives_spike() {
        let mut l = layer(3, 1);
        // Weights 3+7 = 10 = vth 1.25 in raw ⇒ spike (vth default = 8 raw).
        l.memory_mut().write(0, 0, 3).unwrap();
        l.memory_mut().write(2, 0, 7).unwrap();
        let mut out = Vec::new();
        let stats = l.step(&[1, 0, 1], &mut out);
        assert_eq!(out, vec![1]);
        assert_eq!(stats.spikes, 1);
        assert_eq!(stats.mem_cycles, 3);
    }

    #[test]
    fn clock_gating_ledger() {
        let mut l = layer(4, 8);
        let mut out = Vec::new();
        let stats = l.step(&[1, 0, 0, 1], &mut out);
        assert_eq!(stats.synaptic_ops, 16); // 2 active rows × 8 neurons
        assert_eq!(stats.gated_ops, 16); // 2 gated rows × 8 neurons
        assert_eq!(stats.gating_ratio(), 0.5);
    }

    #[test]
    fn activation_wraps_like_hardware() {
        let mut l = layer(4, 1);
        for i in 0..4 {
            l.memory_mut().write(i, 0, 100).unwrap();
        }
        let mut out = Vec::new();
        l.step(&[1, 1, 1, 1], &mut out);
        // 400 wraps to -112 in 8 bits; growth 1.0 ⇒ vmem = wrap(400) raw…
        // (vmem must equal the wrapped activation, not saturate)
        assert_eq!(l.vmem()[0], Q5_3.wrap(400));
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn reset_clears_state() {
        let mut l = layer(2, 2);
        l.memory_mut().write(0, 0, 4).unwrap();
        let mut out = Vec::new();
        l.step(&[1, 1], &mut out);
        assert_ne!(l.vmem(), vec![0, 0]);
        assert_eq!(l.vmem(), l.vmem_slice().to_vec());
        l.reset();
        assert_eq!(l.vmem_slice(), &[0, 0]);
    }

    #[test]
    #[should_panic(expected = "fan-in mismatch")]
    fn input_arity_checked() {
        let mut l = layer(3, 1);
        let mut out = Vec::new();
        l.step(&[1, 0], &mut out);
    }

    #[test]
    fn sparse_topologies_charge_only_stored_synapses() {
        // One-to-one 4x4: 1 synapse per row.
        let cfg = LayerConfig { fan_in: 4, neurons: 4, topology: Topology::OneToOne };
        let mut l = Layer::new(&cfg, Q5_3, MemKind::Bram);
        let mut out = Vec::new();
        let stats = l.step(&[1, 0, 1, 0], &mut out);
        assert_eq!(stats.synaptic_ops, 2);
        assert_eq!(stats.gated_ops, 2);
        assert_eq!(stats.mem_cycles, 4);

        // Gaussian radius-1 6x6: tridiagonal, rows have 2/3/3/3/3/2 words.
        let cfg = LayerConfig { fan_in: 6, neurons: 6, topology: Topology::Gaussian { radius: 1 } };
        let mut l = Layer::new(&cfg, Q5_3, MemKind::Bram);
        let stats = l.step(&[1, 1, 1, 1, 1, 1], &mut out);
        assert_eq!(stats.synaptic_ops, 16);
        assert_eq!(stats.gated_ops, 0);
        let stats = l.step(&[0, 0, 0, 0, 0, 0], &mut out);
        assert_eq!(stats.synaptic_ops, 0);
        assert_eq!(stats.gated_ops, 16);
    }

    #[test]
    fn one_to_one_accumulates_diagonal_only() {
        let cfg = LayerConfig { fan_in: 3, neurons: 3, topology: Topology::OneToOne };
        let mut l = Layer::new(&cfg, Q5_3, MemKind::Bram);
        for i in 0..3 {
            l.memory_mut().write(i, i, 10).unwrap(); // 1.25 > vth 1.0
        }
        let mut out = Vec::new();
        let stats = l.step(&[0, 1, 0], &mut out);
        assert_eq!(out, vec![0, 1, 0]);
        assert_eq!(stats.synaptic_ops, 1);
    }

    #[test]
    fn plane_and_scalar_paths_interleave_consistently() {
        // Alternating packed and scalar steps on the same layer must walk
        // the same trajectory as scalar-only on a twin (the act scratch /
        // dirty-flag handshake between the paths is state-free).
        let mut mixed = layer(16, 8);
        let mut scalar = layer(16, 8);
        let weights: Vec<i32> = (0..16 * 8).map(|k| (k as i32 % 13) - 6).collect();
        mixed.memory_mut().load_dense(&weights).unwrap();
        scalar.memory_mut().load_dense(&weights).unwrap();
        let regs = RegisterFile::new(Q5_3);
        let mut out_b = Vec::new();
        let mut ref_b = Vec::new();
        let mut plane_in = SpikePlane::default();
        let mut plane_out = SpikePlane::default();
        for t in 0..40usize {
            let spikes: Vec<u8> = (0..16).map(|i| ((t * 7 + i) % 5 == 0) as u8).collect();
            let ref_stats = scalar.step_scalar(&spikes, &mut ref_b, &regs);
            let stats = if t % 2 == 0 {
                plane_in.load_bytes(&spikes);
                let s = mixed.step_plane(&plane_in, &mut plane_out, &regs);
                out_b.clear();
                plane_out.append_bytes_to(&mut out_b);
                s
            } else {
                mixed.step_scalar(&spikes, &mut out_b, &regs)
            };
            assert_eq!(out_b, ref_b, "t={t}");
            assert_eq!(mixed.vmem_slice(), scalar.vmem_slice(), "t={t}");
            assert_eq!(stats, ref_stats, "t={t}");
        }
    }

    #[test]
    fn zero_spike_step_skips_work_but_keeps_ledger() {
        let mut l = layer(8, 4);
        l.memory_mut().write(0, 0, 9).unwrap();
        let mut out = Vec::new();
        l.step(&[1; 8], &mut out); // dirty the act registers
        let stats = l.step(&[0; 8], &mut out);
        assert_eq!(stats.synaptic_ops, 0);
        assert_eq!(stats.gated_ops, 32);
        assert_eq!(stats.neuron_updates, 4);
        assert_eq!(out, vec![0, 0, 0, 0]);
    }
}
