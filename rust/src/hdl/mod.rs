//! Cycle-accurate simulator of the QUANTISENC digital core — the substrate
//! substitution for the paper's Verilog RTL + Vivado simulation flow
//! (DESIGN.md §1). Semantics are specified by the paper's Eqs. 1–10 and
//! Figs. 1/2/6/8 and are **bit-exact** with the Python oracle
//! (`kernels/ref.py`) and the Pallas kernel — cross-checked via the
//! `golden_lif_*.json` vectors and via PJRT-executed HLO in the integration
//! tests.
//!
//! Structure mirrors the hardware hierarchy (bottom-up, §II):
//!
//! * [`neuron`] — one LIF datapath: ActGen accumulate + VmemDyn + SpkGen +
//!   VmemSel (Fig. 2), plus the refractory counter.
//! * [`memory`] — a layer's distributed synaptic memory in a
//!   topology-aware store (dense, diagonal, or banded per Eq. 9) with
//!   per-weight addressing (wt_in granularity) and the BRAM /
//!   distributed-LUT / register implementation choice.
//! * [`layer`] — N neurons + their synaptic memory + the address generator
//!   (M `mem_clk` cycles per timestep), with clock-gating accounting.
//! * [`core`] — K layers + the decoder's control registers; one spk_clk
//!   step runs the layers in dataflow order.
//! * [`aer`] — address-event-representation encoding of spike I/O.
//! * [`spikes`] — bit-packed [`SpikePlane`] spike vectors (the event-driven
//!   hot-path wire format), their 64-sample lane-batched transpose
//!   [`SpikeMatrix`], and the recycled-buffer [`PlanePool`]/[`MatrixPool`].
//! * [`clock`] — clock-domain bookkeeping and activity statistics that feed
//!   the power model.
//! * [`integrity`] — parity/SECDED codes guarding the synaptic and
//!   neuron-state memories against single-event upsets, plus the scrub
//!   ledger the serving engine aggregates.

pub mod aer;
pub mod verilog;
pub mod clock;
pub mod extensions;
pub mod integrity;
pub mod core;
pub mod layer;
pub mod memory;
pub mod neuron;
pub mod spikes;

pub use self::core::Core;
pub use clock::ActivityStats;
pub use integrity::IntegrityMode;
pub use layer::Layer;
pub use memory::SynapticMemory;
pub use neuron::LifNeuron;
pub use spikes::{MatrixPool, PlanePool, SpikeMatrix, SpikePlane};
