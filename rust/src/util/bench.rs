//! Minimal benchmark harness (criterion is not available in this offline
//! image — `cargo bench` targets use `harness = false` with this runner).
//!
//! Methodology: warm-up runs, then timed iterations until both a minimum
//! iteration count and a minimum wall-clock budget are met; reports
//! mean / median / p95 per-iteration time and derived throughput.

use std::time::{Duration, Instant};

use super::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() == 0.0 {
            0.0
        } else {
            1.0 / self.mean.as_secs_f64()
        }
    }
}

/// Run `f` repeatedly; at least `min_iters` iterations and `min_time` total.
pub fn bench<F: FnMut()>(name: &str, min_iters: usize, min_time: Duration, mut f: F) -> BenchResult {
    // Warm-up (also primes caches/JIT'd executables).
    for _ in 0..2.min(min_iters) {
        f();
    }
    let mut samples_us: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples_us.len() < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples_us.push(t0.elapsed().as_secs_f64() * 1e6);
        if samples_us.len() >= 10_000 {
            break; // enough statistics for anything we time here
        }
    }
    let r = BenchResult {
        name: name.to_string(),
        iters: samples_us.len(),
        mean: Duration::from_secs_f64(stats::mean(&samples_us) / 1e6),
        median: Duration::from_secs_f64(stats::median(&samples_us) / 1e6),
        p95: Duration::from_secs_f64(stats::percentile(&samples_us, 95.0) / 1e6),
    };
    println!(
        "{:44} {:>7} iters  mean {:>12?}  median {:>12?}  p95 {:>12?}  ({:.1}/s)",
        r.name,
        r.iters,
        r.mean,
        r.median,
        r.p95,
        r.per_sec()
    );
    r
}

/// Standard knobs for repo benches.
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 10, Duration::from_millis(400), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_min_iters() {
        let mut count = 0usize;
        let r = bench("noop", 5, Duration::from_millis(1), || count += 1);
        assert!(r.iters >= 5);
        assert!(count >= r.iters);
        assert!(r.per_sec() > 0.0);
    }
}
