//! Small self-contained utilities (no external deps are available offline).

pub mod bench;
pub mod benchcheck;
pub mod json;
pub mod stats;
pub mod table;
