//! Tiny statistics helpers (mean/median/percentiles/RMSE) used by the
//! benchmark harness and the experiment generators.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Root-mean-square error between two equal-length series.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "rmse: length mismatch");
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (s / a.len() as f64).sqrt()
}

/// Relative error |measured - reference| / |reference| (0 if both 0).
pub fn rel_err(measured: f64, reference: f64) -> f64 {
    if reference == 0.0 {
        if measured == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (measured - reference).abs() / reference.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 95.0), 95.0);
    }

    #[test]
    fn rmse_basics() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn rel_err_basics() {
        assert_eq!(rel_err(11.0, 10.0), 0.1);
        assert_eq!(rel_err(0.0, 0.0), 0.0);
        assert!(rel_err(1.0, 0.0).is_infinite());
    }

    #[test]
    fn stddev_basics() {
        assert_eq!(stddev(&[2.0, 2.0, 2.0]), 0.0);
        assert!((stddev(&[1.0, 3.0]) - 1.0).abs() < 1e-12);
    }
}
