//! Validation of `BENCH_*.json` perf reports — the library behind
//! `repro bench-check` (the `make bench-smoke` gate).
//!
//! Each recognized report kind carries acceptance thresholds: ≥ 5× fewer
//! synaptic ops for the Gaussian-r1 topology report; ≥ 3× packed
//! layer-step speedup at N=400 / 2% firing, positive engine throughput,
//! and — when the host's auto lane kernel is a real vector tier — a
//! ≥ 1.5× SIMD-vs-scalar lane-step speedup for the hot-path report; ≥ 2×
//! serving samples/s at lane width 64 vs 1 with zero pool misses for the
//! lane-batched report; positive throughput, zero protocol errors,
//! zero oracle mismatches, and a bounded p99 for the `serving_slo`
//! front-door report; zero oracle mismatches, at least one shard
//! recovery, an all-healthy final state, and a bounded recovery p99 for
//! the `chaos` soak report; and 100% injected-flip detection, at least
//! one in-place SECDED correction, zero survivor mismatches, and a
//! bounded scrub throughput overhead for the `integrity` SEU-soak
//! report.
//!
//! Outcomes are **typed**: a missing report file is a
//! [`ReportStatus::SkippedMissing`] — a skip the caller surfaces as a
//! warning, not an error — so a partial bench run (say, only
//! `bench-hotpath` on a laptop) can still be gate-checked without the
//! absent reports failing the command. Everything else that is wrong —
//! unreadable file, malformed JSON, unknown report kind, missing key, or
//! a gate below threshold — is an `Err` with the offending path and
//! value in the message.
//!
//! Thresholds live in [`Gates`]; [`Gates::from_env`] applies the CI
//! overrides (`BENCH_GATE_MIN_SPEEDUP`, `BENCH_GATE_MIN_BATCH_SPEEDUP`,
//! `BENCH_GATE_MIN_SIMD_SPEEDUP`, `BENCH_GATE_MAX_P99_US`,
//! `BENCH_GATE_MAX_RECOVERY_MS`, `BENCH_GATE_MAX_SCRUB_OVERHEAD`) on top
//! of the defaults, while tests pass explicit values for determinism.

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Acceptance thresholds for the wall-clock gates. Deterministic gates
/// (op ratios, zero-miss / zero-error counts) are not configurable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Gates {
    /// Minimum packed-vs-scalar layer-step speedup (hotpath report).
    pub min_speedup: f64,
    /// Minimum lane-64-vs-lane-1 serving speedup (batched report).
    pub min_batch_speedup: f64,
    /// Minimum SIMD-vs-scalar lane-step speedup (hotpath report). Only
    /// enforced when the report's `simd_kernel` is a vector tier; the
    /// scalar fallback keeps non-x86 hosts green by construction.
    pub min_simd_speedup: f64,
    /// Maximum front-door p99 latency in microseconds (serving_slo).
    pub max_p99_us: f64,
    /// Maximum shard detection→re-admission p99 latency in milliseconds
    /// (chaos report).
    pub max_recovery_ms: f64,
    /// Maximum fractional lane-64 throughput cost of background scrubbing
    /// (integrity report): `1 - sps_correct / sps_off` must not exceed it.
    pub max_scrub_overhead: f64,
}

impl Default for Gates {
    fn default() -> Self {
        Gates {
            min_speedup: 3.0,
            min_batch_speedup: 2.0,
            min_simd_speedup: 1.5,
            max_p99_us: 2_000_000.0,
            max_recovery_ms: 5_000.0,
            max_scrub_overhead: 0.10,
        }
    }
}

impl Gates {
    /// Defaults with the `BENCH_GATE_*` environment overrides applied —
    /// what the CLI uses. CI sets these lower on shared runners where
    /// timing medians get noisy; the defaults are the acceptance points.
    pub fn from_env() -> Self {
        fn env_f64(key: &str, default: f64) -> f64 {
            std::env::var(key).ok().and_then(|v| v.parse::<f64>().ok()).unwrap_or(default)
        }
        let d = Gates::default();
        Gates {
            min_speedup: env_f64("BENCH_GATE_MIN_SPEEDUP", d.min_speedup),
            min_batch_speedup: env_f64("BENCH_GATE_MIN_BATCH_SPEEDUP", d.min_batch_speedup),
            min_simd_speedup: env_f64("BENCH_GATE_MIN_SIMD_SPEEDUP", d.min_simd_speedup),
            max_p99_us: env_f64("BENCH_GATE_MAX_P99_US", d.max_p99_us),
            max_recovery_ms: env_f64("BENCH_GATE_MAX_RECOVERY_MS", d.max_recovery_ms),
            max_scrub_overhead: env_f64("BENCH_GATE_MAX_SCRUB_OVERHEAD", d.max_scrub_overhead),
        }
    }
}

/// Typed outcome of checking one report path.
#[derive(Debug, Clone, PartialEq)]
pub enum ReportStatus {
    /// The report parsed, its kind was recognized, and every gate passed.
    Validated {
        /// The report's `bench` kind, e.g. `"hotpath"`.
        kind: String,
        /// One human line summarizing the gated numbers.
        summary: String,
    },
    /// The report file does not exist. A skip, not a failure: the caller
    /// should warn (the report was requested but never generated) and
    /// keep checking the remaining paths.
    SkippedMissing {
        /// The path that was requested but absent.
        path: String,
    },
}

/// Check the report at `path`. A nonexistent file is the typed
/// [`ReportStatus::SkippedMissing`]; any other read failure, parse
/// failure, or gate failure is an error.
pub fn check_report(path: &str, gates: &Gates) -> Result<ReportStatus> {
    let text = match std::fs::read_to_string(path) {
        Ok(text) => text,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(ReportStatus::SkippedMissing { path: path.to_string() });
        }
        Err(e) => return Err(e).with_context(|| format!("reading {path}")),
    };
    check_report_str(path, &text, gates)
}

/// Check an already-read report body (`path` is for error messages).
pub fn check_report_str(path: &str, text: &str, gates: &Gates) -> Result<ReportStatus> {
    let json = Json::parse(text).with_context(|| format!("parsing {path}"))?;
    let bench = json.req("bench")?.as_str().context("bench key must be a string")?.to_string();
    let summary = match bench.as_str() {
        "bench_layer/topology" => check_topology(path, &json)?,
        "hotpath" => check_hotpath(path, &json, gates)?,
        "batched" => check_batched(path, &json, gates)?,
        "serving_slo" => check_serving_slo(path, &json, gates)?,
        "chaos" => check_chaos(path, &json, gates)?,
        "integrity" => check_integrity(path, &json, gates)?,
        other => anyhow::bail!("{path}: unknown bench report kind {other:?}"),
    };
    Ok(ReportStatus::Validated { kind: bench, summary })
}

fn check_topology(path: &str, json: &Json) -> Result<String> {
    let ratio = json
        .req("ops_ratio_fc400_over_gaussian_r1_400")?
        .as_f64()
        .context("ops ratio must be numeric")?;
    anyhow::ensure!(ratio >= 5.0, "{path}: ops ratio {ratio:.1} below the 5x gate");
    let cases = json.req("cases")?.as_arr().context("cases must be an array")?;
    anyhow::ensure!(!cases.is_empty(), "{path}: empty cases");
    Ok(format!("topology ops ratio {ratio:.1}x over {} cases", cases.len()))
}

fn check_hotpath(path: &str, json: &Json, gates: &Gates) -> Result<String> {
    let speedup =
        json.req("layer_speedup_n400_2pct")?.as_f64().context("layer speedup must be numeric")?;
    // Wall-clock gate. Default 3.0 per the PR-4 acceptance point;
    // BENCH_GATE_MIN_SPEEDUP relaxes it for heavily contended runners.
    anyhow::ensure!(
        speedup >= gates.min_speedup,
        "{path}: packed layer-step speedup {speedup:.2}x below the \
         {}x gate (N=400, 2% firing, gaussian r1)",
        gates.min_speedup
    );
    let cases = json.req("layer_cases")?.as_arr().context("layer_cases array")?;
    anyhow::ensure!(!cases.is_empty(), "{path}: empty layer_cases");

    // SIMD lane-kernel gate: the auto kernel's lane-step speedup over the
    // pinned scalar oracle (one-to-one N=400 @ 35% firing, 64 lanes).
    // When the host resolves `LaneKernel::auto` to the scalar fallback
    // the twins are the same kernel — the gate degenerates to a sanity
    // check, so non-x86 runners stay green without an override.
    let kernel = json.req("simd_kernel")?.as_str().context("simd_kernel string")?.to_string();
    let simd =
        json.req("simd_speedup_lane_step")?.as_f64().context("simd lane-step speedup numeric")?;
    let simd_cases = json.req("simd_cases")?.as_arr().context("simd_cases array")?;
    anyhow::ensure!(!simd_cases.is_empty(), "{path}: empty simd_cases");
    for c in simd_cases {
        let s = c.req("speedup")?.as_f64().context("simd case speedup numeric")?;
        anyhow::ensure!(s > 0.0, "{path}: non-positive simd case speedup");
    }
    if kernel == "scalar" {
        anyhow::ensure!(simd > 0.0, "{path}: non-positive scalar-fallback lane-step ratio");
    } else {
        anyhow::ensure!(
            simd >= gates.min_simd_speedup,
            "{path}: {kernel} lane-step speedup {simd:.2}x below the {}x SIMD gate \
             (one-to-one N=400, 35% firing, 64 lanes)",
            gates.min_simd_speedup
        );
    }

    let engine = json.req("engine")?;
    let seq = engine
        .req("sequential_samples_per_s")?
        .as_f64()
        .context("sequential_samples_per_s numeric")?;
    let by_cores = engine.req("by_cores")?.as_arr().context("by_cores array")?;
    anyhow::ensure!(seq > 0.0 && !by_cores.is_empty(), "{path}: missing engine throughput section");
    for c in by_cores {
        let sps = c.req("samples_per_s")?.as_f64().context("samples_per_s numeric")?;
        anyhow::ensure!(sps > 0.0, "{path}: non-positive engine throughput");
    }
    Ok(format!(
        "layer speedup {speedup:.1}x, {kernel} lane-step {simd:.1}x, \
         engine throughput for {} core counts",
        by_cores.len()
    ))
}

fn check_batched(path: &str, json: &Json, gates: &Gates) -> Result<String> {
    let speedup = json
        .req("speedup_lane64_over_lane1")?
        .as_f64()
        .context("batched speedup must be numeric")?;
    // Lane width 64 must serve ≥ 2× the samples/s of lane width 1 on the
    // gaussian-r1 N=400 case; BENCH_GATE_MIN_BATCH_SPEEDUP relaxes it.
    anyhow::ensure!(
        speedup >= gates.min_batch_speedup,
        "{path}: lane-64 serving speedup {speedup:.2}x below the \
         {}x gate (gaussian r1, N=400)",
        gates.min_batch_speedup
    );
    let misses = json.req("matrix_pool_misses")?.as_f64().context("matrix_pool_misses numeric")?;
    anyhow::ensure!(
        misses == 0.0,
        "{path}: lane-batched streaming allocated {misses} matrices (pool must not miss)"
    );
    let lanes = json.req("by_lane_width")?.as_arr().context("by_lane_width array")?;
    anyhow::ensure!(!lanes.is_empty(), "{path}: empty by_lane_width");
    for c in lanes {
        let sps = c.req("samples_per_s")?.as_f64().context("samples_per_s numeric")?;
        anyhow::ensure!(sps > 0.0, "{path}: non-positive batched throughput");
    }
    Ok(format!(
        "lane-64 serving speedup {speedup:.1}x over {} lane widths, zero pool misses",
        lanes.len()
    ))
}

fn check_serving_slo(path: &str, json: &Json, gates: &Gates) -> Result<String> {
    let ok = json.req("results_ok")?.as_f64().context("results_ok numeric")?;
    anyhow::ensure!(ok > 0.0, "{path}: no results served");
    let sps = json.req("samples_per_sec")?.as_f64().context("samples_per_sec numeric")?;
    anyhow::ensure!(sps > 0.0, "{path}: non-positive serving throughput");
    let p99 = json.req("p99_us")?.as_f64().context("p99_us numeric")?;
    // A deliberately generous CI bound: the gate exists to catch a wedged
    // pump or a pathological regression (seconds-scale tails), not to
    // benchmark shared runners. BENCH_GATE_MAX_P99_US overrides it.
    anyhow::ensure!(
        p99 > 0.0 && p99 <= gates.max_p99_us,
        "{path}: p99 latency {p99:.0}us outside (0, {:.0}]us",
        gates.max_p99_us
    );
    let perr = json.req("protocol_errors")?.as_f64().context("protocol_errors numeric")?;
    anyhow::ensure!(perr == 0.0, "{path}: {perr} protocol errors on the wire");
    let mism = json.req("result_mismatches")?.as_f64().context("result_mismatches numeric")?;
    anyhow::ensure!(mism == 0.0, "{path}: {mism} results diverged from the oracle");
    let rr = json.req("reject_rate")?.as_f64().context("reject_rate numeric")?;
    anyhow::ensure!((0.0..=1.0).contains(&rr), "{path}: reject_rate {rr} out of range");
    Ok(format!(
        "{ok:.0} results at {sps:.1}/s, p50/p99 {:.0}/{p99:.0}us, reject rate {:.1}%",
        json.req("p50_us")?.as_f64().unwrap_or(0.0),
        100.0 * rr,
    ))
}

fn check_chaos(path: &str, json: &Json, gates: &Gates) -> Result<String> {
    let ok = json.req("results_ok")?.as_f64().context("results_ok numeric")?;
    anyhow::ensure!(ok > 0.0, "{path}: chaos soak served no results");
    let mism = json.req("mismatches")?.as_f64().context("mismatches numeric")?;
    anyhow::ensure!(mism == 0.0, "{path}: {mism} surviving results diverged from the oracle");
    let recoveries = json.req("recoveries")?.as_f64().context("recoveries numeric")?;
    // A soak that never killed (and rebuilt) a shard proved nothing about
    // self-healing — fail closed rather than green-wash an idle run.
    anyhow::ensure!(recoveries >= 1.0, "{path}: no shard recovery exercised ({recoveries})");
    let healthy = json.req("all_healthy")?.as_f64().context("all_healthy numeric")?;
    anyhow::ensure!(healthy == 1.0, "{path}: engine did not end with every shard healthy");
    let p99 = json.req("recovery_p99_ms")?.as_f64().context("recovery_p99_ms numeric")?;
    // Detection→re-admission wall clock. The default bound is generous
    // (rebuild replays a checkpoint, not a training run); CI relaxes it
    // further via BENCH_GATE_MAX_RECOVERY_MS for contended runners.
    anyhow::ensure!(
        p99 > 0.0 && p99 <= gates.max_recovery_ms,
        "{path}: recovery p99 {p99:.1}ms outside (0, {:.0}]ms",
        gates.max_recovery_ms
    );
    Ok(format!(
        "{ok:.0} surviving results bit-exact, {recoveries:.0} recoveries, \
         recovery p50/p99 {:.1}/{p99:.1}ms",
        json.req("recovery_p50_ms")?.as_f64().unwrap_or(0.0),
    ))
}

fn check_integrity(path: &str, json: &Json, gates: &Gates) -> Result<String> {
    let injected = json.req("injected_flips")?.as_f64().context("injected_flips numeric")?;
    // A soak that never injected an upset proved nothing about the
    // integrity layer — fail closed, same policy as the chaos gate.
    anyhow::ensure!(injected >= 1.0, "{path}: no upsets injected ({injected})");
    let rate = json.req("detection_rate")?.as_f64().context("detection_rate numeric")?;
    anyhow::ensure!(
        rate == 1.0,
        "{path}: detection rate {rate} below 1.0 — an injected flip went unnoticed"
    );
    let corrected = json.req("corrected")?.as_f64().context("corrected numeric")?;
    anyhow::ensure!(
        corrected >= 1.0,
        "{path}: no in-place SECDED correction exercised ({corrected})"
    );
    let mism = json.req("mismatches")?.as_f64().context("mismatches numeric")?;
    anyhow::ensure!(mism == 0.0, "{path}: {mism} surviving results diverged from the oracle");
    let overhead = json.req("scrub_overhead")?.as_f64().context("scrub_overhead numeric")?;
    // Fractional lane-64 throughput cost of running with Correct-mode
    // scrubbing vs integrity off. The default bound is the 10% acceptance
    // point; BENCH_GATE_MAX_SCRUB_OVERHEAD relaxes it for noisy runners.
    anyhow::ensure!(
        overhead <= gates.max_scrub_overhead,
        "{path}: scrub overhead {:.1}% above the {:.1}% gate",
        100.0 * overhead,
        100.0 * gates.max_scrub_overhead
    );
    Ok(format!(
        "{injected:.0} upsets all detected, {corrected:.0} corrected in place, \
         scrub overhead {:.1}%",
        100.0 * overhead.max(0.0)
    ))
}
