//! Minimal JSON parser — enough for `artifacts/manifest.json` and the golden
//! vector files. (serde/serde_json are not available in this offline image;
//! this is a complete, strict RFC-8259 subset parser: no comments, no
//! trailing commas, `\uXXXX` escapes supported, numbers as f64.)

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the missing path (for manifests).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a numeric array.
    pub fn num_vec(&self) -> anyhow::Result<Vec<f64>> {
        let arr = self.as_arr().ok_or_else(|| anyhow::anyhow!("expected array"))?;
        arr.iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("expected number")))
            .collect()
    }

    pub fn i32_vec(&self) -> anyhow::Result<Vec<i32>> {
        Ok(self.num_vec()?.into_iter().map(|x| x as i32).collect())
    }
}

/// Recursion bound for nested arrays/objects: deep enough for any real
/// manifest, shallow enough that adversarial `[[[[…` input cannot blow the
/// parser's stack.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        let c = self.peek().ok_or_else(|| self.err("eof"))?;
        match c {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' | b'{' => {
                self.depth += 1;
                if self.depth > MAX_DEPTH {
                    return Err(self.err("nesting too deep"));
                }
                let v = if c == b'[' { self.array() } else { self.object() };
                self.depth -= 1;
                v
            }
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected byte")),
        }
    }

    /// Four hex digits of a `\uXXXX` escape (strict: `+`/whitespace that
    /// `from_str_radix` would tolerate are rejected).
    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let digits = &self.b[self.pos..self.pos + 4];
        if !digits.iter().all(|d| d.is_ascii_hexdigit()) {
            return Err(self.err("bad \\u hex"));
        }
        let hex = std::str::from_utf8(digits).map_err(|_| self.err("bad \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u hex"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            // Copy the longest run of plain bytes as one slice. The input
            // came in as &str, and a run bounded by ASCII delimiters sits
            // on char boundaries, so any multi-byte UTF-8 inside it is
            // already valid — pushing bytes one at a time as `c as char`
            // would mangle it into Latin-1.
            let start = self.pos;
            while let Some(c) = self.peek() {
                if c == b'"' || c == b'\\' || c < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            if self.pos > start {
                let run = std::str::from_utf8(&self.b[start..self.pos])
                    .map_err(|_| self.err("invalid utf-8 in string"))?;
                out.push_str(run);
            }
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let ch = match hi {
                                // UTF-16 high surrogate: only valid as the
                                // first half of a \uD8xx\uDCxx pair.
                                0xD800..=0xDBFF => {
                                    if self.peek() != Some(b'\\')
                                        || self.b.get(self.pos + 1) != Some(&b'u')
                                    {
                                        return Err(self.err("lone high surrogate"));
                                    }
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..=0xDFFF).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(cp)
                                        .ok_or_else(|| self.err("bad surrogate pair"))?
                                }
                                0xDC00..=0xDFFF => return Err(self.err("lone low surrogate")),
                                _ => char::from_u32(hi)
                                    .ok_or_else(|| self.err("bad \\u codepoint"))?,
                            };
                            out.push(ch);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => return Err(self.err("raw control character in string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| self.err("utf8"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""\u0041""#).unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse(r#""\u00e9""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn surrogate_pairs_combine() {
        // U+1F600 encoded as a UTF-16 pair, the way serde_json and
        // JSON.stringify emit astral-plane characters.
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap(),
            Json::Str("\u{1F600}".into())
        );
        assert_eq!(
            Json::parse(r#""x\ud83d\ude00y""#).unwrap(),
            Json::Str("x\u{1F600}y".into())
        );
    }

    #[test]
    fn lone_surrogates_rejected() {
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone high surrogate");
        assert!(Json::parse(r#""\ude00""#).is_err(), "lone low surrogate");
        assert!(Json::parse(r#""\ud83dA""#).is_err(), "high + non-surrogate");
        assert!(Json::parse(r#""\ud83dx""#).is_err(), "high + literal");
        assert!(Json::parse(r#""\ud83d\n""#).is_err(), "high + simple escape");
    }

    #[test]
    fn multibyte_utf8_passes_through() {
        // Raw (unescaped) multi-byte characters must survive intact, not
        // be re-encoded byte-by-byte as Latin-1.
        assert_eq!(Json::parse("\"héllo — 😀\"").unwrap(), Json::Str("héllo — 😀".into()));
        let j = Json::parse("\"日本語\"").unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j, "display roundtrip");
    }

    #[test]
    fn bad_hex_and_control_chars_rejected() {
        assert!(Json::parse(r#""\uZZZZ""#).is_err());
        assert!(Json::parse(r#""\u+12f""#).is_err(), "from_str_radix leniency must not leak");
        assert!(Json::parse("\"a\nb\"").is_err(), "raw control character in string");
    }

    #[test]
    fn depth_cap_guards_the_stack() {
        let deep_ok = format!("{}0{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&deep_ok).is_ok());
        let too_deep = format!("{}0{}", "[".repeat(5000), "]".repeat(5000));
        let err = Json::parse(&too_deep).unwrap_err();
        assert!(err.msg.contains("nesting too deep"), "{err}");
        let mixed = format!("{}0", r#"[{"k":"#.repeat(3000));
        assert!(Json::parse(&mixed).is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,"s"],"b":{"c":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn num_vec_helpers() {
        let j = Json::parse("[1, 2, -3]").unwrap();
        assert_eq!(j.i32_vec().unwrap(), vec![1, 2, -3]);
        assert!(Json::parse("[1, \"x\"]").unwrap().num_vec().is_err());
    }
}
