//! ASCII table rendering for the experiment harness — every paper table /
//! figure generator returns a [`Table`] that prints the same rows the paper
//! reports, plus (where applicable) the paper's published value and the
//! relative error of our model against it.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Markdown rendering (used when writing EXPERIMENTS.md sections).
    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out += &format!("| {} |\n", self.headers.join(" | "));
        out += &format!("|{}|\n", self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            out += &format!("| {} |\n", row.join(" | "));
        }
        for n in &self.notes {
            out += &format!("\n> {n}\n");
        }
        out
    }
}

impl fmt::Display for Table {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let w = self.widths();
        let line = |f: &mut fmt::Formatter<'_>| -> fmt::Result {
            write!(f, "+")?;
            for wi in &w {
                write!(f, "{}+", "-".repeat(wi + 2))?;
            }
            writeln!(f)
        };
        writeln!(f, "\n== {} ==", self.title)?;
        line(f)?;
        write!(f, "|")?;
        for (h, wi) in self.headers.iter().zip(&w) {
            write!(f, " {h:<wi$} |")?;
        }
        writeln!(f)?;
        line(f)?;
        for row in &self.rows {
            write!(f, "|")?;
            for (c, wi) in row.iter().zip(&w) {
                write!(f, " {c:<wi$} |")?;
            }
            writeln!(f)?;
        }
        line(f)?;
        for n in &self.notes {
            writeln!(f, "  note: {n}")?;
        }
        Ok(())
    }
}

/// Format helpers shared by experiment generators.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

pub fn fmt_f(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// "paper / ours / err%" triple cell used throughout EXPERIMENTS.md.
pub fn cmp_cell(ours: f64, paper: f64, digits: usize) -> String {
    let err = super::stats::rel_err(ours, paper);
    format!("{ours:.digits$} (paper {paper:.digits$}, err {:.1}%)", err * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.note("n");
        let s = t.to_string();
        assert!(s.contains("== T =="));
        assert!(s.contains("| 1"));
        assert!(s.contains("note: n"));
        let md = t.to_markdown();
        assert!(md.contains("| a | bb |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn cmp_cell_formats() {
        let c = cmp_cell(11.0, 10.0, 1);
        assert!(c.contains("11.0") && c.contains("err 10.0%"), "{c}");
    }
}
