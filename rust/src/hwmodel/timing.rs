//! Static-timing model — setup slack vs spike frequency per synaptic-memory
//! fabric (paper Fig. 13).
//!
//! Setup slack = required time − arrival time at the worst endpoint. For a
//! single-cycle spike-clock path, required time is the period 1/f and the
//! arrival time is the fabric-dependent critical-path delay. Calibration:
//! the paper's measured peak spike frequencies (least positive slack) are
//! 925 kHz (BRAM), 850 kHz (distributed LUT) and 500 kHz (register file —
//! "multiple timing violations at 600 kHz", peak 500 kHz), giving critical
//! paths of 1081 ns / 1176 ns / 2000 ns respectively.

use crate::config::MemKind;

/// Critical-path delay of the spike-clock domain per memory fabric (ns).
pub fn critical_path_ns(mem: MemKind) -> f64 {
    match mem {
        MemKind::Bram => 1.0e9 / 925_000.0,          // ≈ 1081 ns
        MemKind::DistributedLut => 1.0e9 / 850_000.0, // ≈ 1176 ns
        MemKind::Register => 1.0e9 / 500_000.0,       // = 2000 ns
    }
}

/// Worst setup slack (ns) at spike frequency `f_hz` — one Fig. 13 point.
/// Negative slack = timing violation.
pub fn setup_slack_ns(mem: MemKind, f_hz: f64) -> f64 {
    1.0e9 / f_hz - critical_path_ns(mem)
}

/// Peak spike frequency (Hz): the highest f with non-negative slack.
pub fn peak_frequency_hz(mem: MemKind) -> f64 {
    1.0e9 / critical_path_ns(mem)
}

/// Baseline synapse count the Fig. 13 critical paths were measured at.
pub const SYN0: f64 = 34_048.0;

/// Size-dependent peak frequency: routing/congestion stretches the critical
/// path roughly linearly with the synaptic fabric, so larger cores close
/// timing at proportionally lower spike frequencies. Calibrated against the
/// paper's Table XI peak-perf/W operating points (smnist ≈ 600 kHz, DVS ≈
/// 200 kHz, SHD ≈ 100 kHz — back-computed from Eq. 12 and the published
/// GOPS/W), which fall off ≈ 1/size.
pub fn peak_frequency_scaled_hz(mem: MemKind, synapses: usize) -> f64 {
    let ratio = (synapses as f64 / SYN0).max(1.0);
    peak_frequency_hz(mem) / ratio
}

/// True iff the design meets timing at `f_hz`.
pub fn meets_timing(mem: MemKind, f_hz: f64) -> bool {
    setup_slack_ns(mem, f_hz) >= 0.0
}

/// The Fig. 13 sweep grid (kHz): 100 → 1200.
pub fn fig13_grid_hz() -> Vec<f64> {
    [100, 200, 400, 600, 800, 1000, 1200].iter().map(|k| *k as f64 * 1e3).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_frequencies_match_paper() {
        assert!((peak_frequency_hz(MemKind::Bram) - 925e3).abs() < 1.0);
        assert!((peak_frequency_hz(MemKind::DistributedLut) - 850e3).abs() < 1.0);
        assert!((peak_frequency_hz(MemKind::Register) - 500e3).abs() < 1.0);
    }

    #[test]
    fn register_violates_at_600khz() {
        // Paper: "multiple timing violations for register-based memory" at 600 kHz.
        assert!(!meets_timing(MemKind::Register, 600e3));
        assert!(meets_timing(MemKind::Bram, 600e3));
        assert!(meets_timing(MemKind::DistributedLut, 600e3));
    }

    #[test]
    fn all_positive_up_to_400khz() {
        // Paper: slack positive for 100/200/400 kHz for all three fabrics.
        for mem in MemKind::all() {
            for f in [100e3, 200e3, 400e3] {
                assert!(setup_slack_ns(mem, f) > 0.0, "{mem:?} at {f}");
            }
        }
    }

    #[test]
    fn slack_monotone_decreasing_in_f() {
        for mem in MemKind::all() {
            let mut prev = f64::INFINITY;
            for f in fig13_grid_hz() {
                let s = setup_slack_ns(mem, f);
                assert!(s < prev);
                prev = s;
            }
        }
    }

    #[test]
    fn bram_supports_highest_peak() {
        assert!(peak_frequency_hz(MemKind::Bram) > peak_frequency_hz(MemKind::DistributedLut));
        assert!(peak_frequency_hz(MemKind::DistributedLut) > peak_frequency_hz(MemKind::Register));
    }
}
