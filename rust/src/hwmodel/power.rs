//! Dynamic power model — activity-driven, clock-gated.
//!
//! The paper estimates power from post-implementation toggle rates (§IV).
//! Our substitute drives the same kind of estimate from the cycle-accurate
//! simulator's *measured* activity ([`crate::hdl::ActivityStats`]):
//!
//! `P_dyn(config, rate, f) = mem·quant·(f/f₀)·(syn/syn₀)·(P_base + P_act·rate/rate₀)`
//!
//! Calibration anchors (EXPERIMENTS.md reports per-cell errors):
//! * Table X power-vs-spikes line: least-squares over the six published
//!   (spikes/neuron, W) pairs gives `P = 0.253 + 0.0175·spikes` at the
//!   baseline size and f₀ = 600 kHz ⇒ `P_base = 0.253 W`,
//!   `P_act = 0.454 W` at the baseline rate (26 spikes / 150 steps).
//!   The 7-spike point sits ~17 % above the global line (the paper's own
//!   R/C sweep is not perfectly linear); per-cell errors are in
//!   EXPERIMENTS.md.
//! * Table VI rows 3–4: power scales ≈ linearly with synapse count.
//! * Table VI row 2: Q9.7 = +18.5 % ⇒ quant scale `1 + 0.185·(W−8)/8`.
//! * Fig. 13 subplot: distributed-LUT memory is 23 % below BRAM and 79 %
//!   below register memory.
//! * Fig. 14: performance/W peaks below the peak frequency — modelled by a
//!   static floor (clock tree + leakage-like) plus a cubic high-frequency
//!   term: `P_total(f) = α·P_op + β·P_op·(f/f₀) + γ·P_op·(f/f₀)³` with
//!   α = 0.4, γ = 0.2·√(syn/syn₀), β = 1 − α − γ, which puts the baseline
//!   architecture's optimum exactly at the paper's 600 kHz.

use crate::config::{MemKind, ModelConfig, Topology};
use crate::fixed::QSpec;
use crate::hdl::ActivityStats;

/// Baseline operating point (paper §VI-D).
pub const F0_HZ: f64 = 600_000.0;
const SYN0: f64 = 34_048.0;
/// Paper Table X baseline: 26 spikes/neuron over a 150-step exposure.
pub const RATE0: f64 = 26.0 / 150.0;
const P_BASE_W: f64 = 0.253;
const P_ACT_W: f64 = 0.454;
/// Eq. 12: fixed-point operations per neuron per cycle.
pub const N_OPS: f64 = 10.0;

/// Memory-fabric power multiplier (Fig. 13 subplot).
pub fn mem_scale(mem: MemKind) -> f64 {
    match mem {
        MemKind::Bram => 1.0,
        MemKind::DistributedLut => 0.77,
        MemKind::Register => 0.77 / 0.21, // LUT is 79% below register
    }
}

/// Quantization power multiplier anchored at Q5.3 (Table VI row 2).
pub fn quant_scale(qspec: QSpec) -> f64 {
    (1.0 + 0.185 * (qspec.width() as f64 - 8.0) / 8.0).max(0.25)
}

/// Core dynamic power (W) at spike frequency `f_hz` for a measured
/// per-neuron-per-step spike rate — the "Dynamic (Peak) Power" columns of
/// Tables VI, X, XI. Synapse count from the static topology model; see
/// [`core_dynamic_instance_w`] for the store-measured variant.
pub fn core_dynamic_w(config: &ModelConfig, spike_rate: f64, f_hz: f64) -> f64 {
    dynamic_w_with_synapses(config, config.total_synapses(), spike_rate, f_hz)
}

/// As [`core_dynamic_w`], but with the synapse count measured from an
/// instantiated core's topology-aware stores
/// ([`crate::hdl::Core::synapse_words`]) — a sparse (one-to-one/Gaussian)
/// core is charged only for the synapses it physically stores.
pub fn core_dynamic_instance_w(core: &crate::hdl::Core, spike_rate: f64, f_hz: f64) -> f64 {
    dynamic_w_with_synapses(core.config(), core.synapse_words(), spike_rate, f_hz)
}

fn dynamic_w_with_synapses(
    config: &ModelConfig,
    synapses: usize,
    spike_rate: f64,
    f_hz: f64,
) -> f64 {
    let syn = synapses as f64;
    mem_scale(config.mem)
        * quant_scale(config.qspec)
        * (f_hz / F0_HZ)
        * (syn / SYN0)
        * (P_BASE_W + P_ACT_W * (spike_rate / RATE0))
}

/// Same, taking the simulator's activity ledger directly.
pub fn core_dynamic_from_stats(config: &ModelConfig, stats: &ActivityStats, f_hz: f64) -> f64 {
    core_dynamic_w(config, stats.spike_rate(), f_hz)
}

/// Total power including the static floor and the high-frequency term —
/// the denominator of the Fig. 14 performance-per-watt curves.
pub fn core_total_w(config: &ModelConfig, spike_rate: f64, f_hz: f64) -> f64 {
    let p_op = core_dynamic_w(config, spike_rate, F0_HZ);
    let syn = config.total_synapses() as f64;
    let alpha = 0.4;
    let gamma = 0.2 * (syn / SYN0).sqrt();
    let beta = 1.0 - alpha - gamma;
    let x = f_hz / F0_HZ;
    p_op * (alpha + beta * x + gamma * x * x * x)
}

/// Eq. 12: total fixed-point operations per second at frequency `f_hz`.
pub fn fixed_point_ops(config: &ModelConfig, f_hz: f64) -> f64 {
    (config.total_synapses() as f64 + N_OPS * config.total_neurons() as f64) * f_hz
}

/// Performance per watt (GOPS/W) at `f_hz` — one point of Fig. 14.
pub fn perf_per_watt(config: &ModelConfig, spike_rate: f64, f_hz: f64) -> f64 {
    fixed_point_ops(config, f_hz) / core_total_w(config, spike_rate, f_hz) / 1e9
}

/// Sweep Fig. 14 and return (f_peak_hz, peak GOPS/W). The sweep is capped
/// at the size-dependent timing limit (`timing::peak_frequency_scaled_hz`):
/// large cores cannot be clocked at the baseline's frequencies, which is
/// what pushes the paper's DVS/SHD designs to lower peak-perf/W points.
pub fn peak_perf_per_watt(config: &ModelConfig, spike_rate: f64) -> (f64, f64) {
    let f_cap = crate::hwmodel::timing::peak_frequency_scaled_hz(
        config.mem,
        config.total_synapses(),
    );
    let mut best = (0.0, 0.0);
    let mut f = 10_000.0;
    while f <= f_cap {
        let ppw = perf_per_watt(config, spike_rate, f);
        if ppw > best.1 {
            best = (f, ppw);
        }
        f += 5_000.0;
    }
    best
}

/// Standalone connection-block power (Table V, mW after implementation).
pub fn connection_block_power_mw(topology: Topology, fan_in: usize) -> f64 {
    match topology {
        Topology::OneToOne => 12.0,
        Topology::Gaussian { radius } => {
            let taps = ((2 * radius + 1) * (2 * radius + 1)) as f64;
            16.4 + 0.0625 * taps
        }
        Topology::AllToAll => 14.67 + 0.0651 * fan_in as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q5_3, Q9_7};
    use crate::util::stats::rel_err;

    fn baseline() -> ModelConfig {
        ModelConfig::parse_arch("256x128x10", Q5_3).unwrap()
    }

    #[test]
    fn table10_power_line() {
        // 26 spikes/neuron ⇒ 0.663 W; 7 ⇒ ~0.449 W; 45 ⇒ ~1.087 W.
        let c = baseline();
        for (spikes, watts, tol) in [(26.0, 0.663, 0.07), (7.0, 0.449, 0.25), (45.0, 1.087, 0.08)] {
            let p = core_dynamic_w(&c, spikes / 150.0, F0_HZ);
            assert!(rel_err(p, watts) < tol, "{spikes} spikes: {p} vs {watts}");
        }
    }

    #[test]
    fn table6_power_scaling() {
        let c1 = baseline();
        let c3 = ModelConfig::parse_arch("256x256x10", Q5_3).unwrap();
        let p1 = core_dynamic_w(&c1, RATE0, F0_HZ);
        let p3 = core_dynamic_w(&c3, RATE0, F0_HZ);
        assert!(rel_err(p3 / p1, 2.0) < 0.01, "2x synapses ⇒ 2x power");
        // Q9.7 = +18.5%.
        let q97 = ModelConfig::parse_arch("256x128x10", Q9_7).unwrap();
        assert!(rel_err(core_dynamic_w(&q97, RATE0, F0_HZ) / p1, 1.185) < 0.001);
    }

    #[test]
    fn power_linear_in_frequency() {
        let c = baseline();
        let p6 = core_dynamic_w(&c, RATE0, 600e3);
        let p3 = core_dynamic_w(&c, RATE0, 300e3);
        assert!(rel_err(p6 / p3, 2.0) < 1e-9);
    }

    #[test]
    fn mem_scales_fig13() {
        assert_eq!(mem_scale(MemKind::Bram), 1.0);
        assert!(rel_err(mem_scale(MemKind::DistributedLut), 0.77) < 1e-9);
        assert!(mem_scale(MemKind::Register) > 3.0);
    }

    #[test]
    fn fig14_baseline_peak_at_600khz() {
        let c = baseline();
        let (f_peak, ppw) = peak_perf_per_watt(&c, RATE0);
        assert!((f_peak - 600e3).abs() <= 20e3, "peak at {f_peak}");
        // Table XI: 36.6 GOPS/W. The paper computes this with Table VI's
        // 0.623 W; our Table-X-calibrated line gives 0.707 W at the same
        // point (the paper's own inter-table spread is 0.623 vs 0.663),
        // hence ~12% relative error here — recorded in EXPERIMENTS.md.
        assert!(rel_err(ppw, 36.6) < 0.15, "peak {ppw} GOPS/W");
    }

    #[test]
    fn fig14_bigger_designs_peak_lower() {
        let c1 = baseline();
        let c4 = ModelConfig::parse_arch("256x256x256x10", Q5_3).unwrap();
        let (f1, _) = peak_perf_per_watt(&c1, RATE0);
        let (f4, _) = peak_perf_per_watt(&c4, RATE0);
        assert!(f4 < f1, "larger design should peak at lower frequency");
    }

    #[test]
    fn fixed_ops_eq12() {
        let c = baseline();
        assert_eq!(fixed_point_ops(&c, 600e3), (34048.0 + 10.0 * 394.0) * 600e3);
    }

    #[test]
    fn table5_power_rows() {
        assert_eq!(connection_block_power_mw(Topology::OneToOne, 1), 12.0);
        let c3 = connection_block_power_mw(Topology::Gaussian { radius: 1 }, 20);
        let fc128 = connection_block_power_mw(Topology::AllToAll, 128);
        let fc512 = connection_block_power_mw(Topology::AllToAll, 512);
        assert!(rel_err(c3, 17.0) < 0.02);
        assert!(rel_err(fc128, 23.0) < 0.01);
        assert!(rel_err(fc512, 48.0) < 0.01);
    }

    #[test]
    fn stats_driven_power() {
        let c = baseline();
        let stats = ActivityStats { neuron_updates: 1000, spikes: 173, ..Default::default() };
        let direct = core_dynamic_w(&c, 0.173, F0_HZ);
        assert!(rel_err(core_dynamic_from_stats(&c, &stats, F0_HZ), direct) < 1e-9);
    }

    #[test]
    fn instance_power_matches_static_model() {
        let sparse = ModelConfig::with_topologies(
            &[32, 32, 32],
            &[Topology::OneToOne, Topology::Gaussian { radius: 1 }],
            Q5_3,
        )
        .unwrap();
        for cfg in [baseline(), sparse] {
            let core = crate::hdl::Core::new(cfg.clone());
            let a = core_dynamic_instance_w(&core, RATE0, F0_HZ);
            let b = core_dynamic_w(&cfg, RATE0, F0_HZ);
            assert!(rel_err(a, b) < 1e-12, "{}: {a} vs {b}", cfg.arch_name());
        }
    }
}
