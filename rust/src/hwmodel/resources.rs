//! FPGA resource model — LUT/FF/BRAM/DSP utilisation.
//!
//! Calibration (see DESIGN.md §6 and EXPERIMENTS.md for per-cell errors):
//!
//! * **Single neuron vs quantization** (Table IV): the five published
//!   (W → LUT/FF/DSP/power) points are anchors; unevaluated widths
//!   interpolate piecewise-linearly. FFs are well fit by `4W + 3`; the
//!   anchor table keeps the exact published values.
//! * **Standalone connection blocks** (Table V): affine fits in the fan-in
//!   (FC) or tap count (conv): `LUT = 286 + 1.047·M`, `FF = 60 + 3·M`
//!   (FC rows), `LUT = 275 + 1·taps`, `FF = 51.9 + 3.125·taps` (conv rows).
//! * **Full cores** (Table VI): utilisation is dominated by synaptic
//!   plumbing: `LUT = 1.35·synapses + 8·neurons`, `FF = 0.28·synapses +
//!   2.5·neurons`, `BRAM = 0.5` per compute neuron (exactly reproduces the
//!   69/133/261 BRAM column), `DSP = 2·compute_neurons` for W ≥ 16.
//!   Quantization scaling from Table VI row 2: Q9.7 multiplies LUTs by
//!   1.045 and FFs by 1.422 relative to Q5.3.
//! * Memory choice: distributed-LUT storage converts BRAM words into LUTs
//!   (64 weight-bits/LUT-RAM); register storage converts them into FFs.

use crate::config::{MemKind, ModelConfig, Topology};
use crate::fixed::QSpec;

/// A resource vector (fractional BRAMs are real on AMD parts: half-BRAM18).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Resources {
    pub luts: f64,
    pub ffs: f64,
    pub brams: f64,
    pub dsps: f64,
}

impl Resources {
    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            luts: self.luts + o.luts,
            ffs: self.ffs + o.ffs,
            brams: self.brams + o.brams,
            dsps: self.dsps + o.dsps,
        }
    }

    pub fn scale(&self, s: f64) -> Resources {
        Resources { luts: self.luts * s, ffs: self.ffs * s, brams: self.brams * s, dsps: self.dsps * s }
    }
}

/// Table IV anchors: (width, LUTs, FFs, DSPs, dynamic peak power mW @100MHz).
const NEURON_ANCHORS: [(f64, f64, f64, f64, f64); 5] = [
    (1.0, 14.0, 11.0, 0.0, 3.0),
    (4.0, 66.0, 19.0, 0.0, 4.0),
    (8.0, 245.0, 35.0, 0.0, 6.0),
    (16.0, 242.0, 68.0, 2.0, 14.0),
    (32.0, 856.0, 132.0, 8.0, 27.0),
];

fn interp(anchors: &[(f64, f64)], x: f64) -> f64 {
    if x <= anchors[0].0 {
        return anchors[0].1;
    }
    for w in anchors.windows(2) {
        let ((x0, y0), (x1, y1)) = (w[0], w[1]);
        if x <= x1 {
            return y0 + (x - x0) / (x1 - x0) * (y1 - y0);
        }
    }
    anchors.last().unwrap().1
}

/// Single standalone LIF neuron (Table IV row for width W = n+q).
pub fn lif_neuron(qspec: QSpec) -> Resources {
    let w = qspec.width() as f64;
    let col = |i: usize| -> Vec<(f64, f64)> {
        NEURON_ANCHORS
            .iter()
            .map(|a| (a.0, [a.1, a.2, a.3, a.4][i]))
            .collect()
    };
    Resources {
        luts: interp(&col(0), w).round(),
        ffs: interp(&col(1), w).round(),
        brams: 0.0,
        dsps: interp(&col(2), w).round(),
    }
}

/// Single-neuron dynamic peak power (mW @ 100 MHz spike clock, Table IV).
pub fn lif_neuron_power_mw(qspec: QSpec) -> f64 {
    let w = qspec.width() as f64;
    let anchors: Vec<(f64, f64)> = NEURON_ANCHORS.iter().map(|a| (a.0, a.4)).collect();
    interp(&anchors, w)
}

/// Standalone neuron + connection block (Table V rows), Q5.3, per neuron.
pub fn connection_block(topology: Topology, fan_in: usize, mem: MemKind) -> Resources {
    let m = fan_in as f64;
    match topology {
        // Single published point (Table V row 1) used as an exact anchor.
        Topology::OneToOne => Resources { luts: 296.0, ffs: 56.0, brams: 0.0, dsps: 0.0 },
        Topology::Gaussian { radius } => {
            // Table V reports the 2-D filter (taps = (2r+1)^2): 3×3 / 5×5.
            let taps = ((2 * radius + 1) * (2 * radius + 1)) as f64;
            let base = Resources {
                luts: (275.0 + taps).round(),
                ffs: (51.9 + 3.125 * taps).round(),
                brams: 0.5,
                dsps: 0.0,
            };
            apply_mem_kind(base, taps, MemKind::Bram, mem)
        }
        Topology::AllToAll => {
            let base = Resources {
                luts: (286.0 + 1.047 * m).round(),
                ffs: (60.0 + 3.0 * m).round(),
                brams: 0.5,
                dsps: 0.0,
            };
            apply_mem_kind(base, m, MemKind::Bram, mem)
        }
    }
}

/// Convert the synaptic-storage component between memory kinds: BRAM words
/// (8-bit Q5.3 baseline) become distributed-LUT RAM at 64 bits/LUT or
/// flip-flops at 1 bit/FF.
fn apply_mem_kind(base: Resources, words: f64, from: MemKind, to: MemKind) -> Resources {
    if from == to {
        return base;
    }
    let bits = words * 8.0;
    let mut r = base;
    // Strip the BRAM storage, then add the substitute fabric storage.
    r.brams = 0.0;
    match to {
        MemKind::Bram => r.brams = base.brams,
        MemKind::DistributedLut => r.luts += (bits / 64.0).ceil(),
        MemKind::Register => r.ffs += bits,
    }
    r
}

/// Quantization scaling for full cores, anchored at Q5.3 (Table VI row 2:
/// Q9.7 = +4.5% LUT, +42.2% FF). Scales linearly in (W − 8)/8.
fn quant_scale(qspec: QSpec) -> (f64, f64) {
    let d = (qspec.width() as f64 - 8.0) / 8.0;
    ((1.0 + 0.045 * d).max(0.5), (1.0 + 0.422 * d).max(0.5))
}

/// Full-core utilisation (Table VI model). `config.mem` selects the
/// synaptic storage fabric. The synapse count comes from the static
/// topology model; [`core_instance`] measures it from an instantiated
/// core's actual stores instead.
pub fn core(config: &ModelConfig) -> Resources {
    core_with_synapses(config, config.total_synapses())
}

/// As [`core()`], but with the synapse count measured from an instantiated
/// core's topology-aware stores ([`crate::hdl::Core::synapse_words`]) —
/// resource reporting driven by what the core is physically made of. The
/// static mask model and the physical store agree exactly (asserted in
/// tests), so this differs from [`core()`] only in provenance.
pub fn core_instance(core: &crate::hdl::Core) -> Resources {
    core_with_synapses(core.config(), core.synapse_words())
}

fn core_with_synapses(config: &ModelConfig, synapses: usize) -> Resources {
    let syn = synapses as f64;
    let neurons = config.total_neurons() as f64;
    let compute = config.compute_neurons() as f64;
    let (ls, fs) = quant_scale(config.qspec);

    let mut r = Resources {
        luts: (1.35 * syn + 8.0 * neurons) * ls,
        ffs: (0.28 * syn + 2.5 * neurons) * fs,
        brams: 0.5 * compute,
        dsps: if config.qspec.width() >= 16 { 2.0 * compute } else { 0.0 },
    };
    // Memory fabric substitution for the whole synaptic store.
    let bits = syn * config.qspec.width() as f64;
    match config.mem {
        MemKind::Bram => {}
        MemKind::DistributedLut => {
            r.brams = 0.0;
            r.luts += (bits / 64.0).ceil();
        }
        MemKind::Register => {
            r.brams = 0.0;
            r.ffs += bits;
        }
    }
    r
}

/// Utilisation as fractions of a board (the percent columns of Table VI).
pub fn utilisation(r: &Resources, board: &super::boards::Board) -> (f64, f64, f64, f64) {
    (
        r.luts / board.luts as f64,
        r.ffs / board.ffs as f64,
        r.brams / board.brams,
        if board.dsps == 0 { 0.0 } else { r.dsps / board.dsps as f64 },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q17_15, Q1_0, Q5_3, Q9_7};
    use crate::hwmodel::boards::VIRTEX_ULTRASCALE;
    use crate::util::stats::rel_err;

    #[test]
    fn table4_anchors_exact() {
        let r = lif_neuron(Q5_3);
        assert_eq!((r.luts, r.ffs, r.dsps), (245.0, 35.0, 0.0));
        let r = lif_neuron(Q9_7);
        assert_eq!((r.luts, r.ffs, r.dsps), (242.0, 68.0, 2.0));
        let r = lif_neuron(Q17_15);
        assert_eq!((r.luts, r.ffs, r.dsps), (856.0, 132.0, 8.0));
        assert_eq!(lif_neuron(Q1_0).luts, 14.0);
        assert_eq!(lif_neuron_power_mw(Q17_15), 27.0);
    }

    #[test]
    fn table4_ratios_hold() {
        // Paper: 32-bit uses 61x more LUTs, 12x more FFs than binary.
        let b = lif_neuron(Q1_0);
        let w32 = lif_neuron(Q17_15);
        assert!((w32.luts / b.luts - 61.0).abs() < 1.0);
        assert!((w32.ffs / b.ffs - 12.0).abs() < 0.1);
        // 9x more power.
        assert!((lif_neuron_power_mw(Q17_15) / lif_neuron_power_mw(Q1_0) - 9.0).abs() < 0.1);
    }

    #[test]
    fn table5_fc_rows() {
        for (m, lut, ff) in [(128usize, 420.0, 443.0), (256, 551.0, 829.0), (512, 822.0, 1599.0)] {
            let r = connection_block(Topology::AllToAll, m, MemKind::Bram);
            assert!(rel_err(r.luts, lut) < 0.02, "M={m} luts {} vs {lut}", r.luts);
            assert!(rel_err(r.ffs, ff) < 0.02, "M={m} ffs {} vs {ff}", r.ffs);
            assert_eq!(r.brams, 0.5);
        }
    }

    #[test]
    fn table5_conv_rows() {
        let c3 = connection_block(Topology::Gaussian { radius: 1 }, 20, MemKind::Bram);
        let c5 = connection_block(Topology::Gaussian { radius: 2 }, 20, MemKind::Bram);
        assert!(rel_err(c3.luts, 284.0) < 0.02);
        assert!(rel_err(c3.ffs, 80.0) < 0.02);
        assert!(rel_err(c5.luts, 300.0) < 0.02);
        assert!(rel_err(c5.ffs, 130.0) < 0.02);
    }

    #[test]
    fn table6_baseline_core() {
        let cfg = ModelConfig::parse_arch("256x128x10", Q5_3).unwrap();
        let r = core(&cfg);
        // Paper row 1: 8.97% LUTs, 0.98% FFs, 3.99% BRAMs of Virtex US.
        let (l, f, b, d) = utilisation(&r, &VIRTEX_ULTRASCALE);
        assert!(rel_err(l, 0.0897) < 0.05, "lut {l}");
        assert!(rel_err(f, 0.0098) < 0.10, "ff {f}");
        assert!(rel_err(b, 0.0399) < 0.01, "bram {b}");
        assert_eq!(d, 0.0);
    }

    #[test]
    fn table6_bram_column_exact() {
        for (arch, brams) in [("256x128x10", 69.0), ("256x256x10", 133.0), ("256x256x256x10", 261.0)] {
            let cfg = ModelConfig::parse_arch(arch, Q5_3).unwrap();
            assert_eq!(core(&cfg).brams, brams, "{arch}");
        }
    }

    #[test]
    fn table6_q97_row() {
        let q53 = core(&ModelConfig::parse_arch("256x128x10", Q5_3).unwrap());
        let q97 = core(&ModelConfig::parse_arch("256x128x10", Q9_7).unwrap());
        assert!(rel_err(q97.luts / q53.luts, 1.045) < 0.01);
        assert!(rel_err(q97.ffs / q53.ffs, 1.422) < 0.01);
        assert_eq!(q97.dsps, 276.0); // 2 DSP × 138 compute neurons
        assert_eq!(q97.brams, q53.brams);
    }

    #[test]
    fn mem_kind_conversions() {
        let cfg = ModelConfig::parse_arch("256x128x10", Q5_3).unwrap();
        let bram = core(&cfg);
        let lut = core(&cfg.clone().with_mem(MemKind::DistributedLut));
        let reg = core(&cfg.with_mem(MemKind::Register));
        assert_eq!(lut.brams, 0.0);
        assert_eq!(reg.brams, 0.0);
        assert!(lut.luts > bram.luts);
        assert!(reg.ffs > bram.ffs + 30000.0);
    }

    #[test]
    fn instance_resources_match_static_model() {
        // The sparse stores and the mask model must charge identical
        // synapse counts, for dense and sparse topologies alike.
        let dense = ModelConfig::parse_arch("256x128x10", Q5_3).unwrap();
        let sparse = ModelConfig::with_topologies(
            &[64, 64, 10],
            &[Topology::Gaussian { radius: 2 }, Topology::AllToAll],
            Q9_7,
        )
        .unwrap();
        for cfg in [dense, sparse] {
            let inst = crate::hdl::Core::new(cfg.clone());
            assert_eq!(core_instance(&inst), core(&cfg), "{}", cfg.arch_name());
            assert_eq!(inst.synapse_words(), cfg.total_synapses());
        }
    }

    #[test]
    fn interp_is_monotone_between_anchors() {
        let w12 = QSpec::new(7, 5).unwrap(); // W=12, between anchors 8 and 16
        let r = lif_neuron(w12);
        assert!(r.luts >= 242.0 && r.luts <= 245.0);
        assert!(r.ffs > 35.0 && r.ffs < 68.0);
    }
}
