//! Hardware models — the substitution for Vivado synthesis/implementation
//! and Synopsys DC (DESIGN.md §1).
//!
//! The paper itself motivates this style of model (§VI-D): resource
//! utilisation scales predictably with the configuration, so designers can
//! estimate a design point *without* synthesis during design-space
//! exploration. We implement exactly that predictive model, calibrated
//! against every measurement published in the paper (Tables IV–XII,
//! Figs. 13–14), and report per-cell relative error in EXPERIMENTS.md.
//!
//! * [`resources`] — LUT/FF/BRAM/DSP utilisation for neurons, connection
//!   blocks, and full cores (Tables IV, V, VI, VII).
//! * [`power`] — activity-driven dynamic power with clock gating
//!   (Tables IV–VI, X, XI; Figs. 13/14). Driven by [`crate::hdl`]'s
//!   measured [`crate::hdl::ActivityStats`], not by assumed rates.
//! * [`timing`] — setup-slack vs spike frequency per memory type (Fig. 13).
//! * [`boards`] — the three FPGA evaluation boards of Table III.
//! * [`asic`] — early ASIC synthesis model (Table XII).

pub mod asic;
pub mod boards;
pub mod power;
pub mod resources;
pub mod timing;

pub use boards::Board;
pub use resources::Resources;
