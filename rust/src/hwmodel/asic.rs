//! Early ASIC synthesis model — paper Table XII (Synopsys DC, 32 nm,
//! 100 MHz spike clock, Q5.3 LIF neuron).
//!
//! One published datapoint anchors the model; other quantizations scale
//! with the FPGA LUT model (combinational cells ∝ LUT-equivalents, as both
//! count the synthesised combinational logic of the same RTL), sequential
//! cells equal the neuron's FF count, and leakage scales with area.

use crate::fixed::{QSpec, Q5_3};

use super::resources;

/// Synthesis result summary (Table XII columns).
#[derive(Debug, Clone, PartialEq)]
pub struct AsicSynthesis {
    pub technology_nm: u32,
    pub nets: f64,
    pub comb_cells: f64,
    pub seq_cells: f64,
    pub buf_inv: f64,
    pub area_um2: f64,
    pub switching_power_uw: f64,
    pub leakage_power_uw: f64,
}

impl AsicSynthesis {
    pub fn total_power_uw(&self) -> f64 {
        self.switching_power_uw + self.leakage_power_uw
    }
}

/// Table XII anchors for the Q5.3 neuron at 100 MHz.
const ANCHOR: AsicSynthesis = AsicSynthesis {
    technology_nm: 32,
    nets: 1574.0,
    comb_cells: 944.0,
    seq_cells: 35.0,
    buf_inv: 309.0,
    area_um2: 2894.0,
    switching_power_uw: 23.2,
    leakage_power_uw: 78.5,
};

/// Synthesise one LIF neuron at quantization `qspec` and spike clock `f_hz`.
pub fn synthesize_lif(qspec: QSpec, f_hz: f64) -> AsicSynthesis {
    let r = resources::lif_neuron(qspec);
    let anchor_r = resources::lif_neuron(Q5_3);
    // Combinational complexity tracks the LUT model; DSP-mapped multipliers
    // on FPGA come back as combinational cells on ASIC (add their LUT-equiv:
    // a DSP48 ≈ 120 LUTs of multiplier logic).
    let comb_equiv = |res: &resources::Resources| res.luts + 120.0 * res.dsps;
    let cs = comb_equiv(&r) / comb_equiv(&anchor_r);
    let ss = r.ffs / anchor_r.ffs;
    let area = ANCHOR.area_um2 * (0.85 * cs + 0.15 * ss);
    AsicSynthesis {
        technology_nm: 32,
        nets: (ANCHOR.nets * (0.8 * cs + 0.2 * ss)).round(),
        comb_cells: (ANCHOR.comb_cells * cs).round(),
        seq_cells: (ANCHOR.seq_cells * ss).round(),
        buf_inv: (ANCHOR.buf_inv * cs).round(),
        area_um2: area.round(),
        switching_power_uw: ANCHOR.switching_power_uw * cs * (f_hz / 100e6),
        leakage_power_uw: ANCHOR.leakage_power_uw * (area / ANCHOR.area_um2),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q9_7, Q5_3};

    #[test]
    fn anchor_reproduced_exactly() {
        let s = synthesize_lif(Q5_3, 100e6);
        assert_eq!(s.nets, 1574.0);
        assert_eq!(s.comb_cells, 944.0);
        assert_eq!(s.seq_cells, 35.0);
        assert_eq!(s.buf_inv, 309.0);
        assert_eq!(s.area_um2, 2894.0);
        assert!((s.switching_power_uw - 23.2).abs() < 1e-9);
        assert!((s.leakage_power_uw - 78.5).abs() < 1e-9);
        assert!((s.total_power_uw() - 101.7).abs() < 1e-9);
    }

    #[test]
    fn switching_scales_with_frequency() {
        let s50 = synthesize_lif(Q5_3, 50e6);
        assert!((s50.switching_power_uw - 11.6).abs() < 1e-9);
        // leakage does not scale with f
        assert!((s50.leakage_power_uw - 78.5).abs() < 1e-9);
    }

    #[test]
    fn wider_quantization_grows_design() {
        let s8 = synthesize_lif(Q5_3, 100e6);
        let s16 = synthesize_lif(Q9_7, 100e6);
        assert!(s16.seq_cells > s8.seq_cells);
        assert!(s16.area_um2 > s8.area_um2);
        assert!(s16.comb_cells > s8.comb_cells, "DSP-mapped multiplier must count");
    }
}
