//! FPGA evaluation boards — paper Table III.

/// Resource envelope of one FPGA platform.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Board {
    pub name: &'static str,
    pub technology: &'static str,
    pub luts: u64,
    pub ffs: u64,
    pub brams: f64,
    pub dsps: u64,
}

/// AMD Virtex UltraScale — the paper's primary board.
pub const VIRTEX_ULTRASCALE: Board = Board {
    name: "Virtex UltraScale",
    technology: "16nm FinFET",
    luts: 537_600,
    ffs: 1_075_200,
    brams: 1728.0,
    dsps: 768,
};

pub const VIRTEX_7: Board = Board {
    name: "Virtex 7",
    technology: "28nm",
    luts: 303_600,
    ffs: 607_200,
    brams: 1030.0,
    dsps: 2800,
};

pub const ZYNQ_ULTRASCALE: Board = Board {
    name: "Zynq UltraScale",
    technology: "16nm FinFET",
    luts: 230_400,
    ffs: 460_800,
    brams: 312.0,
    dsps: 1728,
};

impl Board {
    pub fn all() -> [Board; 3] {
        [VIRTEX_ULTRASCALE, VIRTEX_7, ZYNQ_ULTRASCALE]
    }

    pub fn by_name(name: &str) -> Option<Board> {
        Board::all().into_iter().find(|b| b.name.eq_ignore_ascii_case(name))
    }

    /// Whether a design's resource vector fits this board.
    pub fn fits(&self, r: &super::resources::Resources) -> bool {
        r.luts <= self.luts as f64
            && r.ffs <= self.ffs as f64
            && r.brams <= self.brams
            && r.dsps <= self.dsps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_values() {
        assert_eq!(VIRTEX_ULTRASCALE.luts, 537_600);
        assert_eq!(VIRTEX_7.brams, 1030.0);
        assert_eq!(ZYNQ_ULTRASCALE.ffs, 460_800);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Board::by_name("virtex 7").unwrap().luts, 303_600);
        assert!(Board::by_name("spartan").is_none());
    }

    #[test]
    fn fits_checks_all_axes() {
        use super::super::resources::Resources;
        let r = Resources { luts: 1e9, ..Default::default() };
        assert!(!VIRTEX_ULTRASCALE.fits(&r));
        assert!(VIRTEX_ULTRASCALE.fits(&Resources::default()));
    }
}
