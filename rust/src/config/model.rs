//! Static model configuration — the HDL-generation parameters of Table I:
//! layer count, neurons per layer, connectivity, quantization, and the
//! synaptic-memory implementation choice (BRAM / distributed LUT / register,
//! §III-A and Fig. 13).

use crate::fixed::QSpec;

use super::topology::Topology;

/// Synaptic memory implementation — paper §III-A / Fig. 13. Functionally
/// identical; differs in resources, peak frequency, and dynamic power.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemKind {
    /// Block RAM (the paper's default for large fan-in).
    Bram,
    /// Distributed LUT RAM (lowest power; Fig. 13).
    DistributedLut,
    /// Flip-flop register file (lowest peak frequency; Fig. 13).
    Register,
}

impl MemKind {
    pub fn parse(s: &str) -> Option<MemKind> {
        match s {
            "bram" => Some(MemKind::Bram),
            "lut" | "distributed_lut" => Some(MemKind::DistributedLut),
            "register" | "reg" => Some(MemKind::Register),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            MemKind::Bram => "bram",
            MemKind::DistributedLut => "lut",
            MemKind::Register => "register",
        }
    }

    pub fn all() -> [MemKind; 3] {
        [MemKind::Bram, MemKind::DistributedLut, MemKind::Register]
    }
}

/// One hardware layer: N neurons, each with fan-in M through topology α.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerConfig {
    pub fan_in: usize,
    pub neurons: usize,
    pub topology: Topology,
}

impl LayerConfig {
    pub fn synapses(&self) -> usize {
        self.topology
            .synapse_count(self.fan_in, self.neurons)
            .expect("validated at ModelConfig construction")
    }
}

#[derive(Debug, PartialEq)]
pub enum ConfigError {
    TooFewLayers(usize),
    Topology {
        layer: usize,
        source: super::topology::TopologyError,
    },
    Parse(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::TooFewLayers(n) => {
                write!(f, "need at least input + one layer, got {n} sizes")
            }
            ConfigError::Topology { layer, source } => write!(f, "layer {layer}: {source}"),
            ConfigError::Parse(s) => {
                write!(f, "cannot parse architecture {s:?} (expected e.g. \"256x128x10\")")
            }
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Topology { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// A full core configuration, e.g. `256x128x10` at Q5.3 with BRAM memory.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    sizes: Vec<usize>,
    topologies: Vec<Topology>,
    pub qspec: QSpec,
    pub mem: MemKind,
}

impl ModelConfig {
    pub fn new(sizes: &[usize], qspec: QSpec) -> Result<ModelConfig, ConfigError> {
        let topos = vec![Topology::AllToAll; sizes.len().saturating_sub(1)];
        ModelConfig::with_topologies(sizes, &topos, qspec)
    }

    pub fn with_topologies(
        sizes: &[usize],
        topologies: &[Topology],
        qspec: QSpec,
    ) -> Result<ModelConfig, ConfigError> {
        if sizes.len() < 2 {
            return Err(ConfigError::TooFewLayers(sizes.len()));
        }
        assert_eq!(topologies.len(), sizes.len() - 1, "one topology per layer");
        // Validate every mask now so later unwraps are safe.
        for (k, t) in topologies.iter().enumerate() {
            t.mask(sizes[k], sizes[k + 1])
                .map_err(|source| ConfigError::Topology { layer: k, source })?;
        }
        Ok(ModelConfig {
            sizes: sizes.to_vec(),
            topologies: topologies.to_vec(),
            qspec,
            mem: MemKind::Bram,
        })
    }

    /// Parse the paper's `256x128x10` architecture notation.
    pub fn parse_arch(arch: &str, qspec: QSpec) -> Result<ModelConfig, ConfigError> {
        let sizes: Result<Vec<usize>, _> = arch.split('x').map(|s| s.trim().parse()).collect();
        match sizes {
            Ok(v) if v.len() >= 2 && v.iter().all(|&x| x > 0) => ModelConfig::new(&v, qspec),
            _ => Err(ConfigError::Parse(arch.into())),
        }
    }

    pub fn with_mem(mut self, mem: MemKind) -> ModelConfig {
        self.mem = mem;
        self
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    pub fn num_layers(&self) -> usize {
        self.sizes.len() - 1
    }

    pub fn layer(&self, k: usize) -> LayerConfig {
        LayerConfig {
            fan_in: self.sizes[k],
            neurons: self.sizes[k + 1],
            topology: self.topologies[k],
        }
    }

    pub fn layers(&self) -> Vec<LayerConfig> {
        (0..self.num_layers()).map(|k| self.layer(k)).collect()
    }

    pub fn inputs(&self) -> usize {
        self.sizes[0]
    }

    pub fn outputs(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Total neurons, counting the input layer like the paper does
    /// (256x128x10 ⇒ 394 neurons, §VI-D).
    pub fn total_neurons(&self) -> usize {
        self.sizes.iter().sum()
    }

    /// Neurons with hardware LIF datapaths (everything but the input layer).
    pub fn compute_neurons(&self) -> usize {
        self.sizes[1..].iter().sum()
    }

    pub fn total_synapses(&self) -> usize {
        self.layers().iter().map(|l| l.synapses()).sum()
    }

    pub fn arch_name(&self) -> String {
        self.sizes
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>()
            .join("x")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q5_3, Q9_7};

    #[test]
    fn paper_baseline_counts() {
        let c = ModelConfig::parse_arch("256x128x10", Q5_3).unwrap();
        assert_eq!(c.total_neurons(), 394);
        assert_eq!(c.compute_neurons(), 138);
        assert_eq!(c.total_synapses(), 34048);
        assert_eq!(c.arch_name(), "256x128x10");
        assert_eq!(c.num_layers(), 2);
    }

    #[test]
    fn table6_row4_counts() {
        let c = ModelConfig::parse_arch("256x256x256x10", Q5_3).unwrap();
        assert_eq!(c.total_neurons(), 778);
        assert_eq!(c.total_synapses(), 133_632);
    }

    #[test]
    fn parse_errors() {
        assert!(ModelConfig::parse_arch("256", Q5_3).is_err());
        assert!(ModelConfig::parse_arch("256xABCx10", Q5_3).is_err());
        assert!(ModelConfig::parse_arch("256x0x10", Q5_3).is_err());
    }

    #[test]
    fn topology_validated_at_construction() {
        let err = ModelConfig::with_topologies(&[4, 5], &[Topology::OneToOne], Q9_7);
        assert!(matches!(err, Err(ConfigError::Topology { layer: 0, .. })));
    }

    #[test]
    fn mem_kind_default_and_override() {
        let c = ModelConfig::parse_arch("8x4", Q5_3).unwrap();
        assert_eq!(c.mem, MemKind::Bram);
        assert_eq!(c.with_mem(MemKind::Register).mem, MemKind::Register);
        assert_eq!(MemKind::parse("lut"), Some(MemKind::DistributedLut));
        assert_eq!(MemKind::parse("x"), None);
    }

    #[test]
    fn layer_accessors() {
        let c = ModelConfig::parse_arch("6x5x4", Q5_3).unwrap();
        assert_eq!(c.layer(0).fan_in, 6);
        assert_eq!(c.layer(1).neurons, 4);
        assert_eq!(c.inputs(), 6);
        assert_eq!(c.outputs(), 4);
    }
}
