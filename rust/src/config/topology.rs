//! Connectivity topologies — Eq. 9 (α) and polarity Eq. 10 (β).
//!
//! Mirrors `python/compile/kernels/synapse.py` bit-for-bit (same mask
//! layout; verified in the integration tests against golden vectors).

/// Eq. 9 connection parameter α as a named topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    /// Eq. 9a: every pre neuron connects to every post neuron ("full").
    AllToAll,
    /// Eq. 9b: α_ij = 1 iff i == j (requires equal layer widths).
    OneToOne,
    /// Eq. 9c generalised: receptive field of ±radius around the scaled
    /// pre-index centre (radius 1 == the paper's |i−j| ≤ 1 for equal widths).
    Gaussian { radius: u32 },
}

#[derive(Debug, PartialEq)]
pub enum TopologyError {
    BadShape { m: usize, n: usize },
    NotSquare { m: usize, n: usize },
}

impl std::fmt::Display for TopologyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TopologyError::BadShape { m, n } => write!(f, "bad layer shape {m}x{n}"),
            TopologyError::NotSquare { m, n } => {
                write!(f, "one_to_one needs M == N, got {m} != {n}")
            }
        }
    }
}

impl std::error::Error for TopologyError {}

impl Topology {
    pub fn parse(s: &str) -> Option<Topology> {
        match s {
            "all_to_all" | "full" => Some(Topology::AllToAll),
            "one_to_one" => Some(Topology::OneToOne),
            "gaussian" => Some(Topology::Gaussian { radius: 1 }),
            _ => s.strip_prefix("gaussian:").and_then(|r| {
                r.parse().ok().map(|radius| Topology::Gaussian { radius })
            }),
        }
    }

    pub fn label(&self) -> String {
        match self {
            Topology::AllToAll => "all_to_all".into(),
            Topology::OneToOne => "one_to_one".into(),
            Topology::Gaussian { radius } => format!("gaussian:{radius}"),
        }
    }

    /// α mask in row-major [M, N] layout (pre-synaptic × post-synaptic).
    pub fn mask(&self, m: usize, n: usize) -> Result<Vec<u8>, TopologyError> {
        if m == 0 || n == 0 {
            return Err(TopologyError::BadShape { m, n });
        }
        let mut out = vec![0u8; m * n];
        match *self {
            Topology::AllToAll => out.fill(1),
            Topology::OneToOne => {
                if m != n {
                    return Err(TopologyError::NotSquare { m, n });
                }
                for i in 0..m {
                    out[i * n + i] = 1;
                }
            }
            Topology::Gaussian { radius } => {
                // Same centring formula as synapse.py: centre_j =
                // (j + 0.5) * M / N - 0.5; α=1 iff |i - centre_j| <= radius.
                for j in 0..n {
                    let centre = (j as f64 + 0.5) * m as f64 / n as f64 - 0.5;
                    for i in 0..m {
                        if (i as f64 - centre).abs() <= radius as f64 + 1e-9 {
                            out[i * n + j] = 1;
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    /// Number of α=1 synapses — drives the resource/memory models.
    pub fn synapse_count(&self, m: usize, n: usize) -> Result<usize, TopologyError> {
        Ok(self.mask(m, n)?.iter().map(|&x| x as usize).sum())
    }

    /// Every row's contiguous `[lo, hi]` column window of α=1 entries
    /// (`None` for fully pruned rows), computed in one mask pass. Every
    /// topology here produces contiguous per-row windows (all-to-all: the
    /// full row; one-to-one: the diagonal element; Gaussian: the receptive
    /// field, whose centre is monotone in the column index) — the invariant
    /// that makes the banded storage in [`crate::hdl::SynapticMemory`]
    /// exact. That storage is built through this method, and the invariant
    /// is asserted here, so the window extraction has exactly one
    /// implementation.
    pub fn row_windows(
        &self,
        m: usize,
        n: usize,
    ) -> Result<Vec<Option<(usize, usize)>>, TopologyError> {
        let mask = self.mask(m, n)?;
        let mut out = Vec::with_capacity(m);
        for i in 0..m {
            let row = &mask[i * n..(i + 1) * n];
            match row.iter().position(|&x| x == 1) {
                None => out.push(None),
                Some(lo) => {
                    let hi = n - 1 - row.iter().rev().position(|&x| x == 1).unwrap();
                    let nnz = row.iter().filter(|&&x| x == 1).count();
                    assert_eq!(
                        nnz,
                        hi - lo + 1,
                        "non-contiguous α window in row {i} of {m}x{n} {} mask",
                        self.label()
                    );
                    out.push(Some((lo, hi)));
                }
            }
        }
        Ok(out)
    }

}

/// Eq. 10 polarity: fold α·β·ω into signed weights (float domain).
pub fn fold_weights(omega: &[f64], alpha: &[u8], beta: &[i8]) -> anyhow::Result<Vec<f64>> {
    anyhow::ensure!(
        omega.len() == alpha.len() && omega.len() == beta.len(),
        "omega/alpha/beta length mismatch"
    );
    anyhow::ensure!(alpha.iter().all(|&a| a <= 1), "alpha must be 0/1");
    anyhow::ensure!(beta.iter().all(|&b| b == 1 || b == -1), "beta must be ±1");
    Ok(omega
        .iter()
        .zip(alpha)
        .zip(beta)
        .map(|((&w, &a), &b)| a as f64 * b as f64 * w.abs())
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_to_all_full() {
        let m = Topology::AllToAll.mask(4, 3).unwrap();
        assert_eq!(m.len(), 12);
        assert!(m.iter().all(|&x| x == 1));
        assert_eq!(Topology::AllToAll.synapse_count(256, 128).unwrap(), 32768);
    }

    #[test]
    fn one_to_one_identity() {
        let m = Topology::OneToOne.mask(3, 3).unwrap();
        assert_eq!(m, vec![1, 0, 0, 0, 1, 0, 0, 0, 1]);
        assert_eq!(
            Topology::OneToOne.mask(3, 4),
            Err(TopologyError::NotSquare { m: 3, n: 4 })
        );
    }

    #[test]
    fn gaussian_equal_width_is_tridiagonal() {
        let g = Topology::Gaussian { radius: 1 };
        let m = g.mask(6, 6).unwrap();
        for i in 0..6 {
            for j in 0..6 {
                let want = (i as i64 - j as i64).unsigned_abs() <= 1;
                assert_eq!(m[i * 6 + j] == 1, want, "({i},{j})");
            }
        }
    }

    #[test]
    fn gaussian_windows_contiguous() {
        let g = Topology::Gaussian { radius: 2 };
        let m = g.mask(16, 4).unwrap();
        for j in 0..4 {
            let idx: Vec<usize> = (0..16).filter(|&i| m[i * 4 + j] == 1).collect();
            assert!(!idx.is_empty());
            assert!(idx.windows(2).all(|w| w[1] == w[0] + 1), "col {j}: {idx:?}");
        }
    }

    #[test]
    fn conv_tap_counts_match_table5() {
        // Table V: 3x3 / 5x5 conv == radius 1 / 2 windows (3 and 5 taps/row).
        let m3 = Topology::Gaussian { radius: 1 }.mask(20, 20).unwrap();
        let m5 = Topology::Gaussian { radius: 2 }.mask(20, 20).unwrap();
        let col = |m: &[u8], j: usize| (0..20).map(|i| m[i * 20 + j] as usize).sum::<usize>();
        assert_eq!(col(&m3, 10), 3);
        assert_eq!(col(&m5, 10), 5);
    }

    #[test]
    fn parse_labels() {
        assert_eq!(Topology::parse("full"), Some(Topology::AllToAll));
        assert_eq!(Topology::parse("gaussian:3"), Some(Topology::Gaussian { radius: 3 }));
        assert_eq!(Topology::parse("gaussian"), Some(Topology::Gaussian { radius: 1 }));
        assert_eq!(Topology::parse("smallworld"), None);
        for t in [Topology::AllToAll, Topology::OneToOne, Topology::Gaussian { radius: 2 }] {
            assert_eq!(Topology::parse(&t.label()), Some(t));
        }
    }

    #[test]
    fn fold_weights_signs() {
        let w = fold_weights(&[1.0, -2.0], &[1, 0], &[-1, 1]).unwrap();
        assert_eq!(w, vec![-1.0, 0.0]);
        assert!(fold_weights(&[1.0], &[2], &[1]).is_err());
        assert!(fold_weights(&[1.0], &[1], &[0]).is_err());
        assert!(fold_weights(&[1.0, 1.0], &[1], &[1]).is_err());
    }

    #[test]
    fn zero_shape_rejected() {
        assert!(Topology::AllToAll.mask(0, 3).is_err());
    }

    #[test]
    fn row_windows_cover_mask_exactly() {
        for (topo, m, n) in [
            (Topology::AllToAll, 5usize, 7usize),
            (Topology::OneToOne, 6, 6),
            (Topology::Gaussian { radius: 1 }, 8, 8),
            (Topology::Gaussian { radius: 2 }, 16, 4),
            (Topology::Gaussian { radius: 1 }, 3, 9),
        ] {
            let mask = topo.mask(m, n).unwrap();
            let windows = topo.row_windows(m, n).unwrap();
            assert_eq!(windows.len(), m);
            for (i, win) in windows.iter().enumerate() {
                let row = &mask[i * n..(i + 1) * n];
                let nnz = row.iter().filter(|&&x| x == 1).count();
                match *win {
                    None => assert_eq!(nnz, 0, "{topo:?} row {i}"),
                    Some((lo, hi)) => {
                        assert_eq!(nnz, hi - lo + 1, "{topo:?} row {i} window not contiguous");
                        assert!(row[lo] == 1 && row[hi] == 1, "{topo:?} row {i}");
                    }
                }
            }
        }
    }
}
