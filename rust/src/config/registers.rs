//! Control registers — the paper's *dynamic configuration* (Table I).
//!
//! The decoder module of each QUANTISENC core holds control registers,
//! clocked on `mem_clk`, that set the LIF dynamics at run time: decay rate,
//! growth rate, threshold voltage, reset mechanism, and refractory period
//! (§II cfg_in, §III-A). The register *vector layout* is shared with the
//! Python side (`kernels/ref.py`) and with the lowered HLO artifacts, which
//! take the vector as a runtime parameter — programming a register here is
//! literally programming the deployed computation.

use crate::fixed::QSpec;

/// Indices into the register vector (must match `kernels/ref.py`).
pub const REG_DECAY: usize = 0;
pub const REG_GROWTH: usize = 1;
pub const REG_VTH: usize = 2;
pub const REG_VRESET: usize = 3;
pub const REG_RESET_MODE: usize = 4;
pub const REG_REFRACTORY: usize = 5;
pub const NUM_REGS: usize = 6;

/// Eq. 7 reset mechanisms. Encodings match `kernels/ref.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(i32)]
pub enum ResetMode {
    /// Exponential decay — the membrane is not reset, only keeps decaying.
    Default = 0,
    /// U(t) := 0 after a spike.
    ToZero = 1,
    /// U(t) := U(t) - Vth after a spike (the paper's dataset baseline).
    BySubtraction = 2,
    /// U(t) := Vreset after a spike.
    ToConstant = 3,
}

impl ResetMode {
    pub fn from_i32(x: i32) -> Option<ResetMode> {
        match x {
            0 => Some(ResetMode::Default),
            1 => Some(ResetMode::ToZero),
            2 => Some(ResetMode::BySubtraction),
            3 => Some(ResetMode::ToConstant),
            _ => None,
        }
    }

    pub fn all() -> [ResetMode; 4] {
        [ResetMode::Default, ResetMode::ToZero, ResetMode::BySubtraction, ResetMode::ToConstant]
    }

    pub fn label(&self) -> &'static str {
        match self {
            ResetMode::Default => "default (exp decay)",
            ResetMode::ToZero => "reset-to-zero",
            ResetMode::BySubtraction => "reset-by-subtraction",
            ResetMode::ToConstant => "reset-to-constant",
        }
    }
}

#[derive(Debug, PartialEq)]
pub enum RegisterError {
    BadAddress(usize),
    BadResetMode(i32),
    BadRefractory(i32),
    OutOfRange { value: i32, q: String, min: i32, max: i32 },
}

impl std::fmt::Display for RegisterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegisterError::BadAddress(a) => {
                write!(f, "register address {a} out of range (decoder has {NUM_REGS} registers)")
            }
            RegisterError::BadResetMode(m) => write!(f, "invalid reset mode encoding {m}"),
            RegisterError::BadRefractory(r) => {
                write!(f, "refractory period must be >= 0, got {r}")
            }
            RegisterError::OutOfRange { value, q, min, max } => {
                write!(f, "register value {value} does not fit {q} (raw range [{min}, {max}])")
            }
        }
    }
}

impl std::error::Error for RegisterError {}

/// The decoder's control-register file for one core.
///
/// Values are stored raw (Qn.q fixed point for the voltage/rate registers,
/// plain integers for mode/refractory). Writes are validated the way the
/// decoder's address/width checks would reject malformed AXI transactions.
#[derive(Debug, Clone, PartialEq)]
pub struct RegisterFile {
    qspec: QSpec,
    regs: [i32; NUM_REGS],
    /// Total accepted cfg_in writes (telemetry; §IV interface accounting).
    writes: u64,
}

impl RegisterFile {
    /// Paper defaults: decay 0.2 (Δt/τ for τ=5Δt), growth 1.0, vth 1.0,
    /// reset-by-subtraction (Table X baseline), no refractory period.
    pub fn new(qspec: QSpec) -> RegisterFile {
        let mut rf = RegisterFile { qspec, regs: [0; NUM_REGS], writes: 0 };
        rf.regs[REG_DECAY] = qspec.from_float(0.2);
        rf.regs[REG_GROWTH] = qspec.from_float(1.0);
        rf.regs[REG_VTH] = qspec.from_float(1.0);
        rf.regs[REG_VRESET] = 0;
        rf.regs[REG_RESET_MODE] = ResetMode::BySubtraction as i32;
        rf.regs[REG_REFRACTORY] = 0;
        rf
    }

    pub fn qspec(&self) -> QSpec {
        self.qspec
    }

    /// Raw register write — the cfg_in bus transaction.
    pub fn write(&mut self, addr: usize, value: i32) -> Result<(), RegisterError> {
        if addr >= NUM_REGS {
            return Err(RegisterError::BadAddress(addr));
        }
        match addr {
            REG_RESET_MODE => {
                ResetMode::from_i32(value).ok_or(RegisterError::BadResetMode(value))?;
            }
            REG_REFRACTORY => {
                if value < 0 {
                    return Err(RegisterError::BadRefractory(value));
                }
            }
            _ => {
                if !self.qspec.in_range(value) {
                    return Err(RegisterError::OutOfRange {
                        value,
                        q: self.qspec.name(),
                        min: self.qspec.min_raw(),
                        max: self.qspec.max_raw(),
                    });
                }
            }
        }
        self.regs[addr] = value;
        self.writes += 1;
        Ok(())
    }

    /// Apply a whole cfg_in register *program* (an ordered list of
    /// `(address, raw value)` writes) atomically: either every write lands
    /// or the file is untouched and the first offending write's error is
    /// returned. This is the unit the live control plane
    /// ([`crate::coordinator::control::ReconfigProgram`]) broadcasts to a
    /// serving engine's cores.
    pub fn apply_program(&mut self, writes: &[(usize, i32)]) -> Result<(), RegisterError> {
        let mut staged = self.clone();
        for &(addr, value) in writes {
            staged.write(addr, value)?;
        }
        *self = staged;
        Ok(())
    }

    pub fn read(&self, addr: usize) -> Result<i32, RegisterError> {
        self.regs.get(addr).copied().ok_or(RegisterError::BadAddress(addr))
    }

    /// The full vector in the cross-language layout (HLO parameter form).
    pub fn vector(&self) -> [i32; NUM_REGS] {
        self.regs
    }

    pub fn writes(&self) -> u64 {
        self.writes
    }

    // --- typed convenience setters (application-software API, §IV) --------

    pub fn set_decay(&mut self, decay: f64) -> Result<(), RegisterError> {
        self.write(REG_DECAY, self.qspec.from_float(decay))
    }

    pub fn set_growth(&mut self, growth: f64) -> Result<(), RegisterError> {
        self.write(REG_GROWTH, self.qspec.from_float(growth))
    }

    pub fn set_vth(&mut self, vth: f64) -> Result<(), RegisterError> {
        self.write(REG_VTH, self.qspec.from_float(vth))
    }

    pub fn set_vreset(&mut self, v: f64) -> Result<(), RegisterError> {
        self.write(REG_VRESET, self.qspec.from_float(v))
    }

    pub fn set_reset_mode(&mut self, mode: ResetMode) -> Result<(), RegisterError> {
        self.write(REG_RESET_MODE, mode as i32)
    }

    pub fn set_refractory(&mut self, cycles: i32) -> Result<(), RegisterError> {
        self.write(REG_REFRACTORY, cycles)
    }

    // --- typed getters ------------------------------------------------------

    pub fn decay(&self) -> i32 {
        self.regs[REG_DECAY]
    }

    pub fn growth(&self) -> i32 {
        self.regs[REG_GROWTH]
    }

    pub fn vth(&self) -> i32 {
        self.regs[REG_VTH]
    }

    pub fn vreset(&self) -> i32 {
        self.regs[REG_VRESET]
    }

    pub fn reset_mode(&self) -> ResetMode {
        ResetMode::from_i32(self.regs[REG_RESET_MODE]).expect("validated on write")
    }

    pub fn refractory(&self) -> i32 {
        self.regs[REG_REFRACTORY]
    }

    /// Program the R/C pair of paper Fig. 3 / Table X. τ = R·C defines the
    /// decay per Eq. 4; growth = R·Δt/τ = Δt/C per Eq. 5. Values are
    /// normalised so the paper's training point (R=500 MΩ, C=10 pF, τ=5 ms)
    /// maps to (decay=0.2, growth=1.0) — the scale the weights were trained
    /// at (see DESIGN.md calibration policy).
    pub fn set_rc(&mut self, r_mohm: f64, c_pf: f64) -> Result<(), RegisterError> {
        const R0_MOHM: f64 = 500.0;
        const C0_PF: f64 = 10.0;
        let tau = (r_mohm * c_pf) / (R0_MOHM * C0_PF) * 5.0; // ms
        let dt = 1.0; // ms per spk_clk timestep
        self.set_decay(dt / tau * 0.2 * 5.0)?; // Δt/τ, scaled so τ=5ms ⇒ 0.2
        self.set_growth(C0_PF / c_pf) // Δt/C normalised to the training point
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q5_3, Q9_7};

    #[test]
    fn defaults_match_python_default_regs() {
        let rf = RegisterFile::new(Q5_3);
        // python: [from_float(0.2), from_float(1.0), from_float(1.0), 0, 2, 0]
        assert_eq!(rf.vector(), [2, 8, 8, 0, 2, 0]);
    }

    #[test]
    fn typed_setters_roundtrip() {
        let mut rf = RegisterFile::new(Q9_7);
        rf.set_vth(10.0).unwrap();
        assert_eq!(rf.vth(), Q9_7.from_float(10.0));
        rf.set_reset_mode(ResetMode::ToZero).unwrap();
        assert_eq!(rf.reset_mode(), ResetMode::ToZero);
        rf.set_refractory(5).unwrap();
        assert_eq!(rf.refractory(), 5);
        assert_eq!(rf.writes(), 3);
    }

    #[test]
    fn rejects_bad_writes() {
        let mut rf = RegisterFile::new(Q5_3);
        assert_eq!(rf.write(99, 0), Err(RegisterError::BadAddress(99)));
        assert_eq!(rf.write(REG_RESET_MODE, 7), Err(RegisterError::BadResetMode(7)));
        assert_eq!(rf.write(REG_REFRACTORY, -1), Err(RegisterError::BadRefractory(-1)));
        assert!(matches!(rf.write(REG_VTH, 1000), Err(RegisterError::OutOfRange { .. })));
        // failed writes must not bump the counter or mutate state
        assert_eq!(rf.writes(), 0);
        assert_eq!(rf.vth(), Q5_3.from_float(1.0));
    }

    #[test]
    fn apply_program_is_all_or_nothing() {
        let mut rf = RegisterFile::new(Q5_3);
        rf.apply_program(&[(REG_VTH, 12), (REG_REFRACTORY, 3)]).unwrap();
        assert_eq!(rf.vth(), 12);
        assert_eq!(rf.refractory(), 3);
        // A bad write anywhere in the program must leave the file untouched,
        // even if earlier writes were individually valid.
        let before = rf.vector();
        let err = rf.apply_program(&[(REG_VTH, 4), (REG_RESET_MODE, 9)]).unwrap_err();
        assert_eq!(err, RegisterError::BadResetMode(9));
        assert_eq!(rf.vector(), before);
        assert_eq!(rf.apply_program(&[(NUM_REGS, 0)]), Err(RegisterError::BadAddress(NUM_REGS)));
        // The empty program is a no-op.
        rf.apply_program(&[]).unwrap();
        assert_eq!(rf.vector(), before);
    }

    #[test]
    fn rc_mapping_matches_paper_training_point() {
        let mut rf = RegisterFile::new(Q9_7);
        rf.set_rc(500.0, 10.0).unwrap();
        assert_eq!(rf.decay(), Q9_7.from_float(0.2));
        assert_eq!(rf.growth(), Q9_7.from_float(1.0));
        // Table X col 2: R=100 MΩ, C=50 pF (same τ) ⇒ growth 0.2, decay 0.2
        rf.set_rc(100.0, 50.0).unwrap();
        assert_eq!(rf.decay(), Q9_7.from_float(0.2));
        assert_eq!(rf.growth(), Q9_7.from_float(0.2));
    }

    #[test]
    fn reset_mode_encodings_are_stable() {
        assert_eq!(ResetMode::Default as i32, 0);
        assert_eq!(ResetMode::ToZero as i32, 1);
        assert_eq!(ResetMode::BySubtraction as i32, 2);
        assert_eq!(ResetMode::ToConstant as i32, 3);
        assert_eq!(ResetMode::from_i32(4), None);
    }
}
