//! Configuration system — paper Table I.
//!
//! QUANTISENC's "software-defined hardware" methodology splits configuration
//! into **static** parameters (number of layers K, neurons per layer N,
//! layer-to-layer connectivity α/β, quantization Qn.q — HDL generation
//! parameters, fixed at build time) and **dynamic** parameters (growth rate,
//! decay rate, threshold voltage, refractory period, reset mechanism —
//! control registers programmable at run time through cfg_in).
//!
//! [`model::ModelConfig`] is the static half; [`registers::RegisterFile`] is
//! the dynamic half.

pub mod model;
pub mod registers;
pub mod topology;

pub use model::{LayerConfig, MemKind, ModelConfig};
pub use registers::{RegisterFile, ResetMode, NUM_REGS};
pub use topology::Topology;
