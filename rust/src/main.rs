//! `repro` — the QUANTISENC leader binary.
//!
//! Subcommands (hand-rolled parsing; clap is not available offline):
//!
//! ```text
//! repro artifacts             (re)generate the native artifact store
//! repro table <id>            regenerate a paper table (4..12, g)
//! repro figure <id>           regenerate a paper figure (3, 4, 10, 12, 13, 14)
//! repro all                   every table & figure, in paper order
//! repro serve [opts]          batched inference over the ServingEngine
//! repro loadgen [opts]        open-loop load generator for the front door
//! repro snapshot [opts]       run k samples, freeze the engine to a connectome file
//! repro restore [opts]        revive a connectome and diff it against an
//!                             uninterrupted run (nonzero exit on divergence)
//! repro chaos-soak [opts]     hermetic front door under seeded shard-killing
//!                             chaos; retrying clients must end bit-exact and
//!                             the engine all-healthy (nonzero exit otherwise)
//! repro seu-soak [opts]       memory-integrity gate: seeded single-event
//!                             upsets against Correct- and Detect-mode engines
//!                             plus a lane-64 scrub-overhead measurement
//!                             (writes and gates BENCH_integrity.json)
//! repro explore <arch> [Q]    DSE estimate for an architecture on all boards
//! repro codegen <arch>        emit Verilog HDL + self-checking testbench
//! repro bench-check <json>..  validate BENCH_*.json perf reports
//! repro info                  artifact manifest + platform summary
//! ```
//!
//! `serve` options: `--dataset smnist|dvs|shd` `--q Q5.3` `--n <samples>`
//! `--cores <C>` `--lanes <L>` (1..=64 samples per shard message)
//! `--pipeline` `--multicore` `--pjrt` (needs `--features pjrt`),
//! `--listen <addr>` to expose the engine as the TCP front door instead
//! of running a local batch.
//!
//! `loadgen` options: `--addr <host:port>` (omit for hermetic mode: an
//! in-process server on an ephemeral port with bit-exact result
//! verification against the sequential core), `--sessions` `--n`
//! `--rate <Hz>` `--burst <len>` `--reconfig-every <k>` `--pool`
//! `--inflight` `--seed` `--out <BENCH_serving_slo.json>`.

use anyhow::{Context, Result};
use std::time::Instant;

use quantisenc::coordinator::client::{self, LoadgenOptions, RetryPolicy, WireClient};
use quantisenc::coordinator::connectome::Connectome;
use quantisenc::coordinator::metrics::Telemetry;
use quantisenc::coordinator::pipeline;
use quantisenc::coordinator::server::{ServerOptions, SpikeServer};
use quantisenc::coordinator::serving::chaos::{ChaosEvent, ChaosKind, ChaosSchedule};
use quantisenc::coordinator::serving::{ServingEngine, ServingOptions};
use quantisenc::hdl::integrity::FlipTarget;
use quantisenc::hdl::IntegrityMode;
use quantisenc::datasets::{Dataset, Split};
use quantisenc::dse;
use quantisenc::experiments;
use quantisenc::fixed::QSpec;
use quantisenc::hwmodel::Board;
use quantisenc::runtime::artifacts::Manifest;
use quantisenc::util::benchcheck;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Load the manifest, bootstrapping the native artifact store if needed.
fn manifest() -> Result<Manifest> {
    Manifest::load(&quantisenc::golden::ensure_artifacts()?)
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "artifacts" => {
            let dir = quantisenc::artifacts_dir();
            println!("generating native artifact store at {} ...", dir.display());
            let t0 = Instant::now();
            quantisenc::golden::generate(&dir)?;
            let m = Manifest::load(&dir)?;
            println!("done in {:.1?}: models {:?}", t0.elapsed(), m.datasets());
            Ok(())
        }
        "table" => {
            let id = args.get(1).context("usage: repro table <id>")?;
            let m = manifest().ok();
            for t in experiments::run_table(id, m.as_ref())? {
                println!("{t}");
            }
            Ok(())
        }
        "figure" => {
            let id = args.get(1).context("usage: repro figure <id>")?;
            let m = manifest().ok();
            for t in experiments::run_figure(id, m.as_ref())? {
                println!("{t}");
            }
            Ok(())
        }
        "all" => {
            let m = manifest().ok();
            for (kind, id) in experiments::ALL {
                let tables = match *kind {
                    "table" => experiments::run_table(id, m.as_ref()),
                    _ => experiments::run_figure(id, m.as_ref()),
                };
                match tables {
                    Ok(ts) => {
                        for t in ts {
                            println!("{t}");
                        }
                    }
                    Err(e) => eprintln!("[skip] {kind} {id}: {e:#}"),
                }
            }
            Ok(())
        }
        "serve" => serve(&args[1..]),
        "loadgen" => loadgen(&args[1..]),
        "snapshot" => snapshot_cmd(&args[1..]),
        "restore" => restore_cmd(&args[1..]),
        "chaos-soak" => chaos_soak(&args[1..]),
        "seu-soak" => seu_soak(&args[1..]),
        "explore" => {
            let arch = args.get(1).context("usage: repro explore <arch> [Qn.q]")?;
            let q = QSpec::parse(args.get(2).map(String::as_str).unwrap_or("Q5.3"))?;
            for board in Board::all() {
                let (p, fits) = dse::estimate(arch, q, &board)?;
                println!(
                    "{:18} {:>9.0} LUT {:>9.0} FF {:>6.1} BRAM {:>5.0} DSP  {:>7.3} W  {}",
                    board.name,
                    p.resources.luts,
                    p.resources.ffs,
                    p.resources.brams,
                    p.resources.dsps,
                    p.power_w,
                    if fits { "FITS" } else { "does NOT fit" }
                );
            }
            Ok(())
        }
        "info" => {
            let m = manifest()?;
            println!("artifacts: {}", m.root.display());
            for ds in m.datasets() {
                println!("  model {ds}: variants {:?}", m.variants(&ds)?);
            }
            println!("  kernels: {:?}", m.kernels());
            #[cfg(feature = "pjrt")]
            {
                match quantisenc::runtime::Runtime::cpu() {
                    Ok(rt) => println!("PJRT platform: {}", rt.platform()),
                    Err(e) => println!("PJRT runtime unavailable: {e:#}"),
                }
            }
            #[cfg(not(feature = "pjrt"))]
            println!("PJRT runtime: disabled (rebuild with --features pjrt)");
            Ok(())
        }
        "codegen" => {
            // Emit Verilog HDL + self-checking SystemVerilog testbench for a
            // configured core (paper §IV's software-defined flow artefacts).
            let arch = args.get(1).context("usage: repro codegen <arch> [outdir]")?;
            let outdir = std::path::PathBuf::from(
                args.get(2).map(String::as_str).unwrap_or("generated_hdl"),
            );
            std::fs::create_dir_all(&outdir)?;
            let cfg = quantisenc::config::ModelConfig::parse_arch(arch, QSpec::parse("Q5.3")?)?;
            let top = quantisenc::hdl::verilog::emit_top(&cfg);
            std::fs::write(outdir.join("quantisenc_top.v"), &top)?;
            // Small random weights + a dataset-shaped stimulus for the TB.
            let mut rng = quantisenc::datasets::rng::XorShift64Star::new(0xC0DE6E);
            let weights: Vec<Vec<i32>> = cfg
                .layers()
                .iter()
                .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(31) as i32 - 15).collect())
                .collect();
            let regs = quantisenc::config::registers::RegisterFile::new(cfg.qspec);
            let t_steps = 8;
            let spikes: Vec<u8> =
                (0..t_steps * cfg.inputs()).map(|_| (rng.uniform() < 0.3) as u8).collect();
            let sample = quantisenc::datasets::Sample {
                spikes,
                t_steps,
                inputs: cfg.inputs(),
                label: 0,
            };
            let tb = quantisenc::hdl::verilog::emit_testbench(&cfg, &weights, &regs, &sample)?;
            std::fs::write(outdir.join("quantisenc_tb.sv"), &tb)?;
            println!(
                "wrote {} ({} bytes) and {} ({} bytes)",
                outdir.join("quantisenc_top.v").display(),
                top.len(),
                outdir.join("quantisenc_tb.sv").display(),
                tb.len()
            );
            Ok(())
        }
        "bench-check" => {
            anyhow::ensure!(args.len() > 1, "usage: repro bench-check <BENCH_*.json>...");
            let gates = benchcheck::Gates::from_env();
            let mut skipped = 0usize;
            for path in &args[1..] {
                match benchcheck::check_report(path, &gates)? {
                    benchcheck::ReportStatus::Validated { summary, .. } => {
                        println!("{path}: OK ({summary})");
                    }
                    benchcheck::ReportStatus::SkippedMissing { path } => {
                        skipped += 1;
                        eprintln!(
                            "warning: {path}: bench report not found — skipped \
                             (run `make bench-smoke` to generate it)"
                        );
                    }
                }
            }
            if skipped > 0 {
                eprintln!("warning: {skipped} bench report(s) skipped as missing");
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}\n{HELP}"),
    }
}


const HELP: &str = "repro — QUANTISENC reproduction CLI
  artifacts       (re)generate the native artifact store (no Python needed)
  table <id>      regenerate a paper table (4,5,6,7,8,9,10,11,12,g)
  figure <id>     regenerate a paper figure (3,4,10,12,13,14)
  all             everything, in paper order
  serve           batched inference service (ServingEngine; --lanes <L> for
                  the 64-sample lane-batched datapath, --pipeline /
                  --multicore for the legacy paths, --pjrt with the feature,
                  --listen <addr> for the TCP spike-frame front door)
  loadgen         open-loop load generator for the front door (--addr, or
                  hermetic with an oracle-verified in-process server);
                  writes BENCH_serving_slo.json for bench-check
  snapshot        run --n samples on a fresh engine, then freeze its complete
                  state to --out <FILE> (versioned connectome, per-section CRCs)
  restore         revive --in <FILE> into a fresh engine, run it to --total
                  samples, and diff against an uninterrupted run — bit-exact
                  or nonzero exit (the snapshot-smoke gate)
  chaos-soak      hermetic front door with a seeded shard-killing schedule
                  (--deaths, --seed, --ckpt-every); retrying clients verify
                  every result against the sequential oracle and the engine
                  must end all-healthy; writes BENCH_chaos.json and gates it
                  (the chaos-smoke gate; BENCH_GATE_MAX_RECOVERY_MS overrides)
  seu-soak        memory-integrity gate: seeded single-event upsets (--flips,
                  --det-flips, --seed) against a SECDED Correct-mode engine
                  (repaired in place, bit-exact) and a parity Detect-mode
                  engine (quarantine + rebuild + resubmit), plus the lane-64
                  scrub-overhead measurement; writes BENCH_integrity.json and
                  gates it (BENCH_GATE_MAX_SCRUB_OVERHEAD overrides)
  explore <arch>  DSE estimate, e.g. repro explore 256x512x10 Q5.3
  codegen <arch>  emit Verilog HDL + self-checking SV testbench (paper §IV)
  bench-check <f> validate BENCH_*.json perf reports (the bench-smoke gate)
  info            artifact + platform summary";

fn flag_val<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(String::as_str)
}

fn serve(args: &[String]) -> Result<()> {
    let ds_name = flag_val(args, "--dataset").unwrap_or("smnist");
    let qname = flag_val(args, "--q").unwrap_or("Q5.3");
    let n: u64 = flag_val(args, "--n").unwrap_or("100").parse()?;
    let cores: usize = flag_val(args, "--cores").unwrap_or("2").parse()?;
    let lanes: usize = flag_val(args, "--lanes").unwrap_or("1").parse()?;
    let use_pipeline = args.iter().any(|a| a == "--pipeline");
    let use_multicore = args.iter().any(|a| a == "--multicore" || a == "--hdl");
    let use_pjrt = args.iter().any(|a| a == "--pjrt");
    anyhow::ensure!(
        lanes <= 1 || !(use_pipeline || use_multicore || use_pjrt),
        "--lanes is a ServingEngine knob; it does nothing on the \
         --pipeline/--multicore/--pjrt backends — drop one of the flags"
    );
    let dataset = Dataset::parse(ds_name).context("bad --dataset")?;

    let m = manifest()?;
    let art = m.model(ds_name, qname)?;
    let backend = if use_pjrt {
        "pjrt"
    } else if use_pipeline {
        "pipeline"
    } else if use_multicore {
        "multicore"
    } else {
        "serving-engine"
    };
    println!(
        "serving {ds_name} ({}) {qname}, {n} requests, backend={backend}",
        art.sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join("x"),
    );

    if let Some(listen) = flag_val(args, "--listen") {
        anyhow::ensure!(
            !(use_pipeline || use_multicore || use_pjrt),
            "--listen exposes the ServingEngine backend only"
        );
        let (_config, engine) =
            experiments::engine_from_artifact(&art, ServingOptions::with_lanes(cores, lanes))?;
        let server = SpikeServer::bind(engine, listen, ServerOptions::default())?;
        println!(
            "front door listening on {} ({ds_name} {qname}, {cores} cores, lane width {lanes}); \
             stop with Ctrl-C",
            server.local_addr()
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(30));
            let s = server.stats();
            println!(
                "conns={} sessions={} served={} reconfigs={} overloaded={} bad={} \
                 protocol_errors={} engine_failures={}",
                s.connections,
                s.sessions,
                s.samples_served,
                s.reconfigs_applied,
                s.rejects_overloaded,
                s.rejects_bad,
                s.protocol_errors,
                s.engine_failures,
            );
        }
    }

    if use_pjrt {
        return serve_pjrt(&art, dataset, n);
    }

    if use_pipeline {
        // Layer-pipelined streaming over the cycle-accurate core (Fig. 8).
        let (config, core) = experiments::core_from_artifact(&art)?;
        let samples: Vec<_> =
            (0..n).map(|i| dataset.sample(i, Split::Test, art.t_steps)).collect();
        let t0 = Instant::now();
        let results = pipeline::run_pipelined(&config, &art.weights, &core.registers, &samples)?;
        let dt = t0.elapsed();
        let correct =
            results.iter().zip(&samples).filter(|(r, s)| r.prediction == s.label).count();
        println!(
            "pipelined: {} streams in {:.2?} ({:.1}/s), accuracy {:.1}%",
            results.len(),
            dt,
            results.len() as f64 / dt.as_secs_f64(),
            100.0 * correct as f64 / n as f64
        );
        return Ok(());
    }

    if use_multicore {
        let mut tel = Telemetry::new();
        tel.start();
        let (config, core) = experiments::core_from_artifact(&art)?;
        let mut mc = quantisenc::coordinator::multicore::MultiCore::new(
            &config,
            &art.weights,
            &core.registers,
            cores,
        )?;
        let samples: Vec<_> =
            (0..n).map(|i| dataset.sample(i, Split::Test, art.t_steps)).collect();
        let t0 = Instant::now();
        let results = mc.run_batch(&samples);
        let per_req = t0.elapsed() / n.max(1) as u32;
        for (r, s) in results.iter().zip(&samples) {
            tel.record(per_req, &r.stats, Some(r.prediction == s.label));
        }
        tel.stop();
        println!("{}", tel.summary());
        let p = quantisenc::hwmodel::power::core_dynamic_from_stats(
            &config,
            &tel.activity,
            quantisenc::hwmodel::power::F0_HZ,
        );
        println!("modelled dynamic power at 600 kHz: {p:.3} W");
        return Ok(());
    }

    // Default: the unified ServingEngine (C sharded cores × pipelined
    // layers, optionally stepping `--lanes` samples per shard message).
    let (config, core) = experiments::core_from_artifact(&art)?;
    let mut engine = ServingEngine::new(
        &config,
        &art.weights,
        &core.registers,
        ServingOptions::with_lanes(cores, lanes),
    )?;
    let samples: Vec<_> = (0..n).map(|i| dataset.sample(i, Split::Test, art.t_steps)).collect();
    let mut tel = Telemetry::new();
    tel.start();
    let t0 = Instant::now();
    let results = engine.run_batch(&samples)?;
    let dt = t0.elapsed();
    let per_req = dt / n.max(1) as u32;
    for (r, s) in results.iter().zip(&samples) {
        tel.record(per_req, &r.stats, Some(r.prediction == s.label));
        tel.record_epoch(r.epoch);
    }
    tel.stop();
    tel.record_bus(engine.bus());
    let (submitted, completed) = engine.stats();
    println!(
        "serving-engine: {} streams on {} cores in {:.2?}, admitted={submitted} completed={completed}",
        results.len(),
        engine.num_cores(),
        dt,
    );
    println!("{}", tel.summary());
    Ok(())
}

/// `repro loadgen` — drive the network front door with open-loop Poisson
/// (optionally bursty) traffic and write the `BENCH_serving_slo.json`
/// report that `repro bench-check` gates on.
///
/// With `--addr` it measures a server someone else is running; without
/// it, it is hermetic: it binds an in-process [`SpikeServer`] on an
/// ephemeral localhost port, computes a sequential `Core::run` oracle for
/// the sample pool, and verifies every network result bit-exactly.
fn loadgen(args: &[String]) -> Result<()> {
    let ds_name = flag_val(args, "--dataset").unwrap_or("smnist");
    let opts = LoadgenOptions {
        sessions: flag_val(args, "--sessions").unwrap_or("2").parse()?,
        samples_per_session: flag_val(args, "--n").unwrap_or("64").parse()?,
        rate_hz: flag_val(args, "--rate").unwrap_or("500").parse()?,
        burst_len: flag_val(args, "--burst").unwrap_or("1").parse()?,
        reconfig_every: flag_val(args, "--reconfig-every").unwrap_or("16").parse()?,
        dataset: Dataset::parse(ds_name).context("bad --dataset")?,
        t_steps: flag_val(args, "--t").unwrap_or("6").parse()?,
        pool: flag_val(args, "--pool").unwrap_or("16").parse()?,
        max_inflight: flag_val(args, "--inflight").unwrap_or("32").parse()?,
        seed: flag_val(args, "--seed").unwrap_or("4269").parse()?,
    };
    let out_path = flag_val(args, "--out").unwrap_or("BENCH_serving_slo.json");

    let (report, server_protocol_errors) = if let Some(addr) = flag_val(args, "--addr") {
        println!(
            "loadgen against {addr}: {} sessions x {} samples at {} Hz ...",
            opts.sessions, opts.samples_per_session, opts.rate_hz
        );
        // A remote server's weights are unknown — no oracle, latency and
        // protocol health only.
        (client::run_loadgen(addr, &opts, None)?, 0u64)
    } else {
        let qname = flag_val(args, "--q").unwrap_or("Q5.3");
        let cores: usize = flag_val(args, "--cores").unwrap_or("2").parse()?;
        let lanes: usize = flag_val(args, "--lanes").unwrap_or("8").parse()?;
        let m = manifest()?;
        let art = m.model(ds_name, qname)?;
        let (_config, mut core) = experiments::core_from_artifact(&art)?;
        let oracle: Vec<Vec<u32>> = client::sample_pool(opts.dataset, opts.pool, opts.t_steps)
            .iter()
            .map(|s| core.run(s).counts)
            .collect();
        let (_config, engine) =
            experiments::engine_from_artifact(&art, ServingOptions::with_lanes(cores, lanes))?;
        let mut server = SpikeServer::bind(engine, "127.0.0.1:0", ServerOptions::default())?;
        let addr = server.local_addr().to_string();
        println!(
            "loadgen (hermetic) on {addr}: {} sessions x {} samples at {} Hz, \
             reconfig every {}, oracle-verified ...",
            opts.sessions, opts.samples_per_session, opts.rate_hz, opts.reconfig_every
        );
        let report = client::run_loadgen(&addr, &opts, Some(&oracle))?;
        server.shutdown();
        (report, server.stats().protocol_errors)
    };

    println!(
        "loadgen: ok={} reconfig_acks={} rejects={} ({:.1}%) errors={} mismatches={} \
         p50={:.0}us p99={:.0}us {:.1} samples/s",
        report.results_ok,
        report.reconfig_acks,
        report.rejects,
        100.0 * report.reject_rate,
        report.errors,
        report.result_mismatches,
        report.p50_us,
        report.p99_us,
        report.samples_per_sec,
    );
    let json = format!(
        "{{\n  \"bench\": \"serving_slo\",\n  \"sessions\": {},\n  \"samples_per_session\": {},\n  \
         \"submitted\": {},\n  \"results_ok\": {},\n  \"reconfig_acks\": {},\n  \"rejects\": {},\n  \
         \"reject_rate\": {:.6},\n  \"errors\": {},\n  \"protocol_errors\": {},\n  \
         \"result_mismatches\": {},\n  \"verified\": {},\n  \"p50_us\": {:.1},\n  \
         \"p99_us\": {:.1},\n  \"mean_us\": {:.1},\n  \"samples_per_sec\": {:.2}\n}}\n",
        report.sessions,
        opts.samples_per_session,
        report.submitted,
        report.results_ok,
        report.reconfig_acks,
        report.rejects,
        report.reject_rate,
        report.errors,
        server_protocol_errors + report.errors,
        report.result_mismatches,
        report.verified,
        report.p50_us,
        report.p99_us,
        report.mean_us,
        report.samples_per_sec,
    );
    std::fs::write(out_path, &json)?;
    println!("wrote {out_path}");
    anyhow::ensure!(
        report.result_mismatches == 0,
        "{} network results diverged from the sequential oracle",
        report.result_mismatches
    );
    Ok(())
}

/// `repro snapshot` — run `--n` samples through a fresh [`ServingEngine`]
/// and write its complete software-defined state (weights, registers,
/// neuron banks, epoch, bus/activity ledgers) to `--out` as a versioned
/// connectome image.
fn snapshot_cmd(args: &[String]) -> Result<()> {
    let out = flag_val(args, "--out").unwrap_or("connectome.qcnx");
    let ds_name = flag_val(args, "--dataset").unwrap_or("smnist");
    let qname = flag_val(args, "--q").unwrap_or("Q5.3");
    let k: u64 = flag_val(args, "--n").unwrap_or("8").parse()?;
    let cores: usize = flag_val(args, "--cores").unwrap_or("2").parse()?;
    let lanes: usize = flag_val(args, "--lanes").unwrap_or("1").parse()?;
    let dataset = Dataset::parse(ds_name).context("bad --dataset")?;
    let m = manifest()?;
    let art = m.model(ds_name, qname)?;
    let (_config, mut engine) =
        experiments::engine_from_artifact(&art, ServingOptions::with_lanes(cores, lanes))?;
    let samples: Vec<_> = (0..k).map(|i| dataset.sample(i, Split::Test, art.t_steps)).collect();
    let t0 = Instant::now();
    engine.run_batch(&samples)?;
    let c = engine.snapshot()?;
    let bytes = c.encode();
    std::fs::write(out, &bytes).with_context(|| format!("writing {out}"))?;
    println!(
        "snapshot: {ds_name} {qname} frozen after {k} samples -> {out} \
         ({} bytes, {} cores x {} layers, lane width {}, epoch {}, {:.2?})",
        bytes.len(),
        c.cores,
        c.layers.first().map_or(0, Vec::len),
        c.lane_width,
        c.epoch,
        t0.elapsed(),
    );
    Ok(())
}

/// `repro restore` — revive a connectome written by `repro snapshot` into
/// a fresh engine, run it forward to `--total` samples, and diff every
/// result (and the final machine state) against an engine that ran the
/// whole prefix uninterrupted. Any divergence is a nonzero exit; this is
/// the `make snapshot-smoke` gate.
fn restore_cmd(args: &[String]) -> Result<()> {
    let path = flag_val(args, "--in").context("usage: repro restore --in <FILE> [--total N]")?;
    let ds_name = flag_val(args, "--dataset").unwrap_or("smnist");
    let qname = flag_val(args, "--q").unwrap_or("Q5.3");
    let total: u64 = flag_val(args, "--total").unwrap_or("16").parse()?;
    let dataset = Dataset::parse(ds_name).context("bad --dataset")?;
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    let c = Connectome::decode(&bytes)?;
    let k = c.completed;
    anyhow::ensure!(
        total >= k,
        "--total {total} is before the snapshot point ({k} samples already completed)"
    );
    let mut revived = ServingEngine::from_connectome(&c)?;

    // The uninterrupted control: the same artifact, same shard/lane
    // geometry, replaying the full prefix in one life.
    let m = manifest()?;
    let art = m.model(ds_name, qname)?;
    let (_config, mut fresh) = experiments::engine_from_artifact(
        &art,
        ServingOptions::with_lanes(c.cores as usize, c.lane_width as usize),
    )?;
    let samples: Vec<_> =
        (0..total).map(|i| dataset.sample(i, Split::Test, art.t_steps)).collect();
    fresh.run_batch(&samples[..k as usize])?;

    let revived_tail = revived.run_batch(&samples[k as usize..])?;
    let fresh_tail = fresh.run_batch(&samples[k as usize..])?;
    anyhow::ensure!(
        revived_tail.len() == fresh_tail.len(),
        "result count diverged after restore"
    );
    for (i, (r, f)) in revived_tail.iter().zip(&fresh_tail).enumerate() {
        anyhow::ensure!(
            r.prediction == f.prediction
                && r.counts == f.counts
                && r.spikes_total == f.spikes_total
                && r.epoch == f.epoch,
            "restored engine diverged from the uninterrupted run at sample {} \
             (prediction {} vs {}, epoch {} vs {})",
            k as usize + i,
            r.prediction,
            f.prediction,
            r.epoch,
            f.epoch,
        );
    }
    // Stronger than result equality: the full machine state must re-freeze
    // to byte-identical images.
    let revived_image = revived.snapshot()?.encode();
    let fresh_image = fresh.snapshot()?.encode();
    anyhow::ensure!(
        revived_image == fresh_image,
        "post-run connectomes differ: restore is not bit-exact"
    );
    println!(
        "restore: OK — {} samples past the snapshot point ({k}..{total}) match the \
         uninterrupted run bit-exactly; final state images identical ({} bytes)",
        revived_tail.len(),
        revived_image.len(),
    );
    Ok(())
}

/// `repro chaos-soak` — the self-healing gate. Hermetic by construction:
/// binds an in-process [`SpikeServer`] whose engine carries a seeded
/// [`ChaosSchedule`] of shard-killing faults, drives it with closed-loop
/// client sessions that absorb `ShardLost` rejections under a
/// [`RetryPolicy`], and verifies every result bit-exactly against the
/// sequential [`Core`](quantisenc::hdl::Core) oracle. Writes
/// `BENCH_chaos.json` and gates it through `benchcheck` (zero mismatches,
/// ≥ 1 recovery, all shards healthy, bounded recovery p99) — any failure
/// is a nonzero exit. Replayable: the schedule and the retry jitter are
/// pure functions of `--seed`.
fn chaos_soak(args: &[String]) -> Result<()> {
    let ds_name = flag_val(args, "--dataset").unwrap_or("smnist");
    let qname = flag_val(args, "--q").unwrap_or("Q5.3");
    let sessions: usize = flag_val(args, "--sessions").unwrap_or("3").parse()?;
    let n: u64 = flag_val(args, "--n").unwrap_or("48").parse()?;
    let cores: usize = flag_val(args, "--cores").unwrap_or("2").parse()?;
    let lanes: usize = flag_val(args, "--lanes").unwrap_or("1").parse()?;
    let deaths: usize = flag_val(args, "--deaths").unwrap_or("4").parse()?;
    let ckpt_every: u64 = flag_val(args, "--ckpt-every").unwrap_or("8").parse()?;
    let pool: usize = flag_val(args, "--pool").unwrap_or("12").parse()?;
    let t_steps: usize = flag_val(args, "--t").unwrap_or("6").parse()?;
    let seed: u64 = flag_val(args, "--seed").unwrap_or("64017").parse()?;
    let out_path = flag_val(args, "--out").unwrap_or("BENCH_chaos.json");
    let dataset = Dataset::parse(ds_name).context("bad --dataset")?;
    anyhow::ensure!(sessions >= 1 && n >= 1 && deaths >= 1, "need sessions, samples and deaths");

    let m = manifest()?;
    let art = m.model(ds_name, qname)?;
    let samples = client::sample_pool(dataset, pool, t_steps);
    let (_config, mut core) = experiments::core_from_artifact(&art)?;
    let oracle: Vec<_> = samples.iter().map(|s| core.run(s)).collect();

    // All deaths land in the first half of the nominal traffic so the
    // second half exercises the rebuilt shards (and retries can only push
    // the admitted-sample counter past the schedule, never before it).
    let total = sessions as u64 * n;
    let span = (total / 2).max(deaths as u64 + 1);
    let (config, mut engine) = experiments::engine_from_artifact(
        &art,
        ServingOptions::with_lanes(cores, lanes).checkpoints_every(ckpt_every),
    )?;
    let schedule = ChaosSchedule::seeded(seed, deaths, span, cores, config.num_layers());
    println!(
        "chaos-soak: {sessions} sessions x {n} samples on {cores} cores (lane width {lanes}), \
         checkpoint every {ckpt_every}, {} seeded shard-killing faults over the first {span} \
         admissions (seed {seed})",
        schedule.events().len(),
    );
    engine.install_chaos(schedule);
    let mut server = SpikeServer::bind(engine, "127.0.0.1:0", ServerOptions::default())?;
    let addr = server.local_addr().to_string();

    let policy = RetryPolicy {
        max_attempts: 10,
        base: std::time::Duration::from_millis(5),
        cap: std::time::Duration::from_millis(100),
        deadline: std::time::Duration::from_secs(30),
        seed,
    };
    // (ok, retries, shard_losses, overloads, mismatches, failures) per session.
    let mut tallies: Vec<(u64, u64, u64, u64, u64, u64)> = Vec::new();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..sessions)
            .map(|_| {
                let (addr, samples, oracle, policy) = (&addr, &samples, &oracle, &policy);
                scope.spawn(move || -> Result<(u64, u64, u64, u64, u64, u64)> {
                    let mut client = WireClient::connect(addr)?;
                    let (session, _quota) = client.open_session(0)?;
                    let mut t = (0u64, 0u64, 0u64, 0u64, 0u64, 0u64);
                    for i in 0..n {
                        let idx = i as usize % samples.len();
                        match client.submit_with_retry(session, i, &samples[idx], policy) {
                            Ok(r) => {
                                t.0 += 1;
                                t.1 += (r.attempts - 1) as u64;
                                t.2 += r.shard_losses as u64;
                                t.3 += r.overloads as u64;
                                let o = &oracle[idx];
                                if r.prediction as usize != o.prediction || r.counts != o.counts {
                                    t.4 += 1;
                                }
                            }
                            Err(e) => {
                                t.5 += 1;
                                eprintln!("chaos-soak: stream {i} failed: {e:#}");
                            }
                        }
                    }
                    Ok(t)
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(Ok(t)) => tallies.push(t),
                Ok(Err(e)) => eprintln!("chaos-soak: session aborted: {e:#}"),
                Err(_) => eprintln!("chaos-soak: session thread panicked"),
            }
        }
    });
    let elapsed = t0.elapsed();
    anyhow::ensure!(tallies.len() == sessions, "a session aborted before finishing its stream");
    let results_ok: u64 = tallies.iter().map(|t| t.0).sum();
    let retries: u64 = tallies.iter().map(|t| t.1).sum();
    let client_losses: u64 = tallies.iter().map(|t| t.2).sum();
    let overloads: u64 = tallies.iter().map(|t| t.3).sum();
    let mismatches: u64 = tallies.iter().map(|t| t.4).sum();
    let failures: u64 = tallies.iter().map(|t| t.5).sum();

    // The pump mirrors supervision state after each op, so the engine's
    // post-recovery health is already visible; the brief poll only covers
    // the window between the last Result frame and the final mirror.
    let heal_deadline = Instant::now() + std::time::Duration::from_secs(10);
    let all_healthy = loop {
        if server.shard_health().iter().all(|&h| h == 0) {
            break true;
        }
        if Instant::now() >= heal_deadline {
            break false;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    };
    // Exercise the wire-level probe too: a fresh connection must see the
    // same verdict through Frame::HealthReq.
    let health = WireClient::connect(&addr)?.health(1)?;
    let stats = server.stats();
    let recovery_ms = server.recovery_latencies_ms();
    let p50 = quantisenc::util::stats::percentile(&recovery_ms, 50.0);
    let p99 = quantisenc::util::stats::percentile(&recovery_ms, 99.0);
    server.shutdown();

    println!(
        "chaos-soak: ok={results_ok}/{total} in {elapsed:.2?}, retries={retries} \
         (shard_losses={client_losses} overloads={overloads}), failures={failures}, \
         mismatches={mismatches}; server recoveries={} quarantines={} degraded={}ms, \
         recovery p50/p99 {p50:.1}/{p99:.1}ms, wire health degraded={} shards={:?}",
        stats.recoveries, stats.quarantines, stats.degraded_ms, health.degraded, health.shards,
    );
    let json = format!(
        "{{\n  \"bench\": \"chaos\",\n  \"seed\": {seed},\n  \"samples\": {total},\n  \
         \"results_ok\": {results_ok},\n  \"failures\": {failures},\n  \"retries\": {retries},\n  \
         \"shard_losses\": {},\n  \"overloads\": {overloads},\n  \"recoveries\": {},\n  \
         \"quarantines\": {},\n  \"mismatches\": {mismatches},\n  \"all_healthy\": {},\n  \
         \"checkpoint_age\": {},\n  \"degraded_ms\": {},\n  \"recovery_p50_ms\": {p50:.3},\n  \
         \"recovery_p99_ms\": {p99:.3}\n}}\n",
        stats.shard_losses.max(client_losses),
        stats.recoveries,
        stats.quarantines,
        if all_healthy && !health.degraded { 1 } else { 0 },
        stats.checkpoint_age,
        stats.degraded_ms,
    );
    std::fs::write(out_path, &json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    anyhow::ensure!(failures == 0, "{failures} streams exhausted their retry budget");
    match benchcheck::check_report_str(out_path, &json, &benchcheck::Gates::from_env())? {
        benchcheck::ReportStatus::Validated { summary, .. } => println!("chaos gate: OK ({summary})"),
        other => anyhow::bail!("{out_path}: unexpected gate outcome {other:?}"),
    }
    Ok(())
}

/// `repro seu-soak` — the memory-integrity gate. Engine-direct (no network):
/// seeded single-event upsets go through the chaos harness and all three
/// integrity behaviours are checked. Phase 1 (SECDED): a Correct-mode engine
/// absorbs every flip in place — bit-exact against the sequential oracle,
/// `corrected` equal to the injected count. Flips are spaced `cores + 1`
/// admissions apart so round-robin dispatch lands a boundary scrub on the
/// target shard between consecutive upsets: each flip is a fresh single-bit
/// error when the scrubber reaches it, never an accumulated double-bit one.
/// Phase 2 (parity): a Detect-mode engine turns each upset into a quarantine
/// and checkpoint rebuild; the lost streams are resubmitted on the healed
/// engine and must come back bit-exact. Phase 3 (cost): lane-64 throughput
/// with Correct-mode scrubbing against integrity off. Writes
/// `BENCH_integrity.json` and gates it in-process (100% detection, at least
/// one in-place correction, zero mismatches, bounded scrub overhead;
/// `BENCH_GATE_MAX_SCRUB_OVERHEAD` overrides). Replayable from `--seed`.
fn seu_soak(args: &[String]) -> Result<()> {
    let ds_name = flag_val(args, "--dataset").unwrap_or("smnist");
    let qname = flag_val(args, "--q").unwrap_or("Q5.3");
    let cores: usize = flag_val(args, "--cores").unwrap_or("2").parse()?;
    let flips: usize = flag_val(args, "--flips").unwrap_or("6").parse()?;
    let det_flips: usize = flag_val(args, "--det-flips").unwrap_or("2").parse()?;
    let n64: usize = flag_val(args, "--n64").unwrap_or("192").parse()?;
    let pool: usize = flag_val(args, "--pool").unwrap_or("12").parse()?;
    let t_steps: usize = flag_val(args, "--t").unwrap_or("6").parse()?;
    let seed: u64 = flag_val(args, "--seed").unwrap_or("24269").parse()?;
    let out_path = flag_val(args, "--out").unwrap_or("BENCH_integrity.json");
    let dataset = Dataset::parse(ds_name).context("bad --dataset")?;
    anyhow::ensure!(cores >= 1 && flips >= 1 && det_flips >= 1, "need cores and flips");

    let m = manifest()?;
    let art = m.model(ds_name, qname)?;
    let samples = client::sample_pool(dataset, pool, t_steps);
    let (config, mut core) = experiments::core_from_artifact(&art)?;
    let oracle: Vec<_> = samples.iter().map(|s| core.run(s)).collect();
    let mut rng = quantisenc::datasets::rng::XorShift64Star::new(seed | 1);
    let mut mismatches = 0u64;
    let mut scrubbed_total = 0u64;

    // Phase 1 — SECDED correction in place. Words beyond a bank's length
    // wrap, so a 20-bit draw exercises every store without knowing sizes.
    let stride = cores as u64 + 1;
    let n1 = (flips as u64 * stride + 2).max(4 * samples.len() as u64) as usize;
    let events: Vec<ChaosEvent> = (0..flips)
        .map(|i| ChaosEvent {
            at_sample: 1 + i as u64 * stride,
            shard: i % cores,
            kind: ChaosKind::BitFlip {
                layer: rng.below(config.num_layers() as u64) as usize,
                target: if i % 2 == 0 { FlipTarget::Weights } else { FlipTarget::Vmem },
                word: rng.below(1 << 20) as usize,
                bit: rng.below(32) as u8,
            },
        })
        .collect();
    let (_, mut correct_engine) = experiments::engine_from_artifact(
        &art,
        ServingOptions::with_cores(cores).with_integrity(IntegrityMode::Correct),
    )?;
    correct_engine.install_chaos(ChaosSchedule::new(events));
    let batch1: Vec<_> = (0..n1).map(|i| samples[i % samples.len()].clone()).collect();
    for (i, r) in correct_engine.run_batch(&batch1)?.iter().enumerate() {
        let o = &oracle[i % samples.len()];
        if r.counts != o.counts || r.prediction != o.prediction {
            mismatches += 1;
        }
    }
    let (scrubbed1, corrected, det1) = correct_engine.integrity_counters();
    scrubbed_total += scrubbed1;
    anyhow::ensure!(
        det1 == 0 && correct_engine.quarantines() == 0,
        "Correct mode must repair in place (detected {det1}, quarantines {})",
        correct_engine.quarantines()
    );
    println!(
        "seu-soak phase 1 (SECDED): {flips} upsets over {n1} samples on {cores} cores, \
         corrected={corrected}, scrubbed={scrubbed1} blocks, mismatches={mismatches}"
    );

    // Phase 2 — parity detection: quarantine, rebuild, resubmit. One upset
    // per round, because a chaos send aimed at an already-dead shard is
    // dropped silently; a single flip per session keeps detection exact.
    let (_, mut detect_engine) = experiments::engine_from_artifact(
        &art,
        ServingOptions::with_cores(cores)
            .with_integrity(IntegrityMode::Detect)
            .checkpoints_every(8),
    )?;
    let mut resubmitted = 0u64;
    for k in 0..det_flips {
        let (submitted, _) = detect_engine.stats();
        detect_engine.install_chaos(ChaosSchedule::new(vec![ChaosEvent {
            at_sample: submitted + 1,
            shard: k % cores,
            kind: ChaosKind::BitFlip {
                layer: k % config.num_layers(),
                target: FlipTarget::Weights,
                word: rng.below(1 << 20) as usize,
                bit: rng.below(32) as u8,
            },
        }]));
        let outcomes = detect_engine.run_batch_outcomes(&samples)?;
        let mut failed = Vec::new();
        for (i, outcome) in outcomes.iter().enumerate() {
            match outcome {
                Ok(r) => {
                    if r.counts != oracle[i].counts || r.prediction != oracle[i].prediction {
                        mismatches += 1;
                    }
                }
                Err(_) => failed.push(i),
            }
        }
        anyhow::ensure!(!failed.is_empty(), "phase 2 round {k}: the injected upset cost no stream");
        let redo: Vec<_> = failed.iter().map(|&i| samples[i].clone()).collect();
        for (r, &i) in detect_engine.run_batch(&redo)?.iter().zip(&failed) {
            if r.counts != oracle[i].counts || r.prediction != oracle[i].prediction {
                mismatches += 1;
            }
        }
        resubmitted += failed.len() as u64;
    }
    let (scrubbed2, corrected2, detected) = detect_engine.integrity_counters();
    scrubbed_total += scrubbed2;
    anyhow::ensure!(corrected2 == 0, "parity cannot correct, yet corrected={corrected2}");
    let quarantines = detect_engine.quarantines();
    println!(
        "seu-soak phase 2 (parity): {det_flips} upsets, detected={detected}, \
         quarantines={quarantines}, recoveries={}, resubmitted={resubmitted} streams, \
         mismatches={mismatches}",
        detect_engine.recoveries(),
    );

    // Phase 3 — scrub overhead at lane width 64, integrity off vs Correct.
    let batch64: Vec<_> = (0..n64).map(|i| samples[i % samples.len()].clone()).collect();
    let (_, mut off_engine) =
        experiments::engine_from_artifact(&art, ServingOptions::with_lanes(cores, 64))?;
    let (_, mut scrub_engine) = experiments::engine_from_artifact(
        &art,
        ServingOptions::with_lanes(cores, 64).with_integrity(IntegrityMode::Correct),
    )?;
    // One warm-up pass each (thread spin-up, allocator steady state).
    off_engine.run_batch(&batch64)?;
    scrub_engine.run_batch(&batch64)?;
    let t0 = Instant::now();
    let out_off = off_engine.run_batch(&batch64)?;
    let sps_off = n64 as f64 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let out_scrub = scrub_engine.run_batch(&batch64)?;
    let sps_scrub = n64 as f64 / t0.elapsed().as_secs_f64();
    for (a, b) in out_off.iter().zip(&out_scrub) {
        if a.counts != b.counts || a.stats != b.stats {
            mismatches += 1;
        }
    }
    let (scrubbed3, _, det3) = scrub_engine.integrity_counters();
    scrubbed_total += scrubbed3;
    anyhow::ensure!(det3 == 0, "clean lane-64 run flagged corruption (detected {det3})");
    let overhead = 1.0 - sps_scrub / sps_off;
    println!(
        "seu-soak phase 3 (cost): lane-64 {sps_off:.1} sps off vs {sps_scrub:.1} sps correct \
         ({:.1}% scrub overhead, {scrubbed3} blocks)",
        overhead.max(0.0) * 100.0,
    );

    let injected = (flips + det_flips) as u64;
    let detection_rate = (corrected + detected) as f64 / injected as f64;
    let json = format!(
        "{{\n  \"bench\": \"integrity\",\n  \"seed\": {seed},\n  \"injected_flips\": {injected},\n  \
         \"corrected\": {corrected},\n  \"detected\": {detected},\n  \
         \"detection_rate\": {detection_rate:.4},\n  \"quarantines\": {quarantines},\n  \
         \"resubmitted_streams\": {resubmitted},\n  \"mismatches\": {mismatches},\n  \
         \"scrubbed_blocks\": {scrubbed_total},\n  \"lane64_sps_off\": {sps_off:.1},\n  \
         \"lane64_sps_correct\": {sps_scrub:.1},\n  \"scrub_overhead\": {overhead:.4}\n}}\n"
    );
    std::fs::write(out_path, &json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    match benchcheck::check_report_str(out_path, &json, &benchcheck::Gates::from_env())? {
        benchcheck::ReportStatus::Validated { summary, .. } => {
            println!("integrity gate: OK ({summary})")
        }
        other => anyhow::bail!("{out_path}: unexpected gate outcome {other:?}"),
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn serve_pjrt(
    art: &quantisenc::runtime::artifacts::ModelArtifact,
    dataset: Dataset,
    n: u64,
) -> Result<()> {
    let rt = quantisenc::runtime::Runtime::cpu()?;
    let exe = rt.load_model(art)?;
    let mut tel = Telemetry::new();
    tel.start();
    for i in 0..n {
        let s = dataset.sample(i, Split::Test, art.t_steps);
        let t0 = Instant::now();
        let out = exe.run(&s.spikes)?;
        tel.record(
            t0.elapsed(),
            &quantisenc::hdl::ActivityStats {
                spikes: out.layer_spikes.iter().map(|&x| x as u64).sum(),
                ..Default::default()
            },
            Some(out.prediction == s.label),
        );
    }
    tel.stop();
    println!("{}", tel.summary());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn serve_pjrt(
    _art: &quantisenc::runtime::artifacts::ModelArtifact,
    _dataset: Dataset,
    _n: u64,
) -> Result<()> {
    anyhow::bail!("the PJRT backend is feature-gated: rebuild with `--features pjrt`")
}
