//! `repro` — the QUANTISENC leader binary.
//!
//! Subcommands (hand-rolled parsing; clap is not available offline):
//!
//! ```text
//! repro artifacts             (re)generate the native artifact store
//! repro table <id>            regenerate a paper table (4..12, g)
//! repro figure <id>           regenerate a paper figure (3, 4, 10, 12, 13, 14)
//! repro all                   every table & figure, in paper order
//! repro serve [opts]          batched inference over the ServingEngine
//! repro explore <arch> [Q]    DSE estimate for an architecture on all boards
//! repro codegen <arch>        emit Verilog HDL + self-checking testbench
//! repro bench-check <json>..  validate BENCH_*.json perf reports
//! repro info                  artifact manifest + platform summary
//! ```
//!
//! `serve` options: `--dataset smnist|dvs|shd` `--q Q5.3` `--n <samples>`
//! `--cores <C>` `--lanes <L>` (1..=64 samples per shard message)
//! `--pipeline` `--multicore` `--pjrt` (needs `--features pjrt`).

use anyhow::{Context, Result};
use std::time::Instant;

use quantisenc::coordinator::metrics::Telemetry;
use quantisenc::coordinator::pipeline;
use quantisenc::coordinator::serving::{ServingEngine, ServingOptions};
use quantisenc::datasets::{Dataset, Split};
use quantisenc::dse;
use quantisenc::experiments;
use quantisenc::fixed::QSpec;
use quantisenc::hwmodel::Board;
use quantisenc::runtime::artifacts::Manifest;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Load the manifest, bootstrapping the native artifact store if needed.
fn manifest() -> Result<Manifest> {
    Manifest::load(&quantisenc::golden::ensure_artifacts()?)
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "artifacts" => {
            let dir = quantisenc::artifacts_dir();
            println!("generating native artifact store at {} ...", dir.display());
            let t0 = Instant::now();
            quantisenc::golden::generate(&dir)?;
            let m = Manifest::load(&dir)?;
            println!("done in {:.1?}: models {:?}", t0.elapsed(), m.datasets());
            Ok(())
        }
        "table" => {
            let id = args.get(1).context("usage: repro table <id>")?;
            let m = manifest().ok();
            for t in experiments::run_table(id, m.as_ref())? {
                println!("{t}");
            }
            Ok(())
        }
        "figure" => {
            let id = args.get(1).context("usage: repro figure <id>")?;
            let m = manifest().ok();
            for t in experiments::run_figure(id, m.as_ref())? {
                println!("{t}");
            }
            Ok(())
        }
        "all" => {
            let m = manifest().ok();
            for (kind, id) in experiments::ALL {
                let tables = match *kind {
                    "table" => experiments::run_table(id, m.as_ref()),
                    _ => experiments::run_figure(id, m.as_ref()),
                };
                match tables {
                    Ok(ts) => {
                        for t in ts {
                            println!("{t}");
                        }
                    }
                    Err(e) => eprintln!("[skip] {kind} {id}: {e:#}"),
                }
            }
            Ok(())
        }
        "serve" => serve(&args[1..]),
        "explore" => {
            let arch = args.get(1).context("usage: repro explore <arch> [Qn.q]")?;
            let q = QSpec::parse(args.get(2).map(String::as_str).unwrap_or("Q5.3"))?;
            for board in Board::all() {
                let (p, fits) = dse::estimate(arch, q, &board)?;
                println!(
                    "{:18} {:>9.0} LUT {:>9.0} FF {:>6.1} BRAM {:>5.0} DSP  {:>7.3} W  {}",
                    board.name,
                    p.resources.luts,
                    p.resources.ffs,
                    p.resources.brams,
                    p.resources.dsps,
                    p.power_w,
                    if fits { "FITS" } else { "does NOT fit" }
                );
            }
            Ok(())
        }
        "info" => {
            let m = manifest()?;
            println!("artifacts: {}", m.root.display());
            for ds in m.datasets() {
                println!("  model {ds}: variants {:?}", m.variants(&ds)?);
            }
            println!("  kernels: {:?}", m.kernels());
            #[cfg(feature = "pjrt")]
            {
                match quantisenc::runtime::Runtime::cpu() {
                    Ok(rt) => println!("PJRT platform: {}", rt.platform()),
                    Err(e) => println!("PJRT runtime unavailable: {e:#}"),
                }
            }
            #[cfg(not(feature = "pjrt"))]
            println!("PJRT runtime: disabled (rebuild with --features pjrt)");
            Ok(())
        }
        "codegen" => {
            // Emit Verilog HDL + self-checking SystemVerilog testbench for a
            // configured core (paper §IV's software-defined flow artefacts).
            let arch = args.get(1).context("usage: repro codegen <arch> [outdir]")?;
            let outdir = std::path::PathBuf::from(
                args.get(2).map(String::as_str).unwrap_or("generated_hdl"),
            );
            std::fs::create_dir_all(&outdir)?;
            let cfg = quantisenc::config::ModelConfig::parse_arch(arch, QSpec::parse("Q5.3")?)?;
            let top = quantisenc::hdl::verilog::emit_top(&cfg);
            std::fs::write(outdir.join("quantisenc_top.v"), &top)?;
            // Small random weights + a dataset-shaped stimulus for the TB.
            let mut rng = quantisenc::datasets::rng::XorShift64Star::new(0xC0DE6E);
            let weights: Vec<Vec<i32>> = cfg
                .layers()
                .iter()
                .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(31) as i32 - 15).collect())
                .collect();
            let regs = quantisenc::config::registers::RegisterFile::new(cfg.qspec);
            let t_steps = 8;
            let spikes: Vec<u8> =
                (0..t_steps * cfg.inputs()).map(|_| (rng.uniform() < 0.3) as u8).collect();
            let sample = quantisenc::datasets::Sample {
                spikes,
                t_steps,
                inputs: cfg.inputs(),
                label: 0,
            };
            let tb = quantisenc::hdl::verilog::emit_testbench(&cfg, &weights, &regs, &sample)?;
            std::fs::write(outdir.join("quantisenc_tb.sv"), &tb)?;
            println!(
                "wrote {} ({} bytes) and {} ({} bytes)",
                outdir.join("quantisenc_top.v").display(),
                top.len(),
                outdir.join("quantisenc_tb.sv").display(),
                tb.len()
            );
            Ok(())
        }
        "bench-check" => {
            anyhow::ensure!(args.len() > 1, "usage: repro bench-check <BENCH_*.json>...");
            for path in &args[1..] {
                bench_check(path)?;
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}\n{HELP}"),
    }
}

/// Validate a `BENCH_*.json` perf report (the `make bench-smoke` gate):
/// required keys present, and the acceptance thresholds met — ≥ 5× fewer
/// synaptic ops for the Gaussian-r1 topology report, ≥ 3× layer-step
/// speedup at N=400 / 2% firing plus positive engine throughput for the
/// event-driven hot-path report, and ≥ 2× serving samples/s at lane width
/// 64 vs 1 (gaussian-r1 N=400, zero pool misses) for the lane-batched
/// report.
fn bench_check(path: &str) -> Result<()> {
    use quantisenc::util::json::Json;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let json = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let bench = json.req("bench")?.as_str().context("bench key must be a string")?.to_string();
    match bench.as_str() {
        "bench_layer/topology" => {
            let ratio = json
                .req("ops_ratio_fc400_over_gaussian_r1_400")?
                .as_f64()
                .context("ops ratio must be numeric")?;
            anyhow::ensure!(ratio >= 5.0, "{path}: ops ratio {ratio:.1} below the 5x gate");
            let cases = json.req("cases")?.as_arr().context("cases must be an array")?;
            anyhow::ensure!(!cases.is_empty(), "{path}: empty cases");
            println!("{path}: OK (topology ops ratio {ratio:.1}x over {} cases)", cases.len());
        }
        "hotpath" => {
            let speedup = json
                .req("layer_speedup_n400_2pct")?
                .as_f64()
                .context("layer speedup must be numeric")?;
            // Wall-clock gate (the only timing-based one; the topology gate
            // above is a deterministic op count). Default 3.0 per the PR-4
            // acceptance point; BENCH_GATE_MIN_SPEEDUP overrides it for
            // heavily contended runners where medians get noisy.
            let min_speedup = std::env::var("BENCH_GATE_MIN_SPEEDUP")
                .ok()
                .and_then(|v| v.parse::<f64>().ok())
                .unwrap_or(3.0);
            anyhow::ensure!(
                speedup >= min_speedup,
                "{path}: packed layer-step speedup {speedup:.2}x below the \
                 {min_speedup}x gate (N=400, 2% firing, gaussian r1)"
            );
            let cases = json.req("layer_cases")?.as_arr().context("layer_cases array")?;
            anyhow::ensure!(!cases.is_empty(), "{path}: empty layer_cases");
            let engine = json.req("engine")?;
            let seq = engine
                .req("sequential_samples_per_s")?
                .as_f64()
                .context("sequential_samples_per_s numeric")?;
            let by_cores = engine.req("by_cores")?.as_arr().context("by_cores array")?;
            anyhow::ensure!(
                seq > 0.0 && !by_cores.is_empty(),
                "{path}: missing engine throughput section"
            );
            for c in by_cores {
                let sps = c.req("samples_per_s")?.as_f64().context("samples_per_s numeric")?;
                anyhow::ensure!(sps > 0.0, "{path}: non-positive engine throughput");
            }
            println!(
                "{path}: OK (layer speedup {speedup:.1}x, engine throughput for {} core counts)",
                by_cores.len()
            );
        }
        "batched" => {
            let speedup = json
                .req("speedup_lane64_over_lane1")?
                .as_f64()
                .context("batched speedup must be numeric")?;
            // Wall-clock gate on the lane-batched serving path: lane width
            // 64 must serve ≥ 2× the samples/s of lane width 1 on the
            // gaussian-r1 N=400 case. BENCH_GATE_MIN_BATCH_SPEEDUP
            // overrides it for heavily contended runners.
            let min_speedup = std::env::var("BENCH_GATE_MIN_BATCH_SPEEDUP")
                .ok()
                .and_then(|v| v.parse::<f64>().ok())
                .unwrap_or(2.0);
            anyhow::ensure!(
                speedup >= min_speedup,
                "{path}: lane-64 serving speedup {speedup:.2}x below the \
                 {min_speedup}x gate (gaussian r1, N=400)"
            );
            let misses = json
                .req("matrix_pool_misses")?
                .as_f64()
                .context("matrix_pool_misses numeric")?;
            anyhow::ensure!(
                misses == 0.0,
                "{path}: lane-batched streaming allocated {misses} matrices (pool must not miss)"
            );
            let lanes = json.req("by_lane_width")?.as_arr().context("by_lane_width array")?;
            anyhow::ensure!(!lanes.is_empty(), "{path}: empty by_lane_width");
            for c in lanes {
                let sps = c.req("samples_per_s")?.as_f64().context("samples_per_s numeric")?;
                anyhow::ensure!(sps > 0.0, "{path}: non-positive batched throughput");
            }
            println!(
                "{path}: OK (lane-64 serving speedup {speedup:.1}x over {} lane widths, \
                 zero pool misses)",
                lanes.len()
            );
        }
        other => anyhow::bail!("{path}: unknown bench report kind {other:?}"),
    }
    Ok(())
}

const HELP: &str = "repro — QUANTISENC reproduction CLI
  artifacts       (re)generate the native artifact store (no Python needed)
  table <id>      regenerate a paper table (4,5,6,7,8,9,10,11,12,g)
  figure <id>     regenerate a paper figure (3,4,10,12,13,14)
  all             everything, in paper order
  serve           batched inference service (ServingEngine; --lanes <L> for
                  the 64-sample lane-batched datapath, --pipeline /
                  --multicore for the legacy paths, --pjrt with the feature)
  explore <arch>  DSE estimate, e.g. repro explore 256x512x10 Q5.3
  codegen <arch>  emit Verilog HDL + self-checking SV testbench (paper §IV)
  bench-check <f> validate BENCH_*.json perf reports (the bench-smoke gate)
  info            artifact + platform summary";

fn flag_val<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(String::as_str)
}

fn serve(args: &[String]) -> Result<()> {
    let ds_name = flag_val(args, "--dataset").unwrap_or("smnist");
    let qname = flag_val(args, "--q").unwrap_or("Q5.3");
    let n: u64 = flag_val(args, "--n").unwrap_or("100").parse()?;
    let cores: usize = flag_val(args, "--cores").unwrap_or("2").parse()?;
    let lanes: usize = flag_val(args, "--lanes").unwrap_or("1").parse()?;
    let use_pipeline = args.iter().any(|a| a == "--pipeline");
    let use_multicore = args.iter().any(|a| a == "--multicore" || a == "--hdl");
    let use_pjrt = args.iter().any(|a| a == "--pjrt");
    anyhow::ensure!(
        lanes <= 1 || !(use_pipeline || use_multicore || use_pjrt),
        "--lanes is a ServingEngine knob; it does nothing on the \
         --pipeline/--multicore/--pjrt backends — drop one of the flags"
    );
    let dataset = Dataset::parse(ds_name).context("bad --dataset")?;

    let m = manifest()?;
    let art = m.model(ds_name, qname)?;
    let backend = if use_pjrt {
        "pjrt"
    } else if use_pipeline {
        "pipeline"
    } else if use_multicore {
        "multicore"
    } else {
        "serving-engine"
    };
    println!(
        "serving {ds_name} ({}) {qname}, {n} requests, backend={backend}",
        art.sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join("x"),
    );

    if use_pjrt {
        return serve_pjrt(&art, dataset, n);
    }

    if use_pipeline {
        // Layer-pipelined streaming over the cycle-accurate core (Fig. 8).
        let (config, core) = experiments::core_from_artifact(&art)?;
        let samples: Vec<_> =
            (0..n).map(|i| dataset.sample(i, Split::Test, art.t_steps)).collect();
        let t0 = Instant::now();
        let results = pipeline::run_pipelined(&config, &art.weights, &core.registers, &samples)?;
        let dt = t0.elapsed();
        let correct =
            results.iter().zip(&samples).filter(|(r, s)| r.prediction == s.label).count();
        println!(
            "pipelined: {} streams in {:.2?} ({:.1}/s), accuracy {:.1}%",
            results.len(),
            dt,
            results.len() as f64 / dt.as_secs_f64(),
            100.0 * correct as f64 / n as f64
        );
        return Ok(());
    }

    if use_multicore {
        let mut tel = Telemetry::new();
        tel.start();
        let (config, core) = experiments::core_from_artifact(&art)?;
        let mut mc = quantisenc::coordinator::multicore::MultiCore::new(
            &config,
            &art.weights,
            &core.registers,
            cores,
        )?;
        let samples: Vec<_> =
            (0..n).map(|i| dataset.sample(i, Split::Test, art.t_steps)).collect();
        let t0 = Instant::now();
        let results = mc.run_batch(&samples);
        let per_req = t0.elapsed() / n.max(1) as u32;
        for (r, s) in results.iter().zip(&samples) {
            tel.record(per_req, &r.stats, Some(r.prediction == s.label));
        }
        tel.stop();
        println!("{}", tel.summary());
        let p = quantisenc::hwmodel::power::core_dynamic_from_stats(
            &config,
            &tel.activity,
            quantisenc::hwmodel::power::F0_HZ,
        );
        println!("modelled dynamic power at 600 kHz: {p:.3} W");
        return Ok(());
    }

    // Default: the unified ServingEngine (C sharded cores × pipelined
    // layers, optionally stepping `--lanes` samples per shard message).
    let (config, core) = experiments::core_from_artifact(&art)?;
    let mut engine = ServingEngine::new(
        &config,
        &art.weights,
        &core.registers,
        ServingOptions::with_lanes(cores, lanes),
    )?;
    let samples: Vec<_> = (0..n).map(|i| dataset.sample(i, Split::Test, art.t_steps)).collect();
    let mut tel = Telemetry::new();
    tel.start();
    let t0 = Instant::now();
    let results = engine.run_batch(&samples)?;
    let dt = t0.elapsed();
    let per_req = dt / n.max(1) as u32;
    for (r, s) in results.iter().zip(&samples) {
        tel.record(per_req, &r.stats, Some(r.prediction == s.label));
        tel.record_epoch(r.epoch);
    }
    tel.stop();
    tel.record_bus(engine.bus());
    let (submitted, completed) = engine.stats();
    println!(
        "serving-engine: {} streams on {} cores in {:.2?}, admitted={submitted} completed={completed}",
        results.len(),
        engine.num_cores(),
        dt,
    );
    println!("{}", tel.summary());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn serve_pjrt(
    art: &quantisenc::runtime::artifacts::ModelArtifact,
    dataset: Dataset,
    n: u64,
) -> Result<()> {
    let rt = quantisenc::runtime::Runtime::cpu()?;
    let exe = rt.load_model(art)?;
    let mut tel = Telemetry::new();
    tel.start();
    for i in 0..n {
        let s = dataset.sample(i, Split::Test, art.t_steps);
        let t0 = Instant::now();
        let out = exe.run(&s.spikes)?;
        tel.record(
            t0.elapsed(),
            &quantisenc::hdl::ActivityStats {
                spikes: out.layer_spikes.iter().map(|&x| x as u64).sum(),
                ..Default::default()
            },
            Some(out.prediction == s.label),
        );
    }
    tel.stop();
    println!("{}", tel.summary());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn serve_pjrt(
    _art: &quantisenc::runtime::artifacts::ModelArtifact,
    _dataset: Dataset,
    _n: u64,
) -> Result<()> {
    anyhow::bail!("the PJRT backend is feature-gated: rebuild with `--features pjrt`")
}
