//! Baseline and comparator designs.
//!
//! * [`DataflowBaseline`] — the non-pipelined layer-by-layer dataflow
//!   execution of Gyro \[30\]: every stream pays the full K·L layer
//!   latency (the §VI-G comparison point, 31.25 fps vs our 41.67 fps —
//!   see [`crate::coordinator::pipeline::ScheduleModel`]).
//! * [`SotaDesign`] and the `EULER_*` / `BEST_*` / `PAPER_OURS_*`
//!   constants — the published comparison designs of Tables II and VII
//!   (\[28\] overlay DNN, \[33\]/\[34\] Euler LIF neurons, \[35\]
//!   HLS-optimised SELM). These are *literature constants with citations*
//!   — the paper's authors did not re-implement them either; they are the
//!   fixed columns our measured/modelled numbers
//!   ([`crate::experiments::resources_exp`]) are compared against.

use crate::config::ModelConfig;
use crate::datasets::Sample;
use crate::hdl::core::RunResult;
use crate::hdl::Core;

/// Non-pipelined dataflow execution [30]: functionally identical results,
/// but the timing model charges K·L cycles of layer latency per stream and
/// no stream overlap. Wraps the same cycle-accurate core (the *hardware*
/// doesn't change — the schedule does).
pub struct DataflowBaseline {
    core: Core,
    /// Per-layer latency L in spk_clk cycles.
    pub layer_latency: f64,
}

impl DataflowBaseline {
    pub fn new(config: ModelConfig) -> DataflowBaseline {
        DataflowBaseline { core: Core::new(config), layer_latency: 4.0 }
    }

    pub fn core_mut(&mut self) -> &mut Core {
        &mut self.core
    }

    pub fn run(&mut self, sample: &Sample) -> RunResult {
        self.core.run(sample)
    }

    /// Streams/sec at exposure `exposure_s` and spike frequency `f_hz` —
    /// the [30] formula 1/(exposure + K·L/f).
    pub fn fps(&self, exposure_s: f64, f_hz: f64) -> f64 {
        let k = self.core.config().num_layers() as f64 + 1.0; // paper counts input layer stage
        1.0 / (exposure_s + k * self.layer_latency / f_hz)
    }
}

/// A published comparator design (Tables II / VII constants).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SotaDesign {
    pub citation: &'static str,
    pub year: u32,
    pub config: &'static str,
    pub neurons: Option<u32>,
    pub synapses: Option<u32>,
    pub luts: u32,
    pub ffs: u32,
    pub brams: u32,
    pub power_w: Option<f64>,
    pub accuracy: Option<f64>,
}

/// Table VII column "Euler [33]" (single neuron).
pub const EULER_GUO_33: SotaDesign = SotaDesign {
    citation: "[33] Guo et al., TNNLS 2021",
    year: 2021,
    config: "single neuron",
    neurons: None,
    synapses: None,
    luts: 95,
    ffs: 85,
    brams: 0,
    power_w: Some(0.25),
    accuracy: None,
};

/// Table VII column "Euler [34]" (single neuron).
pub const EULER_YE_34: SotaDesign = SotaDesign {
    citation: "[34] Ye et al., TCAD 2022",
    year: 2022,
    config: "single neuron",
    neurons: None,
    synapses: None,
    luts: 76,
    ffs: 20,
    brams: 0,
    power_w: None, // "NR" in the paper
    accuracy: None,
};

/// Table VII column "Best Accuracy [28]" (full SNN, 784-1024-10).
pub const BEST_ACCURACY_28: SotaDesign = SotaDesign {
    citation: "[28] Abdelsalam et al., ReConFig 2018",
    year: 2018,
    config: "784-1024-10",
    neurons: Some(1818),
    synapses: Some(813_056),
    luts: 78_679,
    ffs: 16_864,
    brams: 174,
    power_w: Some(3.4),
    accuracy: Some(0.984),
};

/// Table VII column "Best Hardware [35]" (full SNN, 784-2048-10).
pub const BEST_HARDWARE_35: SotaDesign = SotaDesign {
    citation: "[35] He et al., TCAS-II 2021",
    year: 2021,
    config: "784-2048-10",
    neurons: Some(2932),
    synapses: Some(1_810_432),
    luts: 16_813,
    ffs: 7_559,
    brams: 129,
    power_w: Some(1.03),
    accuracy: Some(0.930),
};

/// The paper's own Table VII "Ours" single-neuron column (kept as published
/// constants so the comparison table can show paper-vs-model error).
pub const PAPER_OURS_NEURON: SotaDesign = SotaDesign {
    citation: "QUANTISENC (paper)",
    year: 2023,
    config: "single neuron",
    neurons: None,
    synapses: None,
    luts: 108,
    ffs: 23,
    brams: 0,
    power_w: Some(0.05),
    accuracy: None,
};

/// The paper's Table VII "Ours" SNN column (256-128-10).
pub const PAPER_OURS_SNN: SotaDesign = SotaDesign {
    citation: "QUANTISENC (paper)",
    year: 2023,
    config: "256-128-10",
    neurons: Some(394),
    synapses: Some(34_048),
    luts: 40_965,
    ffs: 7_095,
    brams: 69,
    power_w: Some(0.623),
    accuracy: Some(0.965),
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q5_3;

    #[test]
    fn dataflow_fps_matches_paper() {
        // [30] at 20 ms exposure, L = 4 cycles, f = 1 kHz, 3-layer design.
        let cfg = ModelConfig::parse_arch("256x128x10", Q5_3).unwrap();
        let b = DataflowBaseline::new(cfg);
        assert!((b.fps(0.020, 1000.0) - 31.25).abs() < 0.01, "{}", b.fps(0.020, 1000.0));
    }

    #[test]
    fn dataflow_functionally_identical() {
        let cfg = ModelConfig::parse_arch("4x3x2", Q5_3).unwrap();
        let mut b = DataflowBaseline::new(cfg.clone());
        let mut c = Core::new(cfg);
        for i in 0..4 {
            b.core_mut().layer_mut(0).memory_mut().write(i, 0, 8).unwrap();
            c.layer_mut(0).memory_mut().write(i, 0, 8).unwrap();
        }
        let s = Sample { spikes: vec![1; 4 * 5], t_steps: 5, inputs: 4, label: 0 };
        assert_eq!(b.run(&s).counts, c.run(&s).counts);
    }

    #[test]
    fn sota_constants_sane() {
        assert!(BEST_ACCURACY_28.accuracy.unwrap() > PAPER_OURS_SNN.accuracy.unwrap());
        assert!(BEST_ACCURACY_28.power_w.unwrap() > PAPER_OURS_SNN.power_w.unwrap());
        assert!(BEST_HARDWARE_35.luts < PAPER_OURS_SNN.luts);
        assert_eq!(EULER_YE_34.power_w, None);
    }
}
