//! Deterministic chaos harness for the serving engine.
//!
//! A [`ChaosSchedule`] is a sorted list of fault injections keyed on the
//! **global sample index** (the engine-lifetime count of admitted
//! samples, [`ServingEngine::stats`](super::ServingEngine::stats)'s
//! `submitted`): when the feeder is about to admit sample `at_sample`, it
//! first pushes the event's [`ChaosKind`] into the target shard's stage
//! FIFO as a control message. Because injection rides the same bounded
//! channels as the data, the fault lands at an exact, reproducible point
//! in each shard's message stream: every stream dispatched to that shard
//! before the event completes normally, and everything behind it is lost
//! with the shard (and settled as a typed
//! [`ShardLost`](super::ServingError::ShardLost)).
//!
//! This generalizes the PR-6 `chaos_panic` one-shot (a panic riding a
//! reconfig broadcast, which necessarily killed *every* shard at the same
//! epoch) into per-shard, per-stage, per-sample-index faults of three
//! kinds: stage panics, channel teardowns, and slow-stage stalls. The
//! first two kill the shard — the supervisor must quarantine, rebuild
//! from the last connectome checkpoint, and re-admit it; the stall only
//! delays it — the shard must *not* be quarantined, and results must
//! still arrive bit-exact.
//!
//! Schedules are either explicit ([`ChaosSchedule::new`]) or generated
//! from a seed ([`ChaosSchedule::seeded`]); both are pure functions of
//! their inputs, so a chaos soak is replayable from its command line.

use crate::datasets::rng::XorShift64Star;
use crate::hdl::integrity::FlipTarget;

/// One kind of injected fault, addressed to a stage of the target shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosKind {
    /// The addressed stage thread panics (unwind, not abort). The shard's
    /// chain cascades down and the supervisor rebuilds it.
    StagePanic { stage: usize },
    /// The addressed stage exits its loop, dropping its channel ends —
    /// the software model of a torn-down channel. Upstream sends start
    /// failing, downstream drains and exits; unlike a panic there is no
    /// payload to harvest, so recovery must not depend on one.
    ChannelDrop { stage: usize },
    /// The addressed stage sleeps `millis` before continuing. The shard
    /// stays healthy; backpressure holds the traffic, nothing is lost.
    SlowStage { stage: usize, millis: u64 },
    /// A single-event upset: flip `bit` of word `word` in the addressed
    /// layer's state memory (`word` wraps modulo the bank size). The
    /// flip bypasses the integrity codes, exactly like radiation hitting
    /// an SRAM cell; what happens next depends on the engine's
    /// [`IntegrityMode`](crate::hdl::integrity::IntegrityMode) — repaired
    /// in place (`Correct`), quarantined and rebuilt (`Detect`), or
    /// silently corrupting results (`Off`).
    BitFlip { layer: usize, target: FlipTarget, word: usize, bit: u8 },
}

/// A fault scheduled at an exact global sample index on one shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosEvent {
    /// Global (engine-lifetime) sample index at whose admission the fault
    /// is injected. Index 0 is the first sample the engine ever admits.
    pub at_sample: u64,
    /// Target shard.
    pub shard: usize,
    pub kind: ChaosKind,
}

/// A deterministic, replayable fault schedule (see the module docs).
#[derive(Debug, Clone, Default)]
pub struct ChaosSchedule {
    events: Vec<ChaosEvent>,
}

impl ChaosSchedule {
    /// An explicit schedule. Events are sorted by `at_sample` (stable, so
    /// same-index events fire in the given order).
    pub fn new(mut events: Vec<ChaosEvent>) -> ChaosSchedule {
        events.sort_by_key(|e| e.at_sample);
        ChaosSchedule { events }
    }

    /// A seeded schedule of `deaths` shard-killing faults (alternating
    /// stage panics and channel drops) spread over the first `span`
    /// samples of an engine with `shards` shards and `stages` pipeline
    /// stages. Shards are covered round-robin so a multi-shard soak
    /// always exercises more than one shard; sample indices and stage
    /// targets come from the seed. Pure function of its arguments.
    pub fn seeded(
        seed: u64,
        deaths: usize,
        span: u64,
        shards: usize,
        stages: usize,
    ) -> ChaosSchedule {
        let mut rng = XorShift64Star::new(seed | 1);
        let events = (0..deaths)
            .map(|i| {
                let stage = rng.below(stages.max(1) as u64) as usize;
                let kind = if i % 2 == 0 {
                    ChaosKind::StagePanic { stage }
                } else {
                    ChaosKind::ChannelDrop { stage }
                };
                ChaosEvent {
                    at_sample: rng.below(span.max(1)),
                    shard: i % shards.max(1),
                    kind,
                }
            })
            .collect();
        ChaosSchedule::new(events)
    }

    /// A seeded schedule of `flips` single-event upsets spread over the
    /// first `span` samples of an engine with `shards` shards and
    /// `layers` pipeline layers. Shards are covered round-robin and the
    /// flips alternate weight and membrane targets; layer, word, and bit
    /// positions come from the seed (words wrap modulo the bank size at
    /// injection time, so any word value addresses real storage). Pure
    /// function of its arguments.
    pub fn seeded_flips(
        seed: u64,
        flips: usize,
        span: u64,
        shards: usize,
        layers: usize,
    ) -> ChaosSchedule {
        let mut rng = XorShift64Star::new(seed | 1);
        let events = (0..flips)
            .map(|i| {
                let target = if i % 2 == 0 { FlipTarget::Weights } else { FlipTarget::Vmem };
                let kind = ChaosKind::BitFlip {
                    layer: rng.below(layers.max(1) as u64) as usize,
                    target,
                    word: rng.below(1 << 20) as usize,
                    bit: rng.below(32) as u8,
                };
                ChaosEvent { at_sample: rng.below(span.max(1)), shard: i % shards.max(1), kind }
            })
            .collect();
        ChaosSchedule::new(events)
    }

    /// The events, sorted by `at_sample`.
    pub fn events(&self) -> &[ChaosEvent] {
        &self.events
    }

    /// Events whose `at_sample` falls in `[from, to)` — the injections an
    /// admission window of global sample indices must fire, with indices
    /// rebased to the window (`at_sample - from`).
    pub(crate) fn window(&self, from: u64, to: u64) -> Vec<(usize, ChaosEvent)> {
        self.events
            .iter()
            .filter(|e| e.at_sample >= from && e.at_sample < to)
            .map(|e| ((e.at_sample - from) as usize, *e))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_schedules_are_deterministic_and_cover_shards() {
        let a = ChaosSchedule::seeded(0xC405, 6, 100, 3, 3);
        let b = ChaosSchedule::seeded(0xC405, 6, 100, 3, 3);
        assert_eq!(a.events(), b.events(), "same seed, same schedule");
        let shards: std::collections::BTreeSet<usize> =
            a.events().iter().map(|e| e.shard).collect();
        assert_eq!(shards.len(), 3, "round-robin shard coverage");
        assert!(a.events().windows(2).all(|w| w[0].at_sample <= w[1].at_sample), "sorted");
        let c = ChaosSchedule::seeded(0xC406, 6, 100, 3, 3);
        assert_ne!(a.events(), c.events(), "different seed, different schedule");
    }

    #[test]
    fn seeded_flip_schedules_are_deterministic_and_alternate_targets() {
        let a = ChaosSchedule::seeded_flips(0x5EED, 8, 50, 2, 3);
        let b = ChaosSchedule::seeded_flips(0x5EED, 8, 50, 2, 3);
        assert_eq!(a.events(), b.events(), "same seed, same schedule");
        let mut weights = 0;
        let mut vmem = 0;
        for e in a.events() {
            match e.kind {
                ChaosKind::BitFlip { target: FlipTarget::Weights, bit, .. } => {
                    assert!(bit < 32);
                    weights += 1;
                }
                ChaosKind::BitFlip { target: FlipTarget::Vmem, .. } => vmem += 1,
                other => panic!("unexpected kind {other:?}"),
            }
        }
        assert_eq!((weights, vmem), (4, 4), "alternating targets");
        let shards: std::collections::BTreeSet<usize> =
            a.events().iter().map(|e| e.shard).collect();
        assert_eq!(shards.len(), 2, "round-robin shard coverage");
    }

    #[test]
    fn window_rebases_and_filters() {
        let s = ChaosSchedule::new(vec![
            ChaosEvent { at_sample: 3, shard: 0, kind: ChaosKind::StagePanic { stage: 1 } },
            ChaosEvent { at_sample: 10, shard: 1, kind: ChaosKind::ChannelDrop { stage: 0 } },
            ChaosEvent { at_sample: 17, shard: 0, kind: ChaosKind::SlowStage { stage: 2, millis: 5 } },
        ]);
        let w = s.window(8, 16);
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].0, 2, "rebased to the window");
        assert_eq!(w[0].1.shard, 1);
        assert!(s.window(20, 30).is_empty());
    }
}
