//! Pipelined stream processing — paper §IV Fig. 8 and §VI-G.
//!
//! QUANTISENC's distributed per-layer synaptic memory lets the K layers run
//! independently, so consecutive input streams can overlap: stream i+1
//! enters layer 1 while stream i is in layer 2. Streams are injected every
//! `d + s` (d = one layer's stream-processing time, s = the settle time that
//! returns membranes to rest), giving steady-state throughput `1/(d + s)`
//! instead of the dataflow baseline's `1/(exposure + K·L/f)` [30].
//!
//! Two artefacts live here:
//!
//! * [`ScheduleModel`] — the analytic cycle/latency model behind Eq. 11 and
//!   the §VI-G numbers (41.67 fps pipelined vs 31.25 fps non-pipelined).
//! * [`run_pipelined`] — a real thread-per-layer streaming executor over the
//!   cycle-accurate hdl layers: stage k owns layer k, bounded channels carry
//!   per-timestep spike vectors, and results must equal the sequential core
//!   bit-for-bit (asserted in tests). On a many-core host this also yields
//!   wall-clock overlap; on this single-core testbed the cycle model is the
//!   performance evidence and the executor is the correctness evidence.

use std::sync::mpsc;
use std::sync::Arc;

use crate::config::registers::RegisterFile;
use crate::config::ModelConfig;
use crate::datasets::Sample;
use crate::hdl::spikes::{MatrixPool, PlanePool};
use crate::hdl::ActivityStats;

use super::serving::{
    build_layers, collector_loop, panic_message, stage_loop, ScrubPlan, ServingError, StageMsg,
};

/// Analytic pipeline schedule — Eq. 11 and the Fig. 8 timing diagram.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleModel {
    /// Exposure time per stream in seconds (the user-defined presentation
    /// window; 20 ms in §VI-G).
    pub exposure_s: f64,
    /// Spike frequency f (Hz).
    pub f_hz: f64,
    /// Clock cycles to settle the membrane to rest between streams
    /// (N_reset; the paper measured 4 cycles at 1 kHz for τ = 5 ms).
    pub n_reset: f64,
    /// Number of layers K.
    pub layers: usize,
    /// Per-layer latency L in clock cycles (the paper's comparison to [30]
    /// uses L = N_reset = 4).
    pub layer_latency: f64,
}

impl ScheduleModel {
    /// §VI-G operating point: 20 ms exposure, N_reset = 4 @ 1 kHz, K = 3.
    pub fn paper_baseline() -> ScheduleModel {
        ScheduleModel { exposure_s: 0.020, f_hz: 1000.0, n_reset: 4.0, layers: 3, layer_latency: 4.0 }
    }

    /// Eq. 11: pipelined real-time performance (streams/sec = fps).
    /// In steady state a new stream completes every exposure + N_reset/f.
    pub fn pipelined_fps(&self) -> f64 {
        1.0 / (self.exposure_s + self.n_reset / self.f_hz)
    }

    /// The non-pipelined dataflow baseline [30]: every stream pays the full
    /// K·L layer latency on top of the exposure.
    pub fn dataflow_fps(&self) -> f64 {
        1.0 / (self.exposure_s + (self.layers as f64 * self.layer_latency) / self.f_hz)
    }

    /// Throughput improvement of pipelining (the paper reports 33.3%).
    pub fn speedup(&self) -> f64 {
        self.pipelined_fps() / self.dataflow_fps()
    }

    /// Fig. 8 steady-state stream initiation interval in seconds (d + s).
    pub fn initiation_interval_s(&self) -> f64 {
        self.exposure_s + self.n_reset / self.f_hz
    }

    /// Pipeline fill latency for the first stream (K stages).
    pub fn fill_latency_s(&self) -> f64 {
        self.layers as f64 * (self.exposure_s + self.layer_latency / self.f_hz)
    }
}

/// Result of one stream through the pipelined executor / serving engine.
#[derive(Debug, Clone)]
pub struct StreamResult {
    pub stream_id: usize,
    pub counts: Vec<u32>,
    pub prediction: usize,
    /// Output-layer spikes for this stream (the spk_out event count).
    pub spikes_total: u64,
    /// Config epoch this stream was processed under: 0 is the
    /// construction-time configuration; each accepted
    /// [`crate::coordinator::control::ControlPlane`] program increments it.
    /// Always 0 for [`run_pipelined`], which has no control plane.
    pub epoch: u64,
    /// Full activity ledger for this stream, accumulated across every
    /// stage — bit-identical to the `stats` of a sequential
    /// [`crate::hdl::Core::run`] on the same sample.
    pub stats: ActivityStats,
}

/// Thread-per-layer pipelined execution of a batch of samples.
///
/// Each stage owns one hdl layer; samples flow as (stream_id, timestep
/// vectors…, Reset) messages. The settle marker (`Reset`) implements
/// Fig. 8's waiting time `s`: every stage resets its membranes between
/// streams, so results are identical to running each sample through a fresh
/// sequential core.
pub fn run_pipelined(
    config: &ModelConfig,
    weights: &[Vec<i32>],
    regs: &RegisterFile,
    samples: &[Sample],
) -> anyhow::Result<Vec<StreamResult>> {
    // Build the per-stage layers up front (programming weights via wt_in).
    let layers = build_layers(config, weights)?;
    let n_out = config.outputs();
    // Recycled-plane free list shared by the injector and the collector
    // (one-shot executor: allocate on first use, recycle across streams).
    let pool = Arc::new(PlanePool::new());
    // The one-shot executor never lane-batches, but the shared collector
    // body wants a matrix pool handle.
    let mat_pool = Arc::new(MatrixPool::new());
    std::thread::scope(|scope| {
        // Channel chain: injector -> stage 0 -> … -> stage K-1 -> collector.
        // Stage and collector bodies are the serving-engine primitives; this
        // function only adds the scoped one-batch wiring around them. Every
        // handle is kept and joined explicitly below: a scope-exit auto-join
        // re-raises worker panics and would abort the process.
        let (injector, mut chain_rx) = mpsc::sync_channel::<StageMsg>(64);
        let mut stages = Vec::new();
        for (layer_idx, layer) in layers.into_iter().enumerate() {
            let (tx, next_rx) = mpsc::sync_channel::<StageMsg>(64);
            let stage_regs = regs.clone();
            let rx = std::mem::replace(&mut chain_rx, next_rx);
            stages.push(scope.spawn(move || {
                // Integrity-off scrub plan: the one-shot executor has no
                // chaos surface and no supervisor to feed.
                stage_loop(
                    layer_idx,
                    layer,
                    stage_regs,
                    rx,
                    tx,
                    Vec::new(),
                    Vec::new(),
                    ScrubPlan::default(),
                )
            }));
        }
        let collector_rx = chain_rx;

        // Collector accumulates output-layer spike counts per stream.
        let collector_pool = pool.clone();
        let collector_mats = mat_pool.clone();
        let collector = scope.spawn(move || {
            let mut results: Vec<StreamResult> = Vec::new();
            collector_loop(n_out, collector_rx, collector_pool, collector_mats, |r| {
                results.push(r);
                true
            });
            results
        });

        // Inject the streams back-to-back (the d+s stagger emerges from the
        // bounded channels providing backpressure). A dead stage stops the
        // feed but must not early-return: the explicit joins below still
        // have to run to convert a panic into a typed error.
        let mut feed_err = None;
        'feed: for (stream, sample) in samples.iter().enumerate() {
            for t in 0..sample.t_steps {
                let mut plane = pool.take();
                sample.step_plane_into(t, &mut plane);
                if injector.send(StageMsg::Step { stream, plane }).is_err() {
                    feed_err = Some(anyhow::anyhow!("pipeline stage died"));
                    break 'feed;
                }
            }
            if injector
                .send(StageMsg::Flush { stream, stats: ActivityStats::default() })
                .is_err()
            {
                feed_err = Some(anyhow::anyhow!("pipeline stage died"));
                break 'feed;
            }
        }
        // Closing the injector drains the chain: stages exit front-to-back,
        // then the collector returns — so these joins cannot block.
        drop(injector);
        let mut panicked: Option<ServingError> = None;
        for (k, handle) in stages.into_iter().enumerate() {
            if let Err(payload) = handle.join() {
                panicked.get_or_insert(ServingError::WorkerPanicked {
                    worker: format!("pipeline stage {k}"),
                    message: panic_message(payload),
                });
            }
        }
        let results = match collector.join() {
            Ok(r) => Some(r),
            Err(payload) => {
                panicked.get_or_insert(ServingError::WorkerPanicked {
                    worker: "pipeline collector".to_string(),
                    message: panic_message(payload),
                });
                None
            }
        };
        if let Some(err) = panicked {
            return Err(err.into());
        }
        if let Some(e) = feed_err {
            return Err(e);
        }
        Ok(results.expect("collector joined cleanly"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{Dataset, Split};
    use crate::fixed::Q5_3;
    use crate::hdl::Core;

    #[test]
    fn paper_baseline_numbers() {
        let m = ScheduleModel::paper_baseline();
        assert!((m.pipelined_fps() - 41.67).abs() < 0.01, "{}", m.pipelined_fps());
        assert!((m.dataflow_fps() - 31.25).abs() < 0.01, "{}", m.dataflow_fps());
        assert!((m.speedup() - 4.0 / 3.0).abs() < 1e-6, "33.3% improvement");
    }

    #[test]
    fn paper_numbers_to_three_decimals() {
        // §VI-G / Eq. 11 at the paper's operating point, pinned to three
        // decimal places: 1/(0.020 + 4/1000) = 41.667 fps pipelined vs
        // 1/(0.020 + 3·4/1000) = 31.250 fps for the dataflow baseline [30].
        let m = ScheduleModel::paper_baseline();
        assert!((m.pipelined_fps() - 41.667).abs() < 5e-4, "{}", m.pipelined_fps());
        assert!((m.dataflow_fps() - 31.250).abs() < 5e-4, "{}", m.dataflow_fps());
        // Eq. 11 algebraic identity: fps == 1 / initiation interval.
        assert!((m.pipelined_fps() * m.initiation_interval_s() - 1.0).abs() < 1e-12);
        // The paper's 33.3% improvement claim, to three decimals: 4/3.
        assert!((m.speedup() - 1.333).abs() < 5e-4, "{}", m.speedup());
    }

    #[test]
    fn initiation_interval_and_fill() {
        let m = ScheduleModel::paper_baseline();
        assert!((m.initiation_interval_s() - 0.024).abs() < 1e-9);
        assert!(m.fill_latency_s() > m.initiation_interval_s());
    }

    #[test]
    fn pipelined_matches_sequential_bitexact() {
        let cfg = ModelConfig::parse_arch("16x12x4", Q5_3).unwrap();
        // Random-ish weights via the dataset rng.
        let mut rng = crate::datasets::rng::XorShift64Star::new(0x1717);
        let weights: Vec<Vec<i32>> = cfg
            .layers()
            .iter()
            .map(|l| {
                (0..l.fan_in * l.neurons)
                    .map(|_| (rng.below(17) as i32) - 8)
                    .collect()
            })
            .collect();
        let regs = RegisterFile::new(Q5_3);

        // Samples: slices of smnist inputs truncated to 16 channels.
        let samples: Vec<Sample> = (0..6)
            .map(|i| {
                let s = Dataset::Smnist.sample(i, Split::Test, 10);
                let spikes: Vec<u8> = (0..10)
                    .flat_map(|t| s.step(t)[..16].to_vec())
                    .collect();
                Sample { spikes, t_steps: 10, inputs: 16, label: s.label }
            })
            .collect();

        let piped = run_pipelined(&cfg, &weights, &regs, &samples).unwrap();

        let mut core = Core::new(cfg);
        core.load_weights(&weights).unwrap();
        for (i, sample) in samples.iter().enumerate() {
            let seq = core.run(sample);
            assert_eq!(piped[i].counts, seq.counts, "stream {i}");
            assert_eq!(piped[i].prediction, seq.prediction);
            assert_eq!(piped[i].stats, seq.stats, "stream {i} activity ledger");
            assert_eq!(piped[i].epoch, 0, "no control plane here: epoch stays 0");
        }
        // Streams come back in order.
        assert!(piped.windows(2).all(|w| w[0].stream_id < w[1].stream_id));
    }

    #[test]
    fn empty_batch_is_fine() {
        let cfg = ModelConfig::parse_arch("4x2", Q5_3).unwrap();
        let regs = RegisterFile::new(Q5_3);
        let out = run_pipelined(&cfg, &[vec![0; 8]], &regs, &[]).unwrap();
        assert!(out.is_empty());
    }
}
