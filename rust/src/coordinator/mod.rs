//! The L3 coordinator — paper §IV: the hardware-software interface and the
//! pipelined streaming that is QUANTISENC's throughput contribution.
//!
//! * [`interface`] — the three I/O interfaces (wt_in / cfg_in / spk_in-out)
//!   over a modelled AXI bus, fronting either the cycle-accurate hdl core
//!   or a PJRT executable (both are "the hardware" behind the same API).
//! * [`pipeline`] — Fig. 8: streams scheduled every (d + s); the analytic
//!   cycle schedule (Eq. 11 real-time performance) plus a thread-based
//!   streaming executor that overlaps layer processing across streams.
//! * [`multicore`] — batch-level parallelism across QUANTISENC cores.
//! * [`serving`] — the unified production request path: C sharded cores ×
//!   per-layer pipelined stages with bounded channels, batch admission,
//!   backpressure, and in-order results ([`serving::ServingEngine`]).
//! * [`control`] — the live control plane ([`control::ControlPlane`]):
//!   run-time cfg_in/wt_in reprogramming of a serving engine, delivered as
//!   epoch-tagged control messages on the same bounded stage channels as
//!   the data, validated up front, and charged to the same AXI ledger
//!   ([`interface::BusStats`]) as data traffic.
//! * [`metrics`] — request-path telemetry (latency percentiles, throughput,
//!   spike/power accounting, bus-beat reporting).
//! * [`wire`] — the network front door's frame grammar: a std-only,
//!   length-prefixed binary spike-frame/AER protocol carrying bit-packed
//!   spike trains, control-plane programs, and results.
//! * [`server`] — the TCP front door ([`server::SpikeServer`]):
//!   multiplexes many concurrent client sessions onto one lane-batched
//!   [`serving::ServingEngine`] with per-session admission control and
//!   per-tenant reconfiguration through the control plane's epochs.
//! * [`client`] — the matching client ([`client::WireClient`]) and the
//!   open-loop load generator behind `repro loadgen`.
//! * [`connectome`] — the versioned binary snapshot of a serving engine's
//!   complete software-defined state ([`connectome::Connectome`]):
//!   topology-packed weights, registers, neuron banks, epoch and bus
//!   ledgers — with per-section CRCs, a never-panicking decoder, bit-exact
//!   restore ([`serving::ServingEngine::from_connectome`]) and live
//!   blue/green migration ([`control::ControlPlane::migrate`]).
//!
//! See `ARCHITECTURE.md` at the repo root for the module map, the
//! paper-section cross-reference, and the dataflow diagram of the sharded
//! pipelined engine with the control-message path.

pub mod client;
pub mod connectome;
pub mod control;
pub mod interface;
pub mod metrics;
pub mod multicore;
pub mod pipeline;
pub mod server;
pub mod serving;
pub mod wire;
