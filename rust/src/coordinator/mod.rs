//! The L3 coordinator — paper §IV: the hardware-software interface and the
//! pipelined streaming that is QUANTISENC's throughput contribution.
//!
//! * [`interface`] — the three I/O interfaces (wt_in / cfg_in / spk_in-out)
//!   over a modelled AXI bus, fronting either the cycle-accurate hdl core
//!   or a PJRT executable (both are "the hardware" behind the same API).
//! * [`pipeline`] — Fig. 8: streams scheduled every (d + s); the analytic
//!   cycle schedule (Eq. 11 real-time performance) plus a thread-based
//!   streaming executor that overlaps layer processing across streams.
//! * [`multicore`] — batch-level parallelism across QUANTISENC cores.
//! * [`serving`] — the unified production request path: C sharded cores ×
//!   per-layer pipelined stages with bounded channels, batch admission,
//!   backpressure, and in-order results ([`serving::ServingEngine`]).
//! * [`metrics`] — request-path telemetry (latency percentiles, throughput,
//!   spike/power accounting).

pub mod interface;
pub mod metrics;
pub mod multicore;
pub mod pipeline;
pub mod serving;
