//! The network front door — a std-only TCP server that multiplexes many
//! concurrent client sessions onto one lane-batched
//! [`ServingEngine`](super::serving::ServingEngine).
//!
//! The paper's host↔core interface (spk_in / cfg_in / wt_in, §IV) becomes
//! a socket: clients speak the [`super::wire`] frame protocol, submit
//! bit-packed spike trains, and reprogram the core per-tenant through the
//! same [`ControlPlane`] epoch machinery in-process callers use —
//! NeuroCoreX exposes its FPGA emulator over a UART configure/stimulate
//! protocol; this is the same idea with a production transport.
//!
//! ## Architecture
//!
//! ```text
//! accept loop ──spawns──▶ per-connection reader ──bounded queue──▶ pump ──▶ ServingEngine
//!                         (admission control,                     (sole engine owner:
//!                          frame validation)                       micro-batches ops into
//!                               │                                  run_session calls)
//!                               ▼                                        │
//!                         per-connection writer ◀──reply channels────────┘
//! ```
//!
//! * **One pump thread owns the engine.** Readers never touch it; they
//!   enqueue validated [`PumpMsg`]s on one bounded queue. The pump drains
//!   the queue into micro-batches (up to [`ServerOptions::max_batch`] ops
//!   per [`ServingEngine::run_session`] call), so concurrent sessions are
//!   folded into the engine's lane-batched datapath, and in-band
//!   `Reconfig` ops land at exact sample boundaries of the merged stream.
//! * **Admission control is per session and typed.** Each session carries
//!   a granted in-flight quota; a `SubmitSample` over quota — or arriving
//!   while the pump queue is full — is rejected immediately with
//!   [`ErrorCode::Overloaded`] and is never enqueued. Backpressure
//!   reaches the client as a frame, not as TCP stall.
//! * **One tenant's failure stays that tenant's failure.** Malformed
//!   programs are rejected per-request (`BadProgram`) via
//!   [`ControlPlane::validate`] before they reach the shared engine;
//!   protocol violations kill only the offending connection (`BadFrame`);
//!   a dead serving shard costs exactly the streams that were in flight on
//!   it (each answered with a typed [`ErrorCode::ShardLost`], safe to
//!   resubmit) while the engine's supervisor rebuilds the shard from its
//!   last connectome checkpoint; and only if recovery itself fails does
//!   the engine stop serving — the server then answers every request with
//!   a typed `Internal` error, and the process and every connection stay
//!   alive. Clients poll the supervisor through [`Frame::HealthReq`],
//!   answered from the pump's telemetry mirror without touching the
//!   engine.
//!
//! ## Epoch acks
//!
//! The pump is the engine's only epoch source, so accepted `Reconfig`s
//! are acked deterministically: the k-th program accepted in a batch gets
//! epoch `epoch_before_batch + k`, exactly what `run_session` assigns
//! when the op lands.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::datasets::Sample;

use super::connectome::Connectome;
use super::control::{ControlPlane, ReconfigProgram};
use super::serving::{ServingEngine, ServingError, SessionOp};
use super::wire::{self, ErrorCode, Frame, WireError};

/// Front-door tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerOptions {
    /// Per-session in-flight sample quota granted when a client requests 0
    /// (and the cap on what it may request).
    pub max_inflight: u32,
    /// Bound of the reader→pump queue; a full queue rejects with
    /// `Overloaded` instead of stalling readers.
    pub queue_capacity: usize,
    /// Maximum ops folded into one `run_session` call.
    pub max_batch: usize,
    /// Admission bound on a sample's timestep count.
    pub max_t_steps: u32,
    /// Frame-length cap handed to the wire codec.
    pub max_frame_len: u32,
    /// Close a connection that completes no frame for this long (the
    /// slow-loris defence): the session gets a typed
    /// [`ErrorCode::IdleTimeout`] error and the socket is closed, so a
    /// silent client cannot pin a connection thread forever.
    pub idle_timeout: Duration,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            max_inflight: 64,
            queue_capacity: 256,
            max_batch: 64,
            max_t_steps: 4096,
            max_frame_len: wire::DEFAULT_MAX_FRAME_LEN,
            idle_timeout: Duration::from_secs(300),
        }
    }
}

/// Monotonic front-door counters (snapshot via [`SpikeServer::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServerStats {
    pub connections: u64,
    pub sessions: u64,
    pub samples_served: u64,
    pub reconfigs_applied: u64,
    pub rejects_overloaded: u64,
    /// `BadSession` + `BadSample` + `BadProgram` rejections.
    pub rejects_bad: u64,
    /// Connections killed for frame-grammar violations.
    pub protocol_errors: u64,
    /// Connections closed for exceeding [`ServerOptions::idle_timeout`].
    pub idle_timeouts: u64,
    /// Engine failures observed by the pump (the engine stops serving but
    /// the server keeps answering with typed `Internal` errors).
    pub engine_failures: u64,
    /// Streams lost to a dead shard and answered with a typed
    /// [`ErrorCode::ShardLost`] (the client may resubmit; the supervisor
    /// rebuilds the shard).
    pub shard_losses: u64,
    /// Supervisor mirror: shards rebuilt from a checkpoint.
    pub recoveries: u64,
    /// Supervisor mirror: shards quarantined.
    pub quarantines: u64,
    /// Supervisor mirror: samples completed since the live recovery point.
    pub checkpoint_age: u64,
    /// Supervisor mirror: cumulative milliseconds in degraded mode.
    pub degraded_ms: u64,
    /// Integrity mirror: parity/SECDED blocks swept by the scrubber.
    pub scrubbed_blocks: u64,
    /// Integrity mirror: single-bit upsets repaired in place.
    pub integrity_corrected: u64,
    /// Integrity mirror: detected-uncorrectable words (quarantine causes).
    pub integrity_detected: u64,
}

#[derive(Default)]
struct Counters {
    connections: AtomicU64,
    sessions: AtomicU64,
    samples_served: AtomicU64,
    reconfigs_applied: AtomicU64,
    rejects_overloaded: AtomicU64,
    rejects_bad: AtomicU64,
    protocol_errors: AtomicU64,
    idle_timeouts: AtomicU64,
    engine_failures: AtomicU64,
    shard_losses: AtomicU64,
    recoveries: AtomicU64,
    quarantines: AtomicU64,
    checkpoint_age: AtomicU64,
    degraded_ms: AtomicU64,
    scrubbed_blocks: AtomicU64,
    integrity_corrected: AtomicU64,
    integrity_detected: AtomicU64,
    /// One status byte per shard (0 Healthy, 1 Quarantined, 2 Rebuilding),
    /// refreshed by the pump after every engine interaction — readers
    /// answer `HealthReq` from this mirror without touching the engine.
    shard_health: Mutex<Vec<u8>>,
    /// Detection→re-admission latency of every completed recovery (ms).
    recovery_ms: Mutex<Vec<f64>>,
}

impl Counters {
    fn bump(c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> ServerStats {
        ServerStats {
            connections: self.connections.load(Ordering::Relaxed),
            sessions: self.sessions.load(Ordering::Relaxed),
            samples_served: self.samples_served.load(Ordering::Relaxed),
            reconfigs_applied: self.reconfigs_applied.load(Ordering::Relaxed),
            rejects_overloaded: self.rejects_overloaded.load(Ordering::Relaxed),
            rejects_bad: self.rejects_bad.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            idle_timeouts: self.idle_timeouts.load(Ordering::Relaxed),
            engine_failures: self.engine_failures.load(Ordering::Relaxed),
            shard_losses: self.shard_losses.load(Ordering::Relaxed),
            recoveries: self.recoveries.load(Ordering::Relaxed),
            quarantines: self.quarantines.load(Ordering::Relaxed),
            checkpoint_age: self.checkpoint_age.load(Ordering::Relaxed),
            degraded_ms: self.degraded_ms.load(Ordering::Relaxed),
            scrubbed_blocks: self.scrubbed_blocks.load(Ordering::Relaxed),
            integrity_corrected: self.integrity_corrected.load(Ordering::Relaxed),
            integrity_detected: self.integrity_detected.load(Ordering::Relaxed),
        }
    }

    fn shard_health(&self) -> Vec<u8> {
        self.shard_health.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    fn recovery_ms(&self) -> Vec<f64> {
        self.recovery_ms.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }
}

/// Refresh the reader-visible supervision mirror from the engine (the pump
/// is the engine's sole owner; readers must never block on it).
fn mirror_health(engine: &ServingEngine, counters: &Counters) {
    counters.recoveries.store(engine.recoveries(), Ordering::Relaxed);
    counters.quarantines.store(engine.quarantines(), Ordering::Relaxed);
    counters.checkpoint_age.store(engine.checkpoint_age_samples(), Ordering::Relaxed);
    counters
        .degraded_ms
        .store(engine.degraded_duration().as_millis() as u64, Ordering::Relaxed);
    let (scrubbed, corrected, detected) = engine.integrity_counters();
    counters.scrubbed_blocks.store(scrubbed, Ordering::Relaxed);
    counters.integrity_corrected.store(corrected, Ordering::Relaxed);
    counters.integrity_detected.store(detected, Ordering::Relaxed);
    *counters.shard_health.lock().unwrap_or_else(|e| e.into_inner()) =
        engine.shard_health().iter().map(|h| *h as u8).collect();
    *counters.recovery_ms.lock().unwrap_or_else(|e| e.into_inner()) =
        engine.recovery_latencies_ms().to_vec();
}

/// Engine geometry advertised in `HelloAck` and used for reader-side
/// sample validation (captured before the engine moves into the pump).
#[derive(Debug, Clone, Copy)]
struct Geometry {
    inputs: u32,
    outputs: u32,
    cores: u16,
    lane_width: u16,
}

/// One validated client op travelling reader → pump. Carries its reply
/// channel (the connection's writer) and its session's in-flight counter,
/// which the pump decrements once the op is answered.
enum PumpMsg {
    Submit {
        session: u32,
        sample_id: u64,
        sample: Sample,
        inflight: Arc<AtomicU32>,
        reply: Sender<Frame>,
    },
    Reconfig {
        session: u32,
        request: u64,
        program: ReconfigProgram,
        inflight: Arc<AtomicU32>,
        reply: Sender<Frame>,
    },
    /// Serialize the engine's full connectome at the next batch boundary.
    Snapshot { session: u32, request: u64, inflight: Arc<AtomicU32>, reply: Sender<Frame> },
    /// Warm-swap a connectome's weights+registers into the live engine as
    /// one config epoch ([`ControlPlane::migrate`]).
    Restore {
        session: u32,
        request: u64,
        bytes: Vec<u8>,
        inflight: Arc<AtomicU32>,
        reply: Sender<Frame>,
    },
}

/// The TCP front door. Owns the accept loop, the engine pump, and (through
/// them) every connection thread; dropping or [`SpikeServer::shutdown`]ting
/// it tears the whole stack down, engine included.
pub struct SpikeServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    pump: Option<JoinHandle<()>>,
    counters: Arc<Counters>,
}

impl SpikeServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// serving `engine` in background threads. The engine moves into the
    /// pump thread — the server is its sole owner from here on.
    pub fn bind(engine: ServingEngine, addr: &str, options: ServerOptions) -> Result<SpikeServer> {
        anyhow::ensure!(options.max_inflight >= 1, "max_inflight must be positive");
        anyhow::ensure!(options.queue_capacity >= 1, "queue_capacity must be positive");
        anyhow::ensure!(options.max_batch >= 1, "max_batch must be positive");
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let geometry = Geometry {
            inputs: engine.inputs() as u32,
            outputs: engine.outputs() as u32,
            cores: engine.num_cores() as u16,
            lane_width: engine.lane_width() as u16,
        };
        let shutdown = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(Counters::default());
        let (pump_tx, pump_rx) = mpsc::sync_channel::<PumpMsg>(options.queue_capacity);
        let pump = {
            let shutdown = shutdown.clone();
            let counters = counters.clone();
            std::thread::spawn(move || pump_loop(engine, pump_rx, shutdown, counters, options))
        };
        let accept = {
            let shutdown = shutdown.clone();
            let counters = counters.clone();
            std::thread::spawn(move || {
                accept_loop(listener, pump_tx, shutdown, counters, options, geometry)
            })
        };
        Ok(SpikeServer { addr, shutdown, accept: Some(accept), pump: Some(pump), counters })
    }

    /// The bound address (resolves the actual port when bound to `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> ServerStats {
        self.counters.snapshot()
    }

    /// Supervision mirror: one status byte per shard (0 Healthy,
    /// 1 Quarantined, 2 Rebuilding) — the payload a wire `Health` frame
    /// carries, refreshed by the pump after every engine interaction.
    pub fn shard_health(&self) -> Vec<u8> {
        self.counters.shard_health()
    }

    /// Supervision mirror: detection→re-admission latency of every
    /// completed shard recovery, in milliseconds.
    pub fn recovery_latencies_ms(&self) -> Vec<f64> {
        self.counters.recovery_ms()
    }

    /// Stop accepting, close every connection, drain the pump, and shut
    /// the engine down. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.pump.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SpikeServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    pump_tx: SyncSender<PumpMsg>,
    shutdown: Arc<AtomicBool>,
    counters: Arc<Counters>,
    options: ServerOptions,
    geometry: Geometry,
) {
    // Session ids are globally unique so logs and errors stay unambiguous
    // across connections.
    let session_ids = Arc::new(AtomicU32::new(1));
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                Counters::bump(&counters.connections);
                let pump_tx = pump_tx.clone();
                let shutdown = shutdown.clone();
                let counters = counters.clone();
                let session_ids = session_ids.clone();
                conns.push(std::thread::spawn(move || {
                    connection_loop(
                        stream,
                        pump_tx,
                        shutdown,
                        counters,
                        options,
                        geometry,
                        session_ids,
                    )
                }));
                // Reap finished connection threads so a long-lived server
                // does not accumulate handles.
                conns.retain(|h| !h.is_finished());
            }
            // Non-blocking listener: poll the shutdown flag between
            // accepts (std has no accept timeout).
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    // Readers observe the flag via their read timeouts and exit; their
    // pump senders drop with them, and dropping ours lets the pump see a
    // disconnected queue even if it missed the flag.
    drop(pump_tx);
    for h in conns {
        let _ = h.join();
    }
}

/// Send a typed rejection frame (best-effort: a dead writer means the
/// connection is going away anyway).
fn reject(reply: &Sender<Frame>, code: ErrorCode, session: u32, reference: u64, message: String) {
    let _ = reply.send(Frame::Error { code, session, reference, message });
}

/// Enqueue a validated op on the pump queue, undoing its in-flight
/// reservation and answering with a typed error if the queue is full or
/// the server is shutting down.
fn enqueue_or_reject(
    pump_tx: &SyncSender<PumpMsg>,
    msg: PumpMsg,
    inflight: &Arc<AtomicU32>,
    counters: &Counters,
    reply: &Sender<Frame>,
    session: u32,
    reference: u64,
) {
    match pump_tx.try_send(msg) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) => {
            inflight.fetch_sub(1, Ordering::AcqRel);
            Counters::bump(&counters.rejects_overloaded);
            reject(
                reply,
                ErrorCode::Overloaded,
                session,
                reference,
                "server admission queue is full".to_string(),
            );
        }
        Err(TrySendError::Disconnected(_)) => {
            inflight.fetch_sub(1, Ordering::AcqRel);
            reject(
                reply,
                ErrorCode::Internal,
                session,
                reference,
                "server is shutting down".to_string(),
            );
        }
    }
}

fn connection_loop(
    stream: TcpStream,
    pump_tx: SyncSender<PumpMsg>,
    shutdown: Arc<AtomicBool>,
    counters: Arc<Counters>,
    options: ServerOptions,
    geometry: Geometry,
    session_ids: Arc<AtomicU32>,
) {
    let _ = stream.set_nodelay(true);
    // The read timeout is the shutdown/idle poll interval, not a client
    // SLA: an idle socket surfaces as WireError::Idle every 200ms and we
    // re-check the shutdown flag and the session's idle budget.
    let poll = Duration::from_millis(200).min(options.idle_timeout);
    let _ = stream.set_read_timeout(Some(poll));
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (reply_tx, reply_rx) = mpsc::channel::<Frame>();
    let writer = std::thread::spawn(move || writer_loop(write_half, reply_rx));
    let mut reader = BufReader::new(stream);
    // Connection-local sessions: id → (in-flight counter, granted quota).
    let mut sessions: HashMap<u32, (Arc<AtomicU32>, u32)> = HashMap::new();
    let mut hello_done = false;
    // Slow-loris defence: a client that completes no frame for
    // `idle_timeout` is cut off with a typed `IdleTimeout` error. The
    // clock resets on every completed frame, so a chatty-but-slow client
    // is fine; only a silent one trips it.
    let mut last_frame = std::time::Instant::now();
    let fatal: Option<WireError> = loop {
        let frame = match wire::read_frame(&mut reader, options.max_frame_len) {
            Ok(Some(f)) => {
                last_frame = std::time::Instant::now();
                f
            }
            Ok(None) => break None, // clean EOF
            Err(WireError::Idle) => {
                if shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                if last_frame.elapsed() >= options.idle_timeout {
                    break Some(WireError::Idle);
                }
                continue;
            }
            Err(e) => break Some(e),
        };
        match frame {
            Frame::Hello { version } => {
                if version != wire::VERSION {
                    break Some(WireError::BadValue("unsupported protocol version"));
                }
                hello_done = true;
                let _ = reply_tx.send(Frame::HelloAck {
                    version: wire::VERSION,
                    inputs: geometry.inputs,
                    outputs: geometry.outputs,
                    cores: geometry.cores,
                    lane_width: geometry.lane_width,
                });
            }
            _ if !hello_done => break Some(WireError::BadValue("first frame must be Hello")),
            Frame::OpenSession { max_inflight } => {
                let granted = if max_inflight == 0 {
                    options.max_inflight
                } else {
                    max_inflight.min(options.max_inflight)
                };
                let id = session_ids.fetch_add(1, Ordering::Relaxed);
                sessions.insert(id, (Arc::new(AtomicU32::new(0)), granted));
                Counters::bump(&counters.sessions);
                let _ = reply_tx.send(Frame::SessionOpened { session: id, max_inflight: granted });
            }
            Frame::SubmitSample { session, sample, t_steps, inputs, spikes } => {
                let Some((inflight, quota)) = sessions.get(&session) else {
                    Counters::bump(&counters.rejects_bad);
                    reject(
                        &reply_tx,
                        ErrorCode::BadSession,
                        session,
                        sample,
                        format!("session {session} not open on this connection"),
                    );
                    continue;
                };
                if inputs != geometry.inputs || t_steps > options.max_t_steps {
                    Counters::bump(&counters.rejects_bad);
                    reject(
                        &reply_tx,
                        ErrorCode::BadSample,
                        session,
                        sample,
                        format!(
                            "sample geometry {inputs}x{t_steps} outside engine bounds \
                             ({}x<= {})",
                            geometry.inputs, options.max_t_steps
                        ),
                    );
                    continue;
                }
                // Admission control: the session's quota first (this reader
                // is the counter's only incrementer, so load+add is safe),
                // then the shared pump queue.
                if inflight.load(Ordering::Acquire) >= *quota {
                    Counters::bump(&counters.rejects_overloaded);
                    reject(
                        &reply_tx,
                        ErrorCode::Overloaded,
                        session,
                        sample,
                        format!("session {session} already has {quota} samples in flight"),
                    );
                    continue;
                }
                // The unpack geometry is attacker-controlled: a hostile
                // t_steps×inputs product is rejected here with a typed
                // error instead of feeding an unchecked multiply.
                let parsed = match wire::sample_from_submit(t_steps, inputs, &spikes) {
                    Ok(s) => s,
                    Err(e) => {
                        Counters::bump(&counters.rejects_bad);
                        reject(&reply_tx, ErrorCode::BadSample, session, sample, e.to_string());
                        continue;
                    }
                };
                inflight.fetch_add(1, Ordering::AcqRel);
                let msg = PumpMsg::Submit {
                    session,
                    sample_id: sample,
                    sample: parsed,
                    inflight: inflight.clone(),
                    reply: reply_tx.clone(),
                };
                match pump_tx.try_send(msg) {
                    Ok(()) => {}
                    Err(TrySendError::Full(_)) => {
                        inflight.fetch_sub(1, Ordering::AcqRel);
                        Counters::bump(&counters.rejects_overloaded);
                        reject(
                            &reply_tx,
                            ErrorCode::Overloaded,
                            session,
                            sample,
                            "server admission queue is full".to_string(),
                        );
                    }
                    Err(TrySendError::Disconnected(_)) => {
                        inflight.fetch_sub(1, Ordering::AcqRel);
                        reject(
                            &reply_tx,
                            ErrorCode::Internal,
                            session,
                            sample,
                            "server is shutting down".to_string(),
                        );
                    }
                }
            }
            Frame::Reconfig { session, request, cfg, weights } => {
                let Some((inflight, quota)) = sessions.get(&session) else {
                    Counters::bump(&counters.rejects_bad);
                    reject(
                        &reply_tx,
                        ErrorCode::BadSession,
                        session,
                        request,
                        format!("session {session} not open on this connection"),
                    );
                    continue;
                };
                // Reconfigs occupy an in-flight slot too: one uniform bound
                // on what a session may have queued.
                if inflight.load(Ordering::Acquire) >= *quota {
                    Counters::bump(&counters.rejects_overloaded);
                    reject(
                        &reply_tx,
                        ErrorCode::Overloaded,
                        session,
                        request,
                        format!("session {session} already has {quota} requests in flight"),
                    );
                    continue;
                }
                inflight.fetch_add(1, Ordering::AcqRel);
                let msg = PumpMsg::Reconfig {
                    session,
                    request,
                    program: wire::program_from_wire(&cfg, &weights),
                    inflight: inflight.clone(),
                    reply: reply_tx.clone(),
                };
                match pump_tx.try_send(msg) {
                    Ok(()) => {}
                    Err(TrySendError::Full(_)) => {
                        inflight.fetch_sub(1, Ordering::AcqRel);
                        Counters::bump(&counters.rejects_overloaded);
                        reject(
                            &reply_tx,
                            ErrorCode::Overloaded,
                            session,
                            request,
                            "server admission queue is full".to_string(),
                        );
                    }
                    Err(TrySendError::Disconnected(_)) => {
                        inflight.fetch_sub(1, Ordering::AcqRel);
                        reject(
                            &reply_tx,
                            ErrorCode::Internal,
                            session,
                            request,
                            "server is shutting down".to_string(),
                        );
                    }
                }
            }
            Frame::Snapshot { session, request } => {
                let Some((inflight, quota)) = sessions.get(&session) else {
                    Counters::bump(&counters.rejects_bad);
                    reject(
                        &reply_tx,
                        ErrorCode::BadSession,
                        session,
                        request,
                        format!("session {session} not open on this connection"),
                    );
                    continue;
                };
                if inflight.load(Ordering::Acquire) >= *quota {
                    Counters::bump(&counters.rejects_overloaded);
                    reject(
                        &reply_tx,
                        ErrorCode::Overloaded,
                        session,
                        request,
                        format!("session {session} already has {quota} requests in flight"),
                    );
                    continue;
                }
                inflight.fetch_add(1, Ordering::AcqRel);
                let msg = PumpMsg::Snapshot {
                    session,
                    request,
                    inflight: inflight.clone(),
                    reply: reply_tx.clone(),
                };
                enqueue_or_reject(&pump_tx, msg, inflight, &counters, &reply_tx, session, request);
            }
            Frame::Restore { session, request, bytes } => {
                let Some((inflight, quota)) = sessions.get(&session) else {
                    Counters::bump(&counters.rejects_bad);
                    reject(
                        &reply_tx,
                        ErrorCode::BadSession,
                        session,
                        request,
                        format!("session {session} not open on this connection"),
                    );
                    continue;
                };
                if inflight.load(Ordering::Acquire) >= *quota {
                    Counters::bump(&counters.rejects_overloaded);
                    reject(
                        &reply_tx,
                        ErrorCode::Overloaded,
                        session,
                        request,
                        format!("session {session} already has {quota} requests in flight"),
                    );
                    continue;
                }
                inflight.fetch_add(1, Ordering::AcqRel);
                let msg = PumpMsg::Restore {
                    session,
                    request,
                    bytes,
                    inflight: inflight.clone(),
                    reply: reply_tx.clone(),
                };
                enqueue_or_reject(&pump_tx, msg, inflight, &counters, &reply_tx, session, request);
            }
            Frame::HealthReq { request } => {
                // Answered from the pump's telemetry mirror — no session
                // needed, never blocks on the engine, and stays accurate
                // even while the engine is mid-recovery.
                let shards = counters.shard_health();
                let _ = reply_tx.send(Frame::Health {
                    request,
                    degraded: shards.iter().any(|&s| s != 0),
                    recoveries: counters.recoveries.load(Ordering::Relaxed),
                    quarantines: counters.quarantines.load(Ordering::Relaxed),
                    checkpoint_age: counters.checkpoint_age.load(Ordering::Relaxed),
                    scrubbed_blocks: counters.scrubbed_blocks.load(Ordering::Relaxed),
                    corrected: counters.integrity_corrected.load(Ordering::Relaxed),
                    detected: counters.integrity_detected.load(Ordering::Relaxed),
                    shards,
                });
            }
            // Server→client frames arriving from a client violate the
            // protocol.
            Frame::HelloAck { .. }
            | Frame::SessionOpened { .. }
            | Frame::Result { .. }
            | Frame::ReconfigAck { .. }
            | Frame::SnapshotData { .. }
            | Frame::RestoreAck { .. }
            | Frame::Health { .. }
            | Frame::Error { .. } => {
                break Some(WireError::BadValue("client sent a server-side frame"));
            }
        }
    };
    if let Some(e) = fatal {
        // Protocol violations kill this connection only: send the typed
        // error, then close (the writer drains and exits when the last
        // reply sender — possibly held by the pump for in-flight ops —
        // drops). An idle expiry gets its own code so clients can tell a
        // timeout from a grammar violation.
        let (code, message) = match e {
            WireError::Idle => {
                Counters::bump(&counters.idle_timeouts);
                (
                    ErrorCode::IdleTimeout,
                    format!(
                        "connection idle for longer than {:?}; closing",
                        options.idle_timeout
                    ),
                )
            }
            e => {
                Counters::bump(&counters.protocol_errors);
                (ErrorCode::BadFrame, e.to_string())
            }
        };
        reject(&reply_tx, code, 0, 0, message);
    }
    drop(reply_tx);
    let _ = writer.join();
}

/// Connection writer: serializes reply frames onto the socket, batching
/// whatever is queued behind one flush. Never blocks the pump (the reply
/// channel is unbounded and bounded in practice by the admission quotas);
/// after a write error it keeps draining and discarding so senders are
/// never wedged on a dead peer.
fn writer_loop(stream: TcpStream, rx: Receiver<Frame>) {
    let mut w = BufWriter::new(stream);
    let mut dead = false;
    while let Ok(frame) = rx.recv() {
        if !dead && wire::write_frame(&mut w, &frame).is_err() {
            dead = true;
        }
        while let Ok(f) = rx.try_recv() {
            if !dead && wire::write_frame(&mut w, &f).is_err() {
                dead = true;
            }
        }
        if !dead && w.flush().is_err() {
            dead = true;
        }
    }
}

/// What one batch slot owes the client: a `Result` for a submit, a
/// `ReconfigAck` (epoch pre-assigned — the pump is the only epoch source)
/// for an accepted program.
enum Slot {
    Sample { index: usize },
    Ack { session: u32, request: u64, epoch: u64, inflight: Arc<AtomicU32>, reply: Sender<Frame> },
}

/// The engine pump: the sole owner of the [`ServingEngine`]. Drains the
/// reader queue into micro-batches, folds them into `run_session` calls
/// (submits and in-band reconfigs in arrival order), and distributes
/// results/acks/errors back onto each connection's reply channel.
fn pump_loop(
    mut engine: ServingEngine,
    rx: Receiver<PumpMsg>,
    shutdown: Arc<AtomicBool>,
    counters: Arc<Counters>,
    options: ServerOptions,
) {
    let control = engine.control_plane();
    mirror_health(&engine, &counters);
    // Once the engine fails (a failed shard rebuild, a wedged teardown) it
    // stops serving, but the pump keeps answering every request with a
    // typed Internal error — the process and all other tenants'
    // connections stay alive. A plain shard death never lands here: the
    // supervisor heals it and only the lost streams see a typed ShardLost.
    let mut engine_dead: Option<String> = None;
    loop {
        let first = match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(m) => m,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        let mut batch = vec![first];
        while batch.len() < options.max_batch {
            match rx.try_recv() {
                Ok(m) => batch.push(m),
                Err(_) => break,
            }
        }
        // Snapshot/Restore are batch-boundary control ops: everything
        // queued ahead of one runs to completion first (`run_session` is
        // synchronous, so the pipeline is quiesced — `submitted ==
        // completed` — when the op executes), then the rest of the batch
        // proceeds. No queued stream is drained or lost.
        let mut pending: Vec<PumpMsg> = Vec::new();
        for op in batch {
            match op {
                PumpMsg::Submit { .. } | PumpMsg::Reconfig { .. } => pending.push(op),
                PumpMsg::Snapshot { session, request, inflight, reply } => {
                    run_slots(
                        &mut engine,
                        &control,
                        &counters,
                        &mut engine_dead,
                        std::mem::take(&mut pending),
                    );
                    if let Some(msg) = &engine_dead {
                        reject(&reply, ErrorCode::Internal, session, request, msg.clone());
                    } else {
                        match engine.snapshot() {
                            Ok(c) => {
                                let _ = reply.send(Frame::SnapshotData {
                                    session,
                                    request,
                                    bytes: c.encode(),
                                });
                            }
                            Err(e) => {
                                Counters::bump(&counters.engine_failures);
                                let msg = format!("snapshot failed: {e:#}");
                                engine_dead = Some(msg.clone());
                                reject(&reply, ErrorCode::Internal, session, request, msg);
                            }
                        }
                    }
                    inflight.fetch_sub(1, Ordering::AcqRel);
                }
                PumpMsg::Restore { session, request, bytes, inflight, reply } => {
                    run_slots(
                        &mut engine,
                        &control,
                        &counters,
                        &mut engine_dead,
                        std::mem::take(&mut pending),
                    );
                    if let Some(msg) = &engine_dead {
                        reject(&reply, ErrorCode::Internal, session, request, msg.clone());
                    } else {
                        // Decode and migrate both reject with typed errors;
                        // a bad snapshot is the client's problem, not the
                        // engine's — it keeps serving.
                        let outcome = Connectome::decode(&bytes)
                            .map_err(|e| e.to_string())
                            .and_then(|c| control.migrate(&c).map_err(|e| e.to_string()));
                        match outcome {
                            Ok(epoch) => {
                                Counters::bump(&counters.reconfigs_applied);
                                let _ = reply.send(Frame::RestoreAck { session, request, epoch });
                            }
                            Err(msg) => {
                                Counters::bump(&counters.rejects_bad);
                                reject(&reply, ErrorCode::BadProgram, session, request, msg);
                            }
                        }
                    }
                    inflight.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }
        run_slots(&mut engine, &control, &counters, &mut engine_dead, pending);
    }
    // Engine drops here: its Drop joins every shard thread.
}

/// Run one micro-batch of data-path ops (submits + in-band reconfigs)
/// through the engine and answer every slot. Factored out of the pump loop
/// so snapshot/restore control ops can flush the queue ahead of
/// themselves.
fn run_slots(
    engine: &mut ServingEngine,
    control: &ControlPlane,
    counters: &Counters,
    engine_dead: &mut Option<String>,
    batch: Vec<PumpMsg>,
) {
    if batch.is_empty() {
        return;
    }
    if let Some(msg) = engine_dead {
        for op in batch {
            let (reply, inflight, session, reference) = match &op {
                PumpMsg::Submit { reply, inflight, session, sample_id, .. } => {
                    (reply.clone(), inflight.clone(), *session, *sample_id)
                }
                PumpMsg::Reconfig { reply, inflight, session, request, .. }
                | PumpMsg::Snapshot { reply, inflight, session, request, .. }
                | PumpMsg::Restore { reply, inflight, session, request, .. } => {
                    (reply.clone(), inflight.clone(), *session, *request)
                }
            };
            reject(&reply, ErrorCode::Internal, session, reference, msg.clone());
            inflight.fetch_sub(1, Ordering::AcqRel);
        }
        return;
    }
    // Decompose the batch: samples (kept alive for the borrow in
    // SessionOp::Submit), per-submit reply metadata, and the op plan
    // in arrival order. Malformed programs are rejected here,
    // per-tenant, without failing anyone else's batch.
    let mut samples: Vec<Sample> = Vec::new();
    let mut submit_meta: Vec<(u32, u64, Arc<AtomicU32>, Sender<Frame>)> = Vec::new();
    let mut programs: Vec<ReconfigProgram> = Vec::new();
    let mut plan: Vec<Slot> = Vec::new();
    let epoch_before = control.epoch();
    let mut accepted_programs = 0u64;
    for op in batch {
        match op {
            PumpMsg::Submit { session, sample_id, sample, inflight, reply } => {
                samples.push(sample);
                submit_meta.push((session, sample_id, inflight, reply));
                plan.push(Slot::Sample { index: samples.len() - 1 });
            }
            PumpMsg::Reconfig { session, request, program, inflight, reply } => {
                match control.validate(&program) {
                    Ok(()) => {
                        accepted_programs += 1;
                        programs.push(program);
                        plan.push(Slot::Ack {
                            session,
                            request,
                            epoch: epoch_before + accepted_programs,
                            inflight,
                            reply,
                        });
                    }
                    Err(e) => {
                        Counters::bump(&counters.rejects_bad);
                        reject(&reply, ErrorCode::BadProgram, session, request, e.to_string());
                        inflight.fetch_sub(1, Ordering::AcqRel);
                    }
                }
            }
            // Control ops never reach the data path (the pump executes
            // them at flush boundaries); answer defensively rather than
            // panic if one ever does.
            PumpMsg::Snapshot { session, request, inflight, reply }
            | PumpMsg::Restore { session, request, inflight, reply, .. } => {
                reject(
                    &reply,
                    ErrorCode::Internal,
                    session,
                    request,
                    "control op routed to the data path".to_string(),
                );
                inflight.fetch_sub(1, Ordering::AcqRel);
            }
        }
    }
    let (plan, ops) = build_ops(plan, &samples, programs);
    if ops.is_empty() {
        return;
    }
    match engine.run_session_outcomes(&ops) {
        Ok(outcomes) => {
            debug_assert_eq!(outcomes.len(), submit_meta.len(), "one outcome per submit");
            let mut outcome_iter = outcomes.into_iter();
            for slot in plan {
                match slot {
                    Slot::Sample { index } => {
                        let (session, sample_id, inflight, reply) = &submit_meta[index];
                        match outcome_iter.next() {
                            Some(Ok(r)) => {
                                Counters::bump(&counters.samples_served);
                                let _ = reply.send(Frame::Result {
                                    session: *session,
                                    sample: *sample_id,
                                    epoch: r.epoch,
                                    prediction: r.prediction as u32,
                                    spikes_total: r.spikes_total,
                                    counts: r.counts,
                                });
                            }
                            Some(Err(e)) => {
                                // A lost shard costs exactly its in-flight
                                // streams; the supervisor has already
                                // rebuilt it by the time we answer, so the
                                // client's retry lands on a healthy engine.
                                let code = match &e {
                                    ServingError::ShardLost { .. } => {
                                        Counters::bump(&counters.shard_losses);
                                        ErrorCode::ShardLost
                                    }
                                    _ => ErrorCode::Internal,
                                };
                                reject(reply, code, *session, *sample_id, e.to_string());
                            }
                            None => {
                                reject(
                                    reply,
                                    ErrorCode::Internal,
                                    *session,
                                    *sample_id,
                                    "pump bookkeeping mismatch: no outcome for this submit"
                                        .to_string(),
                                );
                            }
                        }
                        inflight.fetch_sub(1, Ordering::AcqRel);
                    }
                    Slot::Ack { session, request, epoch, inflight, reply } => {
                        Counters::bump(&counters.reconfigs_applied);
                        let _ = reply.send(Frame::ReconfigAck { session, request, epoch });
                        inflight.fetch_sub(1, Ordering::AcqRel);
                    }
                }
            }
            mirror_health(engine, counters);
        }
        Err(e) => {
            Counters::bump(&counters.engine_failures);
            let msg = format!("serving engine failed: {e:#}");
            *engine_dead = Some(msg.clone());
            for slot in plan {
                match slot {
                    Slot::Sample { index } => {
                        let (session, sample_id, inflight, reply) = &submit_meta[index];
                        reject(reply, ErrorCode::Internal, *session, *sample_id, msg.clone());
                        inflight.fetch_sub(1, Ordering::AcqRel);
                    }
                    Slot::Ack { session, request, inflight, reply, .. } => {
                        reject(&reply, ErrorCode::Internal, session, request, msg.clone());
                        inflight.fetch_sub(1, Ordering::AcqRel);
                    }
                }
            }
            mirror_health(engine, counters);
        }
    }
}

/// Pair each planned slot with its engine op. An `Ack` slot without a
/// matching validated program is pump bookkeeping gone wrong; it used to
/// panic the pump thread — the engine's sole owner, so one bad batch took
/// the whole front door down. Now the offending slot alone is answered
/// with a typed `Internal` error and dropped from the plan, and the pump
/// keeps serving every other tenant.
fn build_ops<'a>(
    plan: Vec<Slot>,
    samples: &'a [Sample],
    programs: Vec<ReconfigProgram>,
) -> (Vec<Slot>, Vec<SessionOp<'a>>) {
    let mut program_iter = programs.into_iter();
    let mut kept: Vec<Slot> = Vec::with_capacity(plan.len());
    let mut ops: Vec<SessionOp<'a>> = Vec::with_capacity(plan.len());
    for slot in plan {
        match slot {
            Slot::Sample { index } => {
                ops.push(SessionOp::Submit(&samples[index]));
                kept.push(Slot::Sample { index });
            }
            Slot::Ack { session, request, epoch, inflight, reply } => match program_iter.next() {
                Some(program) => {
                    ops.push(SessionOp::Reconfig(program));
                    kept.push(Slot::Ack { session, request, epoch, inflight, reply });
                }
                None => {
                    reject(
                        &reply,
                        ErrorCode::Internal,
                        session,
                        request,
                        "reconfig ack bookkeeping mismatch: no validated program for this ack"
                            .to_string(),
                    );
                    inflight.fetch_sub(1, Ordering::AcqRel);
                }
            },
        }
    }
    (kept, ops)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ack_slot(session: u32, request: u64) -> (Slot, Arc<AtomicU32>, Receiver<Frame>) {
        let (reply_tx, reply_rx) = mpsc::channel::<Frame>();
        let inflight = Arc::new(AtomicU32::new(1));
        let slot = Slot::Ack { session, request, epoch: 1, inflight: inflight.clone(), reply: reply_tx };
        (slot, inflight, reply_rx)
    }

    /// Regression: an `Ack` slot with no matching validated program used to
    /// panic the pump thread via `.expect("one program per ack slot")` —
    /// and the pump is the engine's sole owner, so that panic took the
    /// whole front door down. The mismatch must now fail only the
    /// offending session with a typed `Internal` error.
    #[test]
    fn ack_slot_without_program_fails_session_not_pump() {
        let (slot, inflight, reply_rx) = ack_slot(7, 99);
        let samples: Vec<Sample> = Vec::new();
        let (kept, ops) = build_ops(vec![slot], &samples, Vec::new());
        assert!(kept.is_empty());
        assert!(ops.is_empty());
        assert_eq!(inflight.load(Ordering::SeqCst), 0, "in-flight slot must be released");
        match reply_rx.try_recv().expect("offending session must get a typed error") {
            Frame::Error { code, session, reference, message } => {
                assert_eq!(code, ErrorCode::Internal);
                assert_eq!((session, reference), (7, 99));
                assert!(message.contains("bookkeeping"), "{message}");
            }
            f => panic!("expected Error frame, got {}", f.name()),
        }
    }

    /// A mismatched ack in the middle of a batch must not disturb sibling
    /// slots: every sample and every matched ack still runs.
    #[test]
    fn mismatched_ack_keeps_sibling_slots() {
        let sample = Sample { spikes: vec![0; 4], t_steps: 1, inputs: 4, label: 0 };
        let samples = vec![sample.clone(), sample];
        let (matched, matched_inflight, matched_rx) = ack_slot(1, 10);
        let (orphan, orphan_inflight, orphan_rx) = ack_slot(2, 20);
        let plan = vec![Slot::Sample { index: 0 }, matched, orphan, Slot::Sample { index: 1 }];
        let programs = vec![ReconfigProgram::new()];
        let (kept, ops) = build_ops(plan, &samples, programs);
        assert_eq!(kept.len(), 3, "both samples and the matched ack survive");
        assert_eq!(ops.len(), 3);
        assert!(matches!(ops[0], SessionOp::Submit(_)));
        assert!(matches!(ops[1], SessionOp::Reconfig(_)));
        assert!(matches!(ops[2], SessionOp::Submit(_)));
        // The matched ack is untouched; the orphan alone was answered.
        assert_eq!(matched_inflight.load(Ordering::SeqCst), 1);
        assert!(matched_rx.try_recv().is_err());
        assert_eq!(orphan_inflight.load(Ordering::SeqCst), 0);
        assert!(matches!(orphan_rx.try_recv(), Ok(Frame::Error { .. })));
    }
}
