//! Request-path telemetry: latency percentiles, throughput, activity and
//! power accounting — what the §IV software stack reports back to the
//! application ("visualize hardware output" plus the performance numbers
//! the paper's evaluation tables are built from). Also carries the AXI
//! ledger ([`BusStats`]) of the serving path it observed, so one summary
//! line reports data *and* control-plane traffic.

use std::time::{Duration, Instant};

use crate::hdl::ActivityStats;
use crate::util::stats;

use super::interface::BusStats;

#[derive(Debug, Default, Clone)]
pub struct Telemetry {
    latencies_us: Vec<f64>,
    pub activity: ActivityStats,
    pub requests: u64,
    pub correct: u64,
    /// Snapshot of the serving path's AXI ledger (cfg/wt control beats +
    /// spk data beats) — set via [`Telemetry::record_bus`].
    pub bus: BusStats,
    /// Highest `StreamResult::epoch` observed + 1 — an upper bound on the
    /// number of distinct configs that served traffic in this window
    /// (epochs that were assigned but never served a sample still count).
    pub reconfigs: u64,
    /// Requests turned away by admission control (the front door's typed
    /// `Overloaded` rejections). Not counted in [`Telemetry::requests`],
    /// so throughput and latency describe served traffic only.
    pub rejects: u64,
    /// Streams lost to a dead serving shard (typed `ShardLost` errors —
    /// retryable; not counted in [`Telemetry::requests`]).
    pub shard_losses: u64,
    /// Supervisor mirror: shards rebuilt from a connectome checkpoint.
    pub recoveries: u64,
    /// Supervisor mirror: shards quarantined (≥ recoveries; the excess is
    /// failed rebuilds).
    pub quarantines: u64,
    /// Supervisor mirror: samples completed since the live recovery point
    /// was fenced (the replay distance a rebuild would incur right now).
    pub checkpoint_age_samples: u64,
    /// Supervisor mirror: cumulative wall-clock spent in degraded mode
    /// (one or more shards not healthy), in milliseconds.
    pub degraded_ms: u64,
    /// Integrity mirror: parity/SECDED blocks swept by the background
    /// scrubber across all shards.
    pub scrubbed_blocks: u64,
    /// Integrity mirror: single-bit upsets repaired in place (SECDED).
    pub integrity_corrected: u64,
    /// Integrity mirror: detected-uncorrectable words — each one fed the
    /// supervisor a quarantine cause.
    pub integrity_detected: u64,
    started: Option<Instant>,
    elapsed: Duration,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.elapsed += t0.elapsed();
        }
    }

    pub fn record(&mut self, latency: Duration, stats: &ActivityStats, correct: Option<bool>) {
        self.latencies_us.push(latency.as_secs_f64() * 1e6);
        self.activity.add(stats);
        self.requests += 1;
        if correct == Some(true) {
            self.correct += 1;
        }
    }

    /// Count one admission-control rejection (`Overloaded`).
    pub fn record_reject(&mut self) {
        self.rejects += 1;
    }

    /// Count one stream lost to a dead shard (typed `ShardLost`).
    pub fn record_shard_loss(&mut self) {
        self.shard_losses += 1;
    }

    /// Adopt the engine/server supervision counters so recovery shows up
    /// in the same summary line as the traffic it disturbed.
    pub fn record_supervision(
        &mut self,
        recoveries: u64,
        quarantines: u64,
        checkpoint_age_samples: u64,
        degraded_ms: u64,
    ) {
        self.recoveries = recoveries;
        self.quarantines = quarantines;
        self.checkpoint_age_samples = checkpoint_age_samples;
        self.degraded_ms = degraded_ms;
    }

    /// Adopt the engine's memory-integrity ledger (scrubbed blocks,
    /// in-place corrections, detected-uncorrectable words) so silent-data-
    /// corruption defense is visible in the same summary as the traffic.
    pub fn record_integrity(&mut self, scrubbed_blocks: u64, corrected: u64, detected: u64) {
        self.scrubbed_blocks = scrubbed_blocks;
        self.integrity_corrected = corrected;
        self.integrity_detected = detected;
    }

    /// Rejected fraction of all requests that reached the front door.
    pub fn reject_rate(&self) -> f64 {
        let offered = self.requests + self.rejects;
        if offered == 0 {
            0.0
        } else {
            self.rejects as f64 / offered as f64
        }
    }

    /// Adopt the serving path's AXI ledger so [`Telemetry::summary`]
    /// reports bus occupancy next to the request metrics.
    pub fn record_bus(&mut self, bus: BusStats) {
        self.bus = bus;
    }

    /// Note that a sample was served under config `epoch` (see
    /// [`Telemetry::reconfigs`] for the exact counting semantics).
    pub fn record_epoch(&mut self, epoch: u64) {
        self.reconfigs = self.reconfigs.max(epoch + 1);
    }

    pub fn accuracy(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.correct as f64 / self.requests as f64
        }
    }

    pub fn throughput_rps(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }

    pub fn latency_us(&self, pct: f64) -> f64 {
        stats::percentile(&self.latencies_us, pct)
    }

    pub fn mean_latency_us(&self) -> f64 {
        stats::mean(&self.latencies_us)
    }

    /// One-line ops summary (the CLI's serving report). Includes the AXI
    /// ledger when one was recorded, so cfg/wt reconfiguration beats show
    /// up next to the data traffic they share the bus with.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} acc={:.1}% thr={:.1}/s lat(mean/p50/p99)={:.0}/{:.0}/{:.0}us spikes={} gating={:.0}%",
            self.requests,
            100.0 * self.accuracy(),
            self.throughput_rps(),
            self.mean_latency_us(),
            self.latency_us(50.0),
            self.latency_us(99.0),
            self.activity.spikes,
            100.0 * self.activity.gating_ratio(),
        );
        if self.bus.beats() > 0 {
            s.push_str(&format!(
                " bus={}b (cfg={} wt={})",
                self.bus.beats(),
                self.bus.cfg_writes,
                self.bus.wt_writes
            ));
        }
        if self.reconfigs > 1 {
            s.push_str(&format!(" epochs={}", self.reconfigs));
        }
        if self.rejects > 0 {
            s.push_str(&format!(" rejects={} ({:.1}%)", self.rejects, 100.0 * self.reject_rate()));
        }
        if self.shard_losses > 0 {
            s.push_str(&format!(" shard_losses={}", self.shard_losses));
        }
        if self.quarantines > 0 {
            s.push_str(&format!(
                " recoveries={}/{} degraded={}ms ckpt_age={}",
                self.recoveries, self.quarantines, self.degraded_ms, self.checkpoint_age_samples
            ));
        }
        if self.scrubbed_blocks > 0 || self.integrity_detected > 0 {
            s.push_str(&format!(
                " scrub={}blk corrected={} detected={}",
                self.scrubbed_blocks, self.integrity_corrected, self.integrity_detected
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarises() {
        let mut t = Telemetry::new();
        t.start();
        for i in 0..10 {
            t.record(
                Duration::from_micros(100 + i * 10),
                &ActivityStats { spikes: 5, neuron_updates: 50, ..Default::default() },
                Some(i % 2 == 0),
            );
        }
        t.stop();
        assert_eq!(t.requests, 10);
        assert_eq!(t.accuracy(), 0.5);
        assert!(t.latency_us(50.0) >= 100.0);
        assert!(t.throughput_rps() > 0.0);
        assert!(t.summary().contains("requests=10"));
        assert_eq!(t.activity.spikes, 50);
    }

    #[test]
    fn empty_telemetry_is_safe() {
        let t = Telemetry::new();
        assert_eq!(t.accuracy(), 0.0);
        assert_eq!(t.throughput_rps(), 0.0);
        assert_eq!(t.latency_us(99.0), 0.0);
        assert!(!t.summary().contains("bus="), "no ledger recorded, none reported");
    }

    #[test]
    fn bus_and_epochs_surface_in_summary() {
        let mut t = Telemetry::new();
        t.record_bus(BusStats { cfg_writes: 12, wt_writes: 3, spk_in_events: 5, spk_out_events: 0 });
        t.record_epoch(0);
        t.record_epoch(2);
        t.record_epoch(1);
        let s = t.summary();
        assert!(s.contains("bus=20b (cfg=12 wt=3)"), "{s}");
        assert!(s.contains("epochs=3"), "{s}");
        assert_eq!(t.reconfigs, 3);
    }

    #[test]
    fn rejects_surface_in_summary_and_rate() {
        let mut t = Telemetry::new();
        assert_eq!(t.reject_rate(), 0.0, "no offered load, no rate");
        for _ in 0..3 {
            t.record(Duration::from_micros(100), &ActivityStats::default(), None);
        }
        t.record_reject();
        assert_eq!(t.rejects, 1);
        assert_eq!(t.requests, 3, "rejects are not served requests");
        assert!((t.reject_rate() - 0.25).abs() < 1e-12);
        assert!(t.summary().contains("rejects=1 (25.0%)"), "{}", t.summary());
    }

    #[test]
    fn supervision_counters_surface_in_summary() {
        // Mirrors the reject-rate accounting test: losses and recovery
        // counters are separate ledgers from served requests, and they
        // only appear in the summary once something actually happened.
        let mut t = Telemetry::new();
        assert!(!t.summary().contains("recoveries="), "quiet engine, quiet summary");
        assert!(!t.summary().contains("shard_losses="));
        for _ in 0..4 {
            t.record(Duration::from_micros(100), &ActivityStats::default(), None);
        }
        t.record_shard_loss();
        t.record_shard_loss();
        t.record_supervision(2, 3, 17, 250);
        assert_eq!(t.requests, 4, "lost streams are not served requests");
        assert_eq!(t.shard_losses, 2);
        assert_eq!((t.recoveries, t.quarantines), (2, 3));
        assert_eq!(t.checkpoint_age_samples, 17);
        assert_eq!(t.degraded_ms, 250);
        let s = t.summary();
        assert!(s.contains("shard_losses=2"), "{s}");
        assert!(s.contains("recoveries=2/3 degraded=250ms ckpt_age=17"), "{s}");
    }

    #[test]
    fn integrity_counters_surface_in_summary() {
        let mut t = Telemetry::new();
        assert!(!t.summary().contains("scrub="), "integrity off, no segment");
        t.record_integrity(4096, 2, 1);
        assert_eq!(t.scrubbed_blocks, 4096);
        assert_eq!((t.integrity_corrected, t.integrity_detected), (2, 1));
        let s = t.summary();
        assert!(s.contains("scrub=4096blk corrected=2 detected=1"), "{s}");
    }
}
