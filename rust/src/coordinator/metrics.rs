//! Request-path telemetry: latency percentiles, throughput, activity and
//! power accounting — what the §IV software stack reports back to the
//! application ("visualize hardware output" plus the performance numbers
//! the paper's evaluation tables are built from).

use std::time::{Duration, Instant};

use crate::hdl::ActivityStats;
use crate::util::stats;

#[derive(Debug, Default, Clone)]
pub struct Telemetry {
    latencies_us: Vec<f64>,
    pub activity: ActivityStats,
    pub requests: u64,
    pub correct: u64,
    started: Option<Instant>,
    elapsed: Duration,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.elapsed += t0.elapsed();
        }
    }

    pub fn record(&mut self, latency: Duration, stats: &ActivityStats, correct: Option<bool>) {
        self.latencies_us.push(latency.as_secs_f64() * 1e6);
        self.activity.add(stats);
        self.requests += 1;
        if correct == Some(true) {
            self.correct += 1;
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.correct as f64 / self.requests as f64
        }
    }

    pub fn throughput_rps(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }

    pub fn latency_us(&self, pct: f64) -> f64 {
        stats::percentile(&self.latencies_us, pct)
    }

    pub fn mean_latency_us(&self) -> f64 {
        stats::mean(&self.latencies_us)
    }

    /// One-line ops summary (the CLI's serving report).
    pub fn summary(&self) -> String {
        format!(
            "requests={} acc={:.1}% thr={:.1}/s lat(mean/p50/p99)={:.0}/{:.0}/{:.0}us spikes={} gating={:.0}%",
            self.requests,
            100.0 * self.accuracy(),
            self.throughput_rps(),
            self.mean_latency_us(),
            self.latency_us(50.0),
            self.latency_us(99.0),
            self.activity.spikes,
            100.0 * self.activity.gating_ratio(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarises() {
        let mut t = Telemetry::new();
        t.start();
        for i in 0..10 {
            t.record(
                Duration::from_micros(100 + i * 10),
                &ActivityStats { spikes: 5, neuron_updates: 50, ..Default::default() },
                Some(i % 2 == 0),
            );
        }
        t.stop();
        assert_eq!(t.requests, 10);
        assert_eq!(t.accuracy(), 0.5);
        assert!(t.latency_us(50.0) >= 100.0);
        assert!(t.throughput_rps() > 0.0);
        assert!(t.summary().contains("requests=10"));
        assert_eq!(t.activity.spikes, 50);
    }

    #[test]
    fn empty_telemetry_is_safe() {
        let t = Telemetry::new();
        assert_eq!(t.accuracy(), 0.0);
        assert_eq!(t.throughput_rps(), 0.0);
        assert_eq!(t.latency_us(99.0), 0.0);
    }
}
