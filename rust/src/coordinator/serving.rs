//! Unified serving engine — the production request path.
//!
//! [`ServingEngine`] combines the two throughput mechanisms that previously
//! lived separately in [`super::multicore`] (batch sharding across C cores,
//! paper §IV footnote 1) and [`super::pipeline`] (per-layer stream
//! pipelining, Fig. 8) into one engine:
//!
//! * **C shards**, each a persistent per-layer pipeline: one OS thread per
//!   hardware layer owns that layer's synaptic memory and membrane state,
//!   exactly like the distributed per-layer memory that makes QUANTISENC
//!   streams overlap.
//! * **Bounded channels** everywhere: admission blocks when the engine is
//!   saturated (`queue_depth` messages per stage), which is the
//!   backpressure story — a flooded engine slows producers instead of
//!   buffering unboundedly.
//! * **Deterministic, in-order results**: single-sample mode assigns
//!   streams round-robin (sample *i* → shard *i mod C*); lane mode packs
//!   consecutive samples into groups and dispatches each group to the
//!   shard with the least cumulative dispatched work — a deterministic
//!   work-stealing schedule (a pure function of the op stream, never of
//!   thread timing). Within a shard the stage chain is FIFO and the
//!   feeder records every assignment, so the drainer merges shard outputs
//!   back into submission order. Every stream is settled (membranes
//!   reset) between samples, so results are bit-for-bit identical to a
//!   sequential [`crate::hdl::Core`] run — asserted in tests and in
//!   `benches/bench_serving.rs`.
//! * **Live reconfiguration**: the engine is *software-defined* after
//!   deployment. A [`ControlPlane`] handle (see
//!   [`ServingEngine::control_plane`]) applies cfg_in register programs and
//!   wt_in packed weight swaps while traffic is flowing: accepted programs
//!   ride the same bounded stage channels as epoch-tagged
//!   `StageMsg::Reconfig` control messages, broadcast to every shard at a
//!   sample boundary, so each sample is processed entirely under one config
//!   epoch and each [`StreamResult`] reports the epoch it was computed
//!   under. [`ServingEngine::run_session`] additionally schedules
//!   reconfigurations *in-band*, at exact positions in the request stream.
//!
//! * **Zero-alloc streaming**: stage channels carry bit-packed
//!   [`SpikePlane`]s recycled through buffer pools — each stage reuses the
//!   plane it consumed as a future output buffer, the collector returns
//!   drained planes to an engine-wide [`PlanePool`] the feeder draws from,
//!   and the pool is pre-filled at construction to cover the engine's
//!   maximum in-flight footprint, so the steady-state streaming path
//!   performs **zero plane allocations** (debug-asserted on every batch
//!   via [`PlanePool::misses`]).
//! * **Lane batching** ([`ServingOptions::lane_width`] > 1): the feeder
//!   packs up to 64 consecutive samples into one group, sent to its shard
//!   as one [`SpikeMatrix`] per timestep; every stage steps all lanes at
//!   once
//!   ([`crate::hdl::Layer::step_lanes`]) with each synaptic row fetched
//!   **once** per firing line and every channel hop amortized across the
//!   whole group, lanes of ragged batches are masked out as their streams
//!   end, and the collector demuxes lane results back into in-order
//!   [`StreamResult`]s — bit-identical (counts, epochs, per-stream
//!   activity ledgers) to the single-sample path, which remains the
//!   `lane_width == 1` fallback and conformance oracle. Matrices recycle
//!   through a pre-filled [`MatrixPool`] with the same zero-alloc
//!   contract. With [`ServingOptions::sparse_cutoff`] set, samples whose
//!   input firing density falls below the cutoff skip lane packing and
//!   stream down the single-sample path instead, where the layers'
//!   quiescence fast path elides most neuron work — dense traffic pays
//!   the batched costs, near-silent traffic does not.
//!
//! The per-stage loop (`stage_loop`) and the spike-count collector
//! (`collector_loop`) are shared with [`super::pipeline::run_pipelined`],
//! which is now a thin scoped-thread wrapper over the same primitives.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::registers::RegisterFile;
use crate::config::ModelConfig;
use crate::datasets::Sample;
use crate::hdl::core::argmax;
use crate::hdl::integrity::{self, IntegrityMode};
use crate::hdl::layer::Layer;
use crate::hdl::spikes::{MatrixPool, PlanePool, SpikeMatrix, SpikePlane};
use crate::hdl::ActivityStats;

use super::control::{ControlPlane, ControlShared, ReconfigProgram};
use super::interface::BusStats;

pub mod chaos;

use chaos::{ChaosKind, ChaosSchedule};

pub use super::pipeline::StreamResult;

/// Typed failure of the serving data path.
///
/// The variant that matters operationally is [`WorkerPanicked`]
/// (`ServingError::WorkerPanicked`): a stage/feeder/collector thread
/// panicking used to take down the whole process via
/// `join().expect(...)` — fatal once many tenants share one engine
/// behind the network front door. A panic now surfaces as this error
/// (carrying the panic payload's message), the engine shuts itself down,
/// and the process — and every other tenant's connection — stays alive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServingError {
    /// A worker thread panicked; `worker` names it and `message` is the
    /// stringified panic payload. The engine is shut down but droppable.
    WorkerPanicked { worker: String, message: String },
    /// The engine was shut down (or poisoned and self-shut-down); no
    /// further batches or snapshots are possible. Submitting used to hit
    /// an `expect` on the closed stage channel and panic the caller —
    /// now it is an ordinary, typed refusal.
    ShutDown,
    /// One shard's stage pipeline died while this stream was assigned to
    /// it. **Only** the streams in that shard's FIFO are affected — the
    /// remaining shards keep serving, and the supervisor rebuilds the
    /// dead shard bit-exactly from the last connectome checkpoint before
    /// the next session. `resumable` is true when resubmitting the same
    /// sample is sound (it always is for pure inference submits, which
    /// are idempotent functions of the sample; it is false only when the
    /// engine could not be healed and is shut down).
    ShardLost { shard: usize, resumable: bool },
}

impl std::fmt::Display for ServingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServingError::WorkerPanicked { worker, message } => {
                write!(f, "serving {worker} panicked: {message}")
            }
            ServingError::ShutDown => {
                write!(f, "serving engine is shut down; rebuild or restore it")
            }
            ServingError::ShardLost { shard, resumable } => {
                if *resumable {
                    write!(f, "serving shard {shard} was lost mid-stream; resubmit the sample")
                } else {
                    write!(f, "serving shard {shard} was lost and could not be rebuilt")
                }
            }
        }
    }
}

impl std::error::Error for ServingError {}

/// Stringify a `JoinHandle::join` panic payload (panics carry `&str` or
/// `String` in practice; anything else is reported opaquely).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Message flowing down a shard's stage chain: one timestep's bit-packed
/// spike plane (a recycled pool buffer — see the module docs), the Fig.-8
/// settle marker that ends a stream (accumulating the stream's activity
/// ledger as it passes each stage), their lane-batched twins (one
/// [`SpikeMatrix`] carrying up to 64 samples' spikes per timestep, one
/// group flush carrying the per-lane ledgers and stream ids), or an
/// epoch-tagged cfg_in/wt_in reconfiguration broadcast by the control
/// plane.
pub(crate) enum StageMsg {
    Step { stream: usize, plane: SpikePlane },
    Flush { stream: usize, stats: ActivityStats },
    /// One timestep of a lane group: `active` masks the lanes still
    /// streaming (ragged stream lengths), so per-lane ledgers stay
    /// bit-identical to single-sample runs.
    StepLanes { matrix: SpikeMatrix, active: u64 },
    /// End of a lane group: `streams[l]` is lane `l`'s stream id;
    /// `stats[l]` accumulates lane `l`'s activity as the marker passes
    /// each stage (the lane twin of `Flush`).
    FlushLanes { streams: Vec<usize>, stats: Vec<ActivityStats> },
    Reconfig { epoch: u64, program: Arc<ReconfigProgram> },
    /// Connectome snapshot fence: each stage writes its full state
    /// (registers, packed weights, neuron banks) to `reply` and forwards
    /// the fence downstream. Because it rides the same FIFO as the data,
    /// the export is automatically taken at a sample-group boundary —
    /// nothing in flight, nothing drained.
    Export { reply: std::sync::mpsc::Sender<LayerExport> },
    /// Connectome restore: each stage applies its entry of `states`
    /// (weights + neuron banks; registers were seeded at construction),
    /// acks on `reply`, and forwards. Payloads are validated against the
    /// engine geometry *before* this message is sent, so stage-side
    /// application is infallible — the Reconfig precedent.
    Import { states: Arc<Vec<LayerExport>>, reply: std::sync::mpsc::Sender<()> },
    /// Deterministic fault injection (see [`chaos`]): the stage the kind
    /// addresses acts on it (panics, exits, or stalls); every earlier
    /// stage forwards it, so the fault lands at an exact position in the
    /// shard's FIFO — everything dispatched before it completes, and
    /// everything behind a fatal fault is lost with the shard.
    Chaos { kind: ChaosKind },
}

/// Alias local to the stage machinery: the per-(shard, layer) state
/// section of a [`Connectome`](super::connectome::Connectome).
pub(crate) type LayerExport = super::connectome::LayerState;

/// Per-stage scrubbing contract: how many synaptic-memory blocks each
/// stage verifies at every sample-group boundary
/// ([`ServingOptions::scrub_stride`]) and the engine-wide ledger the
/// tallies land in. The default (stride 0, fresh ledger) is the
/// integrity-off plan the scoped pipeline wrapper uses.
#[derive(Clone, Default)]
pub(crate) struct ScrubPlan {
    pub(crate) stride: usize,
    pub(crate) ledger: Arc<integrity::Ledger>,
}

/// Boundary scrub: verify the stage's neuron banks (in full, they are
/// small) plus the next `stride` synaptic-memory blocks, repairing what
/// the mode can repair and absorbing the tally into the engine ledger.
/// Detected-uncorrectable corruption panics the stage — deliberately: the
/// panic reuses the entire supervision path (typed ShardLost settlement,
/// quarantine, rebuild from the last checkpoint, epoch replay), so a flip
/// the code cannot fix costs exactly one shard's in-flight streams, never
/// a silently wrong result. Runs *before* the first timestep after a
/// boundary, so corrupted state is caught before any datapath work
/// consumes it.
fn boundary_scrub(layer: &mut Layer, layer_idx: usize, scrub: &ScrubPlan) {
    if layer.integrity_mode() == IntegrityMode::Off {
        return;
    }
    let out = layer.scrub(scrub.stride);
    scrub.ledger.absorb(out);
    if out.detected > 0 {
        panic!("integrity: uncorrectable corruption detected at stage {layer_idx}");
    }
}

/// Body of one pipeline stage: owns hardware layer `layer_idx`, transforms
/// spike vectors, resets its membranes at every stream boundary, and applies
/// the slice of each control-plane program that addresses it (all register
/// writes — the decoder registers are core-global — plus its own layer's
/// weight payload). Control messages are applied *between* streams by
/// construction: they arrive through the same FIFO as the data, so every
/// stream is processed entirely under one config epoch. Returns when the
/// input channel closes or the downstream consumer disappears.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stage_loop(
    layer_idx: usize,
    mut layer: Layer,
    mut regs: RegisterFile,
    rx: Receiver<StageMsg>,
    tx: SyncSender<StageMsg>,
    mut pool: Vec<SpikePlane>,
    mut mat_pool: Vec<SpikeMatrix>,
    scrub: ScrubPlan,
) {
    // Activity accumulated by this stage for the stream in flight.
    let mut acc = ActivityStats::default();
    // Lane-batched twins: per-lane accumulators for the group in flight
    // and the per-step scratch `Layer::step_lanes` writes into (sized on
    // first use; the engine keeps the lane width constant).
    let mut acc_lanes: Vec<ActivityStats> = Vec::new();
    let mut lane_scratch: Vec<ActivityStats> = Vec::new();
    // True between streams (initially, and after every flush marker): the
    // first timestep after a boundary runs the background scrub *before*
    // touching the datapath, so a fault injected between samples — the
    // only place the feeder injects — is repaired or detected before any
    // compute consumes the corrupted word.
    let mut at_boundary = true;
    for msg in rx {
        match msg {
            StageMsg::Step { stream, plane } => {
                if at_boundary {
                    boundary_scrub(&mut layer, layer_idx, &scrub);
                    at_boundary = false;
                }
                // Output buffer from the stage-local free list; the consumed
                // input plane is recycled into the same list below, so a
                // pre-filled stage never allocates (and each plane's word
                // storage settles at max(fan_in, neurons) words).
                let mut out = pool.pop().unwrap_or_default();
                let mut st = layer.step_plane(&plane, &mut out, &regs);
                if layer_idx != 0 {
                    // One spk_clk edge per *core* timestep, not per layer —
                    // matches `Core::step`'s accounting bit-for-bit.
                    st.spk_steps = 0;
                }
                acc.add(&st);
                pool.push(plane);
                if tx.send(StageMsg::Step { stream, plane: out }).is_err() {
                    return;
                }
            }
            StageMsg::Flush { stream, stats: mut upstream } => {
                // Fig. 8 settle: membranes back to rest between streams.
                layer.reset();
                at_boundary = true;
                upstream.add(&acc);
                acc = ActivityStats::default();
                if tx.send(StageMsg::Flush { stream, stats: upstream }).is_err() {
                    return;
                }
            }
            StageMsg::StepLanes { matrix, active } => {
                if at_boundary {
                    boundary_scrub(&mut layer, layer_idx, &scrub);
                    at_boundary = false;
                }
                let lanes = matrix.lanes();
                if acc_lanes.len() != lanes {
                    acc_lanes.resize(lanes, ActivityStats::default());
                    lane_scratch.resize(lanes, ActivityStats::default());
                }
                let mut out = mat_pool.pop().unwrap_or_default();
                layer.step_lanes(&matrix, &mut out, &regs, active, &mut lane_scratch);
                for (l, st) in lane_scratch.iter_mut().enumerate() {
                    if layer_idx != 0 {
                        // One spk_clk edge per core timestep per lane.
                        st.spk_steps = 0;
                    }
                    acc_lanes[l].add(st);
                }
                mat_pool.push(matrix);
                if tx.send(StageMsg::StepLanes { matrix: out, active }).is_err() {
                    return;
                }
            }
            StageMsg::FlushLanes { streams, stats: mut upstream } => {
                // Settle every lane's membranes between groups; fold this
                // stage's per-lane ledgers into the marker (zip tolerates a
                // ragged final group shorter than the lane width, and a
                // zero-step group that never sized the accumulators).
                layer.reset();
                at_boundary = true;
                for (st, lane_acc) in upstream.iter_mut().zip(&acc_lanes) {
                    st.add(lane_acc);
                }
                for lane_acc in acc_lanes.iter_mut() {
                    *lane_acc = ActivityStats::default();
                }
                if tx.send(StageMsg::FlushLanes { streams, stats: upstream }).is_err() {
                    return;
                }
            }
            StageMsg::Reconfig { epoch, program } => {
                if program.chaos_panic_stage == Some(layer_idx) {
                    // Fault-injection hook (see ReconfigProgram): prove a
                    // worker panic becomes ServingError::WorkerPanicked,
                    // not a process abort.
                    panic!("chaos program panicked stage {layer_idx}");
                }
                // Programs are validated by the control plane before they
                // are admitted, so stage-side application is infallible —
                // a half-applied config cannot exist.
                regs.apply_program(&program.cfg).expect("program validated by control plane");
                for (k, payload) in &program.weights {
                    if *k == layer_idx {
                        layer
                            .load_packed(payload)
                            .expect("payload validated by control plane");
                    }
                }
                if tx.send(StageMsg::Reconfig { epoch, program }).is_err() {
                    return;
                }
            }
            StageMsg::Export { reply } => {
                // Scrub before fencing: a checkpoint must never capture a
                // flip that landed after the last boundary scrub — either
                // it is repaired here (Correct) or the panic fails the
                // fence as a typed error and the supervisor re-fences
                // after healing (Detect).
                boundary_scrub(&mut layer, layer_idx, &scrub);
                let (lanes, lane_vmem, lane_refcnt) = layer.lane_state();
                // Send errors mean the snapshotter gave up (timeout) —
                // the fence still flows downstream so later stages drain.
                let _ = reply.send(LayerExport {
                    regs: regs.vector(),
                    weights: layer.memory().packed().to_vec(),
                    vmem: layer.vmem_slice().to_vec(),
                    refcnt: layer.refcnt_slice().to_vec(),
                    lanes: lanes as u16,
                    lane_vmem,
                    lane_refcnt,
                });
                if tx.send(StageMsg::Export { reply }).is_err() {
                    return;
                }
            }
            StageMsg::Import { states, reply } => {
                let st = &states[layer_idx];
                layer.load_packed(&st.weights).expect("payload validated before import");
                layer.restore_state(&st.vmem, &st.refcnt);
                layer.restore_lanes(st.lanes as usize, &st.lane_vmem, &st.lane_refcnt);
                let _ = reply.send(());
                if tx.send(StageMsg::Import { states, reply }).is_err() {
                    return;
                }
            }
            StageMsg::Chaos { kind } => {
                match kind {
                    ChaosKind::StagePanic { stage } if stage == layer_idx => {
                        panic!("chaos: injected panic at stage {layer_idx}");
                    }
                    ChaosKind::ChannelDrop { stage } if stage == layer_idx => {
                        // The software model of a torn-down channel: exit
                        // the loop so both channel ends drop — upstream
                        // sends fail, downstream drains and cascades out.
                        return;
                    }
                    ChaosKind::SlowStage { stage, millis } if stage == layer_idx => {
                        std::thread::sleep(Duration::from_millis(millis));
                    }
                    ChaosKind::BitFlip { layer: at_layer, target, word, bit }
                        if at_layer == layer_idx =>
                    {
                        // A single-event upset: flip the raw storage bit
                        // behind the integrity codes' back. The feeder
                        // injects between samples, so the very next
                        // boundary scrub decides the outcome.
                        layer.integrity_flip(target, word, bit);
                    }
                    _ => {}
                }
                if tx.send(StageMsg::Chaos { kind }).is_err() {
                    return;
                }
            }
        }
    }
}

/// Send one lane group down a shard's chain: `t_max` lane-matrix steps
/// (lane `l` = `group[l]`, masked out once its stream ends — ragged
/// lengths never leak across lanes) followed by the group flush carrying
/// the lanes' stream ids. Matrices come from the engine pool and are
/// always `lane_width` wide, so a ragged final group reuses the same
/// stage lane banks (its high lanes simply never go active).
fn feed_group(
    tx: &SyncSender<StageMsg>,
    streams: &mut Vec<usize>,
    group: &mut Vec<&Sample>,
    matrix_pool: &MatrixPool,
    lane_width: usize,
    inputs: usize,
) -> Result<()> {
    if group.is_empty() {
        return Ok(());
    }
    let dead = || anyhow::anyhow!("serving shard died");
    let t_max = group.iter().map(|s| s.t_steps).max().unwrap_or(0);
    for t in 0..t_max {
        let mut matrix = matrix_pool.take();
        matrix.resize_clear(inputs, lane_width);
        let mut active = 0u64;
        for (l, s) in group.iter().enumerate() {
            if t < s.t_steps {
                matrix.load_lane_bytes(l, s.step(t));
                active |= 1 << l;
            }
        }
        tx.send(StageMsg::StepLanes { matrix, active }).map_err(|_| dead())?;
    }
    tx.send(StageMsg::FlushLanes {
        streams: std::mem::take(streams),
        stats: vec![ActivityStats::default(); group.len()],
    })
    .map_err(|_| dead())?;
    group.clear();
    Ok(())
}

/// Stream one sample down a shard's chain as per-timestep planes followed
/// by the Fig.-8 flush marker. Returns false when the shard's first stage
/// is gone (the caller marks it dead); buffers already handed to a dying
/// chain are replaced by the supervisor's pool refill, not reclaimed here.
fn feed_single(
    tx: &SyncSender<StageMsg>,
    stream: usize,
    sample: &Sample,
    plane_pool: &PlanePool,
) -> bool {
    for t in 0..sample.t_steps {
        // Encode straight into a recycled pool plane — no per-timestep
        // Vec allocation.
        let mut plane = plane_pool.take();
        sample.step_plane_into(t, &mut plane);
        if tx.send(StageMsg::Step { stream, plane }).is_err() {
            return false;
        }
    }
    tx.send(StageMsg::Flush { stream, stats: ActivityStats::default() }).is_ok()
}

/// Broadcast an epoch-tagged program to every live shard, marking any
/// whose first stage is gone. A dead shard missing the broadcast is not
/// an error: the program is already committed in the control plane's
/// replay history, and the supervisor programs that history onto the
/// rebuilt shard before re-admitting it.
fn broadcast_program(
    senders: &[SyncSender<StageMsg>],
    alive: &mut [bool],
    epoch: u64,
    program: &Arc<ReconfigProgram>,
) {
    for (i, tx) in senders.iter().enumerate() {
        if alive[i]
            && tx.send(StageMsg::Reconfig { epoch, program: program.clone() }).is_err()
        {
            alive[i] = false;
        }
    }
}

/// Index of the live shard with the least cumulative dispatched work,
/// lowest index on ties (`min_by_key` returns the *first* minimum). With
/// every shard alive — the steady state — the choice is a pure function
/// of the op stream, so identical sessions yield identical shard
/// assignments run-to-run, which keeps per-shard lane-bank shapes, and
/// therefore connectome snapshots, reproducible. When a shard has died
/// mid-session it is excluded (graceful degradation: the survivors absorb
/// its traffic); with *no* shard left alive, shard 0 is returned so the
/// unit is still recorded and the drainer can settle its streams as lost.
fn least_loaded(load: &[u64], alive: &[bool]) -> usize {
    load.iter()
        .enumerate()
        .filter(|&(i, _)| alive[i])
        .min_by_key(|&(_, &c)| c)
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Dispatch the pending lane group (possibly partial) to the least-loaded
/// shard and record the assignment for the drainer.
///
/// This is the serving engine's work-stealing scheduler in deterministic
/// form: instead of idle stage threads racing to pop a shared deque
/// (which would make shard assignment — and with it lane-bank widths and
/// connectome snapshots — depend on thread timing), the feeder tracks the
/// cumulative step-cost dispatched to each shard and hands every ready
/// group to the shard that has received the least. An idle shard thereby
/// takes exactly the group a hot shard would otherwise have queued, while
/// the schedule stays a pure function of the op stream. Groups pack
/// **consecutive** stream ids, so dispatch order equals stream order and
/// the drainer's per-record in-order recv argument holds.
///
/// Called when a group fills, before any reconfiguration broadcast (epoch
/// boundaries land between groups), before a sparse-fallback single (so
/// results stay in submission order), and at end of session.
fn dispatch_group(
    pending: &mut (Vec<usize>, Vec<&Sample>),
    senders: &[SyncSender<StageMsg>],
    alive: &mut [bool],
    load: &mut [u64],
    assign: &std::sync::mpsc::Sender<(usize, usize)>,
    matrix_pool: &MatrixPool,
    lane_width: usize,
    inputs: usize,
) {
    let (streams, group) = pending;
    if group.is_empty() {
        return;
    }
    let shard = least_loaded(load, alive);
    // Cost model: one StepLanes message per timestep plus the FlushLanes
    // marker — proportional to the stage work the group induces.
    let t_max = group.iter().map(|s| s.t_steps).max().unwrap_or(0) as u64;
    load[shard] += t_max + 1;
    // The record channel is unbounded and the drainer holds its receiver
    // until the session scope ends, so this send cannot block; a closed
    // receiver only happens while the scope is already unwinding.
    let _ = assign.send((shard, group.len()));
    // A failed send means the shard's first stage is gone: mark it dead
    // and move on — the record above lets the drainer settle the group's
    // streams as ShardLost while the surviving shards keep serving.
    if alive[shard]
        && feed_group(&senders[shard], streams, group, matrix_pool, lane_width, inputs).is_err()
    {
        alive[shard] = false;
    }
    streams.clear();
    group.clear();
}

/// Body of the terminal collector: accumulates output-layer spike counts per
/// stream, tracks the config epoch announced by [`StageMsg::Reconfig`]
/// markers, and emits one [`StreamResult`] per `Flush` (carrying the epoch
/// and the full activity ledger the stages accumulated). Lane-batched
/// groups are **demuxed** here: per-lane spike counters accumulate from
/// each output [`SpikeMatrix`]'s lane-words, and a `FlushLanes` marker
/// emits one in-order result per lane. Drained planes/matrices are
/// returned to their pools, closing the feeder → stages → collector
/// recycle loop. `emit` returning false stops the loop (downstream gone).
pub(crate) fn collector_loop<F: FnMut(StreamResult) -> bool>(
    n_out: usize,
    rx: Receiver<StageMsg>,
    pool: Arc<PlanePool>,
    mat_pool: Arc<MatrixPool>,
    mut emit: F,
) {
    let mut counts = vec![0u32; n_out];
    let mut spikes_total = 0u64;
    // Lane demux state, sized on the first lane-batched step.
    let mut lane_counts: Vec<Vec<u32>> = Vec::new();
    let mut lane_spikes: Vec<u64> = Vec::new();
    let mut epoch = 0u64;
    for msg in rx {
        match msg {
            StageMsg::Step { plane, .. } => {
                debug_assert_eq!(plane.len(), n_out, "output plane arity");
                for j in plane.iter_ones() {
                    counts[j] += 1;
                    spikes_total += 1;
                }
                pool.put(plane);
            }
            StageMsg::Flush { stream, stats } => {
                let result = StreamResult {
                    stream_id: stream,
                    prediction: argmax(&counts),
                    counts: std::mem::replace(&mut counts, vec![0u32; n_out]),
                    spikes_total,
                    epoch,
                    stats,
                };
                spikes_total = 0;
                if !emit(result) {
                    return;
                }
            }
            StageMsg::StepLanes { matrix, .. } => {
                debug_assert_eq!(matrix.lines(), n_out, "output matrix arity");
                if lane_counts.len() != matrix.lanes() {
                    lane_counts.resize(matrix.lanes(), vec![0u32; n_out]);
                    lane_spikes.resize(matrix.lanes(), 0);
                }
                for (j, &word) in matrix.words().iter().enumerate() {
                    let mut bits = word;
                    while bits != 0 {
                        let l = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        lane_counts[l][j] += 1;
                        lane_spikes[l] += 1;
                    }
                }
                mat_pool.put(matrix);
            }
            StageMsg::FlushLanes { streams, stats } => {
                for (l, (stream, lane_stats)) in streams.into_iter().zip(stats).enumerate() {
                    // A zero-step group may never have sized the demux
                    // state; such lanes have all-zero counts.
                    let counts = if l < lane_counts.len() {
                        std::mem::replace(&mut lane_counts[l], vec![0u32; n_out])
                    } else {
                        vec![0u32; n_out]
                    };
                    let spikes_total =
                        if l < lane_spikes.len() { std::mem::take(&mut lane_spikes[l]) } else { 0 };
                    let result = StreamResult {
                        stream_id: stream,
                        prediction: argmax(&counts),
                        counts,
                        spikes_total,
                        epoch,
                        stats: lane_stats,
                    };
                    if !emit(result) {
                        return;
                    }
                }
            }
            StageMsg::Reconfig { epoch: e, .. } => {
                epoch = e;
            }
            // Snapshot fences terminate here: every stage already exported
            // (or imported) by the time the marker reaches the collector.
            // Chaos markers address stages; a surviving one is spent.
            StageMsg::Export { .. } | StageMsg::Import { .. } | StageMsg::Chaos { .. } => {}
        }
    }
}

/// Build one shard's programmed layer chain (shared with
/// [`super::pipeline::run_pipelined`]). Weights arrive as the artifact
/// store's dense matrices and are scattered into each layer's
/// topology-aware store — a Gaussian/one-to-one shard only allocates the
/// synapses its topology instantiates.
pub(crate) fn build_layers(config: &ModelConfig, weights: &[Vec<i32>]) -> Result<Vec<Layer>> {
    anyhow::ensure!(weights.len() == config.num_layers(), "weights arity");
    let mut layers: Vec<Layer> = config
        .layers()
        .iter()
        .map(|l| Layer::new(l, config.qspec, config.mem))
        .collect();
    for (layer, w) in layers.iter_mut().zip(weights) {
        layer.memory_mut().load_dense(w)?;
    }
    Ok(layers)
}

/// Engine tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServingOptions {
    /// Number of sharded cores C (each shard pipelines its layers).
    pub cores: usize,
    /// Bounded-channel capacity per stage — the admission/backpressure
    /// window, in messages (one message ≈ one timestep of one stream,
    /// or of one whole lane group in batched mode).
    pub queue_depth: usize,
    /// Samples stepped concurrently per shard message (1..=64). At 1 the
    /// engine runs the single-sample packed path; above 1 the feeder packs
    /// **consecutive** samples into lane groups and dispatches each ready
    /// group to the least-loaded shard (see [`ServingEngine::run_session`]),
    /// so every synaptic row fetch and every channel hop is amortized
    /// across the batch. Results are bit-identical either way.
    pub lane_width: usize,
    /// Firing-rate-aware admission policy for lane-batched engines: a
    /// sample whose input spike density (`nnz / (t_steps × inputs)`) is
    /// **below** this cutoff bypasses lane packing and is streamed down the
    /// single-sample packed path, whose per-neuron quiescence fast path
    /// does near-zero work on silence — dense-batch costs are only paid by
    /// streams dense enough to amortize them. `None` (default) packs
    /// everything. Routing never changes results (both paths are
    /// bit-identical); an out-of-order hazard is avoided by flushing the
    /// pending group before a sparse sample is dispatched.
    pub sparse_cutoff: Option<f64>,
    /// Supervision recovery-point cadence: a fresh in-memory connectome
    /// checkpoint is fenced (cheaply, via `StageMsg::Export` at a
    /// sample-group boundary) once at least this many samples completed
    /// since the last one. Smaller intervals shorten the epoch-replay
    /// tail a shard rebuild performs; larger ones fence less often. The
    /// construction state is always checkpoint zero, so recovery works
    /// from the first sample. Must be at least 1 — validated (as a typed
    /// error) at engine construction, never silently clamped.
    pub checkpoint_interval: u64,
    /// SEU-integrity level for every stage's state memories (synaptic
    /// stores and neuron banks — see [`crate::hdl::integrity`]). `Off`
    /// (default) skips all code maintenance; `Detect` adds interleaved
    /// parity (any boundary flip quarantines the shard, which is then
    /// rebuilt from the last checkpoint); `Correct` adds SECDED codes
    /// that repair single-bit flips in place at the boundary scrub.
    pub integrity: IntegrityMode,
    /// Background-scrub budget: synaptic-memory blocks
    /// ([`crate::hdl::integrity::PARITY_BLOCK`]-word groups) each stage
    /// verifies at every sample-group boundary, via a wrapping cursor
    /// (the small neuron banks are always verified in full). The default
    /// `usize::MAX` sweeps the whole weight store every boundary — the
    /// setting the bit-exactness gates assume, since a flip in an
    /// unswept block could be consumed before its scrub turn; smaller
    /// strides amortize the sweep across boundaries at the cost of that
    /// detection-latency window.
    pub scrub_stride: usize,
}

impl Default for ServingOptions {
    fn default() -> Self {
        ServingOptions {
            cores: 2,
            queue_depth: 64,
            lane_width: 1,
            sparse_cutoff: None,
            checkpoint_interval: 256,
            integrity: IntegrityMode::Off,
            scrub_stride: usize::MAX,
        }
    }
}

impl ServingOptions {
    pub fn with_cores(cores: usize) -> ServingOptions {
        ServingOptions { cores, ..Default::default() }
    }

    /// Lane-batched engine: C shards × `lane_width` samples per step.
    pub fn with_lanes(cores: usize, lane_width: usize) -> ServingOptions {
        ServingOptions { cores, lane_width, ..Default::default() }
    }

    /// Builder: set the sparse-stream fallback cutoff (see
    /// [`ServingOptions::sparse_cutoff`]).
    pub fn sparse_cutoff(mut self, cutoff: f64) -> ServingOptions {
        self.sparse_cutoff = Some(cutoff);
        self
    }

    /// Builder: set the supervision checkpoint cadence (see
    /// [`ServingOptions::checkpoint_interval`]). A cadence of 0 is kept
    /// as-is and rejected with a typed error by [`ServingEngine::new`] —
    /// surfacing the misconfiguration beats silently clamping it.
    pub fn checkpoints_every(mut self, samples: u64) -> ServingOptions {
        self.checkpoint_interval = samples;
        self
    }

    /// Builder: set the SEU-integrity level (see
    /// [`ServingOptions::integrity`]).
    pub fn with_integrity(mut self, mode: IntegrityMode) -> ServingOptions {
        self.integrity = mode;
        self
    }

    /// Builder: set the background-scrub budget (see
    /// [`ServingOptions::scrub_stride`]).
    pub fn scrub_stride(mut self, blocks: usize) -> ServingOptions {
        self.scrub_stride = blocks;
        self
    }
}

/// One operation in a [`ServingEngine::run_session`] request stream: admit
/// a sample, or reconfigure the engine *at exactly this point* in the
/// stream (all earlier samples finish under the old epoch, all later ones
/// run under the new one — deterministically, unlike the asynchronous
/// [`ControlPlane::apply`] whose boundary depends on arrival time).
pub enum SessionOp<'a> {
    Submit(&'a Sample),
    Reconfig(ReconfigProgram),
}

struct Shard {
    in_tx: Option<SyncSender<StageMsg>>,
    out_rx: Receiver<StreamResult>,
    threads: Vec<JoinHandle<()>>,
}

/// Supervision state of one shard. In steady state every shard is
/// `Healthy`; the other two states are transited synchronously inside the
/// supervisor's recovery pass, so an observer between sessions sees
/// either all-`Healthy` or a poisoned engine — the intermediate states
/// surface through [`ServingEngine::shard_health`] during recovery and in
/// the recovery counters afterwards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardHealth {
    /// Serving traffic.
    Healthy,
    /// Detected dead; its in-flight streams have been settled as
    /// [`ServingError::ShardLost`] and its threads are being reaped.
    Quarantined,
    /// Stage pipeline being rebuilt from the last connectome checkpoint
    /// (import fence + config-epoch replay).
    Rebuilding,
}

/// An in-memory recovery point: the per-shard, per-layer connectome
/// state fenced at a sample-group boundary, plus the config epoch it was
/// fenced under. A shard rebuilt from `layers[shard]` and replayed
/// through every committed program after `epoch` is bit-exact with its
/// never-died twin: at group boundaries membranes are settled to rest by
/// construction, so registers + packed weights + epoch are the complete
/// state, and replay is idempotent (cfg writes are absolute, wt swaps are
/// whole payloads).
struct Checkpoint {
    epoch: u64,
    /// `ServingEngine::completed` when the fence was taken — the age
    /// ledger behind [`ServingEngine::checkpoint_age_samples`].
    completed: u64,
    layers: Vec<Vec<LayerExport>>,
}

/// Spin up one shard's stage chain + collector (shared by construction
/// and by the supervisor's shard rebuild, which must produce an
/// identically-shaped pipeline for the import fence and epoch replay).
#[allow(clippy::too_many_arguments)]
fn spawn_shard(
    layers: Vec<Layer>,
    regs: &RegisterFile,
    queue_depth: usize,
    lanes: usize,
    wants_planes: bool,
    max_width: usize,
    n_out: usize,
    plane_pool: &Arc<PlanePool>,
    matrix_pool: &Arc<MatrixPool>,
    scrub: &ScrubPlan,
) -> Shard {
    let mut threads = Vec::with_capacity(layers.len() + 1);
    let (first_tx, mut chain_rx) = sync_channel::<StageMsg>(queue_depth);
    for (layer_idx, layer) in layers.into_iter().enumerate() {
        let (tx, next_rx) = sync_channel::<StageMsg>(queue_depth);
        let stage_regs = regs.clone();
        let stage_scrub = scrub.clone();
        let rx = std::mem::replace(&mut chain_rx, next_rx);
        // Two pre-sized buffers per stage-local free list cover the
        // one output buffer a stage ever needs in hand (planes on
        // the single-sample path, lane matrices in batched mode).
        // A sparse-fallback engine mixes both message kinds, so its
        // stages carry both free lists.
        let stage_pool = if wants_planes {
            vec![
                SpikePlane::with_line_capacity(max_width),
                SpikePlane::with_line_capacity(max_width),
            ]
        } else {
            Vec::new()
        };
        let stage_mats = if lanes > 1 {
            vec![
                SpikeMatrix::with_line_capacity(max_width),
                SpikeMatrix::with_line_capacity(max_width),
            ]
        } else {
            Vec::new()
        };
        threads.push(std::thread::spawn(move || {
            stage_loop(layer_idx, layer, stage_regs, rx, tx, stage_pool, stage_mats, stage_scrub)
        }));
    }
    // In lane mode a single FlushLanes emits up to lane_width
    // results at once; the result channel must absorb a whole
    // group so the collector never wedges mid-flush.
    let (out_tx, out_rx) = sync_channel::<StreamResult>(queue_depth.max(lanes) + lanes);
    let collector_rx = chain_rx;
    let collector_pool = plane_pool.clone();
    let collector_mats = matrix_pool.clone();
    threads.push(std::thread::spawn(move || {
        collector_loop(n_out, collector_rx, collector_pool, collector_mats, |r| {
            out_tx.send(r).is_ok()
        })
    }));
    Shard { in_tx: Some(first_tx), out_rx, threads }
}

/// C sharded, per-layer-pipelined QUANTISENC cores behind one batched,
/// backpressured, order-preserving, **run-time reprogrammable** API.
///
/// ```
/// use quantisenc::config::registers::RegisterFile;
/// use quantisenc::config::ModelConfig;
/// use quantisenc::coordinator::serving::{ServingEngine, ServingOptions};
/// use quantisenc::datasets::Sample;
/// use quantisenc::fixed::Q5_3;
///
/// let cfg = ModelConfig::parse_arch("4x3x2", Q5_3)?;
/// let weights = vec![vec![4; 12], vec![4; 6]];
/// let regs = RegisterFile::new(Q5_3);
/// let mut engine = ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2))?;
///
/// let samples: Vec<Sample> = (0..4)
///     .map(|_| Sample { spikes: vec![1; 8], t_steps: 2, inputs: 4, label: 0 })
///     .collect();
/// let results = engine.run_batch(&samples)?;
/// assert_eq!(results.len(), 4);
/// // Results come back in submission order, tagged with the config epoch
/// // (0 = the construction-time configuration).
/// assert!(results.iter().enumerate().all(|(i, r)| r.stream_id == i && r.epoch == 0));
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct ServingEngine {
    shards: Vec<Shard>,
    /// The deployed architecture — kept so snapshots are self-describing
    /// and a restored engine can be rebuilt without the original artifact.
    config: ModelConfig,
    inputs: usize,
    outputs: usize,
    /// Physical synaptic storage words per shard (topology-aware stores).
    synapse_words: usize,
    /// Control-plane state shared with every [`ControlPlane`] handle.
    control: Arc<ControlShared>,
    /// Engine-wide recycled [`SpikePlane`] free list: the feeder draws
    /// input planes here, the collectors return drained output planes.
    /// Pre-filled to the maximum in-flight footprint, so steady-state
    /// streaming allocates nothing ([`ServingEngine::plane_pool_misses`]).
    plane_pool: Arc<PlanePool>,
    /// The lane-batched twin of `plane_pool`: recycled [`SpikeMatrix`]
    /// buffers for `lane_width > 1` engines, pre-filled to the same
    /// in-flight bound ([`ServingEngine::matrix_pool_misses`]).
    matrix_pool: Arc<MatrixPool>,
    /// Samples packed per lane group (1 = single-sample path).
    lane_width: usize,
    /// Firing-density cutoff below which a sample bypasses lane packing
    /// and streams down the single-sample quiescence fast path
    /// ([`ServingOptions::sparse_cutoff`]).
    sparse_cutoff: Option<f64>,
    submitted: u64,
    completed: u64,
    /// Cumulative [`ActivityStats`] over every completed stream — the
    /// engine-lifetime activity ledger a connectome snapshot carries.
    activity: ActivityStats,
    /// Set when the engine failed in a way the supervisor cannot repair
    /// (feeder panic, scheduler bug, failed rebuild): in-flight state is
    /// then indeterminate, so the engine refuses further batches. A mere
    /// shard death does NOT poison — the supervisor quarantines and
    /// rebuilds it instead.
    poisoned: bool,
    // ---- supervision state ----------------------------------------
    /// Per-shard health; all-`Healthy` between sessions unless poisoned.
    health: Vec<ShardHealth>,
    /// The live recovery point (always `Some` once construction
    /// completes; an `Option` only for staged initialization).
    checkpoint: Option<Checkpoint>,
    checkpoint_interval: u64,
    quarantines: u64,
    recoveries: u64,
    /// Cumulative wall-clock spent with any shard not `Healthy`.
    degraded: Duration,
    /// Per-recovery latency (detection → re-admission), milliseconds —
    /// the distribution `repro chaos-soak` reports as p50/p99.
    recovery_ms: Vec<f64>,
    /// Installed fault schedule ([`ServingEngine::install_chaos`]) and
    /// the index of the first event not yet fired.
    chaos: Option<ChaosSchedule>,
    /// Engine-wide integrity tally (blocks scrubbed, flips corrected,
    /// uncorrectable flips detected), shared with every stage thread.
    scrub_ledger: Arc<integrity::Ledger>,
    // ---- rebuild parameters (frozen at construction) ---------------
    /// SEU-integrity level every stage runs under
    /// ([`ServingOptions::integrity`]); rebuilt shards inherit it.
    integrity: IntegrityMode,
    /// Boundary-scrub budget in synaptic-memory blocks
    /// ([`ServingOptions::scrub_stride`]).
    scrub_stride: usize,
    queue_depth: usize,
    max_width: usize,
    wants_planes: bool,
    /// The pool prefill bound (`cores * per_shard`); recovery tops the
    /// pools back up to it after a dead shard drops its in-flight
    /// buffers, so the zero-miss invariant survives re-admission.
    pool_target: usize,
}

impl ServingEngine {
    /// Build C identical programmed shards (persistent stage threads spin up
    /// immediately and idle on their channels).
    pub fn new(
        config: &ModelConfig,
        weights: &[Vec<i32>],
        regs: &RegisterFile,
        options: ServingOptions,
    ) -> Result<ServingEngine> {
        anyhow::ensure!(options.cores >= 1, "need at least one core");
        anyhow::ensure!(options.queue_depth >= 1, "queue depth must be positive");
        anyhow::ensure!(
            (1..=64).contains(&options.lane_width),
            "lane width must be 1..=64 (one bit per sample in a u64 lane word)"
        );
        anyhow::ensure!(
            options.checkpoint_interval >= 1,
            "checkpoint interval must be at least 1 sample (a zero cadence cannot make \
             recovery points more frequent than the per-session fence)"
        );
        let lanes = options.lane_width;
        let n_out = config.outputs();
        let max_width = config.sizes().iter().copied().max().unwrap_or(1);
        // Upper bound on planes (or lane matrices, in batched mode)
        // simultaneously *outside* the shared pool, per shard: every
        // bounded-channel slot of the K+1 stage channels can hold one Step
        // buffer, each of the K stages holds at most two in hand (input
        // being processed + output just popped), plus one each in the
        // feeder's and collector's hands. Pre-filling past this bound means
        // the pool never allocates in steady state — the zero-alloc
        // invariant `run_session` debug-asserts. Only the active mode's
        // pool is pre-filled (the other is never drawn from).
        let per_shard = (config.num_layers() + 1) * options.queue_depth
            + 2 * config.num_layers()
            + 4;
        // The sparse-stream fallback routes below-cutoff samples down the
        // single-sample plane path even in lane mode, so such engines
        // pre-fill both pools (the zero-alloc invariant covers both).
        let wants_planes = lanes == 1 || options.sparse_cutoff.is_some();
        let plane_pool = Arc::new(if wants_planes {
            PlanePool::prefilled(options.cores * per_shard, max_width)
        } else {
            PlanePool::new()
        });
        let matrix_pool = Arc::new(if lanes > 1 {
            MatrixPool::prefilled(options.cores * per_shard, max_width)
        } else {
            MatrixPool::new()
        });
        let scrub_ledger = Arc::new(integrity::Ledger::default());
        let scrub = ScrubPlan { stride: options.scrub_stride, ledger: scrub_ledger.clone() };
        let mut shards = Vec::with_capacity(options.cores);
        let mut synapse_words = 0usize;
        let mut packed_sizes: Vec<usize> = Vec::new();
        for shard_idx in 0..options.cores {
            let mut layers = build_layers(config, weights)?;
            for layer in &mut layers {
                layer.set_integrity(options.integrity);
            }
            if shard_idx == 0 {
                // Shards are identical; measure the footprint once. The
                // per-layer word counts double as the control plane's
                // wt_in payload-size contract.
                packed_sizes = layers.iter().map(|l| l.memory().synapses()).collect();
                synapse_words = packed_sizes.iter().sum();
            }
            shards.push(spawn_shard(
                layers,
                regs,
                options.queue_depth,
                lanes,
                wants_planes,
                max_width,
                n_out,
                &plane_pool,
                &matrix_pool,
                &scrub,
            ));
        }
        let control = Arc::new(ControlShared::new(regs.clone(), packed_sizes, options.cores));
        let mut engine = ServingEngine {
            health: vec![ShardHealth::Healthy; shards.len()],
            shards,
            config: config.clone(),
            inputs: config.inputs(),
            outputs: n_out,
            synapse_words,
            control,
            plane_pool,
            matrix_pool,
            lane_width: lanes,
            sparse_cutoff: options.sparse_cutoff,
            submitted: 0,
            completed: 0,
            activity: ActivityStats::default(),
            poisoned: false,
            checkpoint: None,
            checkpoint_interval: options.checkpoint_interval,
            quarantines: 0,
            recoveries: 0,
            degraded: Duration::ZERO,
            recovery_ms: Vec::new(),
            chaos: None,
            scrub_ledger,
            integrity: options.integrity,
            scrub_stride: options.scrub_stride,
            queue_depth: options.queue_depth,
            max_width,
            wants_planes,
            pool_target: options.cores * per_shard,
        };
        // Checkpoint zero: the construction state is always a valid
        // recovery point, so supervision covers the very first sample.
        engine.take_checkpoint()?;
        Ok(engine)
    }

    /// Samples stepped per shard message (1 = single-sample path).
    pub fn lane_width(&self) -> usize {
        self.lane_width
    }

    /// The firing-density cutoff for the sparse-stream fallback, if one
    /// was configured ([`ServingOptions::sparse_cutoff`]).
    pub fn sparse_cutoff(&self) -> Option<f64> {
        self.sparse_cutoff
    }

    /// Spike lines of the input layer (spk_in width) — the sample width
    /// every admitted stream must match.
    pub fn inputs(&self) -> usize {
        self.inputs
    }

    /// Neurons of the output layer (spk_out width) — the arity of every
    /// [`StreamResult::counts`].
    pub fn outputs(&self) -> usize {
        self.outputs
    }

    pub fn num_cores(&self) -> usize {
        self.shards.len()
    }

    /// Physical synaptic storage words per shard — measured from the
    /// topology-aware stores, so a Gaussian/one-to-one engine reports its
    /// actual (sparse) memory footprint, not the dense M×N size.
    pub fn synapse_words_per_shard(&self) -> usize {
        self.synapse_words
    }

    /// Requests accepted / completed over the engine's lifetime.
    pub fn stats(&self) -> (u64, u64) {
        (self.submitted, self.completed)
    }

    /// Times the streaming path had to allocate a spike plane because the
    /// recycled-buffer pool was dry. Stays 0 for the engine's whole
    /// lifetime (the pool is pre-filled past the in-flight bound); the
    /// engine debug-asserts this after every batch.
    pub fn plane_pool_misses(&self) -> u64 {
        self.plane_pool.misses()
    }

    /// Lane-batched twin of [`ServingEngine::plane_pool_misses`]: times the
    /// batched streaming path had to allocate a [`SpikeMatrix`] because the
    /// recycled-buffer pool was dry. Stays 0 for the engine's lifetime;
    /// debug-asserted after every batch.
    pub fn matrix_pool_misses(&self) -> u64 {
        self.matrix_pool.misses()
    }

    /// A cloneable, thread-safe [`ControlPlane`] handle for reprogramming
    /// this engine while it serves — see [`super::control`] for the epoch
    /// and validation semantics.
    pub fn control_plane(&self) -> ControlPlane {
        ControlPlane::from_shared(self.control.clone())
    }

    /// The engine's AXI transaction ledger ([`BusStats`], §IV bus model):
    /// cfg_in/wt_in control beats charged by the control plane (per shard)
    /// and spk_in/spk_out data beats metered by admission and drain — one
    /// ledger for control and data traffic.
    pub fn bus(&self) -> BusStats {
        self.control.bus()
    }

    /// The config epoch the *next* admitted sample will be served under
    /// (0 until the first accepted reconfiguration).
    pub fn epoch(&self) -> u64 {
        self.control.epoch()
    }

    /// Serve a batch: admission feeds the shards under backpressure
    /// (round-robin in single-sample mode, least-loaded lane groups in
    /// lane mode) while results are drained concurrently; returns one
    /// result per sample, in submission order, bit-identical to a
    /// sequential core. Control-plane programs admitted via
    /// [`ControlPlane::apply`] are broadcast at sample boundaries of this
    /// feed (and before the first sample).
    pub fn run_batch(&mut self, samples: &[Sample]) -> Result<Vec<StreamResult>> {
        let ops: Vec<SessionOp> = samples.iter().map(SessionOp::Submit).collect();
        self.run_session(&ops)
    }

    /// Per-stream twin of [`ServingEngine::run_batch`]: one outcome per
    /// sample, `Err(ShardLost)` only for streams that were in a dying
    /// shard's FIFO (see [`ServingEngine::run_session_outcomes`]).
    pub fn run_batch_outcomes(
        &mut self,
        samples: &[Sample],
    ) -> Result<Vec<Result<StreamResult, ServingError>>> {
        let ops: Vec<SessionOp> = samples.iter().map(SessionOp::Submit).collect();
        self.run_session_outcomes(&ops)
    }

    /// Serve a request stream that interleaves samples with in-band
    /// reconfigurations. Each [`SessionOp::Reconfig`] takes effect at
    /// exactly its position: samples before it complete under the previous
    /// epoch, samples after it under the new one, with no drain in between
    /// — the control message simply flows down the same bounded channels
    /// behind the last sample's data. Returns one result per
    /// [`SessionOp::Submit`], in submission order, each tagged with its
    /// epoch.
    ///
    /// In-band programs are validated up front; an invalid program fails
    /// the call before any sample is admitted (the engine stays healthy).
    pub fn run_session(&mut self, ops: &[SessionOp]) -> Result<Vec<StreamResult>> {
        let outcomes = self.run_session_outcomes(ops)?;
        let mut results = Vec::with_capacity(outcomes.len());
        for outcome in outcomes {
            // Fail-fast view: the first lost stream fails the call. The
            // engine itself was already healed by the outcomes pass (it is
            // NOT poisoned) — the caller only lost this session's results.
            results.push(outcome.map_err(anyhow::Error::from)?);
        }
        Ok(results)
    }

    /// Serve a request stream with **per-stream settlement**: one outcome
    /// per [`SessionOp::Submit`], in submission order. `Ok` results are
    /// bit-identical to a sequential [`crate::hdl::Core`] run;
    /// `Err(`[`ServingError::ShardLost`]`)` settles exactly the streams
    /// that were in a dying shard's FIFO behind the fault. The call itself
    /// only fails for whole-engine conditions: poisoned/shut-down engine,
    /// invalid in-band program (checked before any admission), a feeder
    /// panic, or a failed shard rebuild.
    ///
    /// This is the supervised entry point. Before admission the engine
    /// heals any shard that died since the last session and refreshes the
    /// in-memory recovery point when the checkpoint cadence is due
    /// ([`ServingOptions::checkpoint_interval`]); after the drain, every
    /// shard lost mid-session is quarantined, rebuilt bit-exactly from the
    /// last checkpoint (import fence + config-epoch replay), and
    /// re-admitted to the dispatcher — the engine returns to
    /// all-[`Healthy`](ShardHealth::Healthy) before this returns, and the
    /// surviving shards serve throughout (graceful degradation: a fault
    /// costs its own shard's in-flight streams, nothing else).
    pub fn run_session_outcomes(
        &mut self,
        ops: &[SessionOp],
    ) -> Result<Vec<Result<StreamResult, ServingError>>> {
        anyhow::ensure!(
            !self.poisoned,
            "serving engine poisoned by an earlier failed batch; build a new engine"
        );
        let mut n_samples = 0usize;
        for op in ops {
            match op {
                SessionOp::Submit(s) => {
                    anyhow::ensure!(
                        s.inputs == self.inputs,
                        "sample width {} does not match engine input layer {}",
                        s.inputs,
                        self.inputs
                    );
                    n_samples += 1;
                }
                SessionOp::Reconfig(program) => {
                    self.control.validate(program)?;
                }
            }
        }
        // Supervised pre-pass: heal anything that died between sessions
        // (e.g. a fault that landed after the previous drain finished) and
        // refresh the recovery point if the cadence is due.
        self.heal()?;
        self.maybe_checkpoint()?;
        let n_cores = self.shards.len();
        // A shut-down engine has dropped its stage senders; submitting to
        // it is a typed, recoverable refusal — not an `expect` panic.
        let mut senders: Vec<SyncSender<StageMsg>> = Vec::with_capacity(n_cores);
        for shard in &self.shards {
            match &shard.in_tx {
                Some(tx) => senders.push(tx.clone()),
                None => return Err(ServingError::ShutDown.into()),
            }
        }
        // This session's slice of the installed chaos schedule, rebased to
        // session-local sample indices, plus the kill set for post-session
        // supervision (a fault landing after a shard's last assigned
        // stream loses nothing but still must be healed before the next
        // session — the drainer alone would never see it).
        let base = self.submitted;
        let chaos_events: Vec<(usize, chaos::ChaosEvent)> = self
            .chaos
            .as_ref()
            .map(|c| c.window(base, base + n_samples as u64))
            .unwrap_or_default();
        // SlowStage only delays; BitFlip kills a shard only when the mode
        // leaves the boundary scrub nothing better than a panic (Detect),
        // and that death is observed directly — as ShardLost settlements
        // or by the next heal pass — so neither is a blanket suspect.
        let chaos_suspects: Vec<usize> = chaos_events
            .iter()
            .filter(|(_, e)| {
                !matches!(e.kind, ChaosKind::SlowStage { .. } | ChaosKind::BitFlip { .. })
            })
            .map(|(_, e)| e.shard)
            .collect();
        let control = self.control.clone();
        let plane_pool = self.plane_pool.clone();
        let matrix_pool = self.matrix_pool.clone();
        let lane_width = self.lane_width;
        let sparse_cutoff = self.sparse_cutoff;
        let inputs = self.inputs;
        let pool_misses_before = self.plane_pool.misses();
        let mat_misses_before = self.matrix_pool.misses();
        // Assignment records (shard, n_results): the feeder appends one per
        // dispatched unit in stream order; the drainer follows them to know
        // which shard's output queue holds the next in-order results.
        // Unbounded — records are tiny and the feeder must never block on
        // bookkeeping while holding backpressured data channels.
        let (assign_tx, assign_rx) = std::sync::mpsc::channel::<(usize, usize)>();

        let outcomes = std::thread::scope(
            |scope| -> Result<Vec<Result<StreamResult, ServingError>>> {
                // Feeder: streams every sample to a shard (blocking on the
                // bounded channels = admission control), fires this
                // session's chaos injections at their exact sample indices,
                // and broadcasts control programs to every *live* shard at
                // sample boundaries (a dead shard catches up during its
                // rebuild by replaying the committed history). In
                // lane-batched mode consecutive samples are packed into one
                // lane group sent as a SpikeMatrix per timestep, and each
                // ready group goes to the live shard with the least
                // cumulative dispatched work; partial groups are flushed
                // before any broadcast or injection, so epoch and fault
                // positions are exact. The feeder is resilient by design —
                // a failed send marks the shard dead and moves on; it
                // records an assignment for every stream regardless (so the
                // drainer can settle the lost ones) and never errors.
                let feeder = scope.spawn(move || {
                    let mut alive = vec![true; n_cores];
                    // The single lane group under construction (consecutive
                    // stream ids + samples); unused on the single-sample path.
                    let mut pending: (Vec<usize>, Vec<&Sample>) = (Vec::new(), Vec::new());
                    // Cumulative dispatched step-cost per shard — the
                    // deterministic load model behind [`least_loaded`].
                    let mut load = vec![0u64; n_cores];
                    let mut injections = chaos_events.iter().peekable();
                    // Firing-rate-aware routing: a sample whose input density
                    // is below the cutoff skips lane packing entirely and
                    // streams as a single-sample plane sequence, where the
                    // layers' quiescence fast path elides most neuron work.
                    let is_sparse = |s: &Sample| {
                        sparse_cutoff.is_some_and(|cut| {
                            let slots = (s.t_steps * s.inputs).max(1) as f64;
                            (s.nnz() as f64) < cut * slots
                        })
                    };
                    let mut stream = 0usize;
                    for op in ops {
                        // Programs applied asynchronously through a ControlPlane
                        // handle land here, at the next sample boundary (group
                        // boundary in lane mode: the partial group goes first so
                        // already-admitted samples keep the old epoch).
                        let async_programs = control.take_pending();
                        if !async_programs.is_empty() {
                            dispatch_group(
                                &mut pending,
                                &senders,
                                &mut alive,
                                &mut load,
                                &assign_tx,
                                &matrix_pool,
                                lane_width,
                                inputs,
                            );
                            for (epoch, program) in async_programs {
                                broadcast_program(&senders, &mut alive, epoch, &program);
                            }
                        }
                        match op {
                            SessionOp::Submit(sample) => {
                                // Chaos injections scheduled at this sample's
                                // admission fire first, after flushing the
                                // pending group — every earlier stream's
                                // position relative to the fault is exact.
                                while injections.peek().is_some_and(|(rel, _)| *rel <= stream) {
                                    let (_, e) = injections.next().expect("peeked");
                                    dispatch_group(
                                        &mut pending,
                                        &senders,
                                        &mut alive,
                                        &mut load,
                                        &assign_tx,
                                        &matrix_pool,
                                        lane_width,
                                        inputs,
                                    );
                                    if alive[e.shard]
                                        && senders[e.shard]
                                            .send(StageMsg::Chaos { kind: e.kind })
                                            .is_err()
                                    {
                                        alive[e.shard] = false;
                                    }
                                }
                                if lane_width == 1 {
                                    // Single-sample mode keeps the static
                                    // round-robin schedule — the conformance
                                    // fallback and oracle for the adaptive
                                    // path. A stream whose round-robin shard
                                    // has died reroutes to the next live one:
                                    // still a pure function of the op stream
                                    // and the fault point, so deterministic.
                                    let mut shard = stream % n_cores;
                                    for k in 0..n_cores {
                                        let cand = (stream + k) % n_cores;
                                        if alive[cand] {
                                            shard = cand;
                                            break;
                                        }
                                    }
                                    let _ = assign_tx.send((shard, 1));
                                    if alive[shard]
                                        && !feed_single(
                                            &senders[shard],
                                            stream,
                                            sample,
                                            &plane_pool,
                                        )
                                    {
                                        alive[shard] = false;
                                    }
                                    control.charge_spk_in(sample.nnz() as u64);
                                    stream += 1;
                                } else if is_sparse(sample) {
                                    // Sparse fallback: flush the pending group
                                    // first so results stay in submission
                                    // order, then stream this sample alone to
                                    // the least-loaded live shard as planes.
                                    dispatch_group(
                                        &mut pending,
                                        &senders,
                                        &mut alive,
                                        &mut load,
                                        &assign_tx,
                                        &matrix_pool,
                                        lane_width,
                                        inputs,
                                    );
                                    let shard = least_loaded(&load, &alive);
                                    load[shard] += sample.t_steps as u64 + 1;
                                    let _ = assign_tx.send((shard, 1));
                                    if alive[shard]
                                        && !feed_single(
                                            &senders[shard],
                                            stream,
                                            sample,
                                            &plane_pool,
                                        )
                                    {
                                        alive[shard] = false;
                                    }
                                    control.charge_spk_in(sample.nnz() as u64);
                                    stream += 1;
                                } else {
                                    pending.0.push(stream);
                                    pending.1.push(*sample);
                                    control.charge_spk_in(sample.nnz() as u64);
                                    stream += 1;
                                    if pending.1.len() == lane_width {
                                        dispatch_group(
                                            &mut pending,
                                            &senders,
                                            &mut alive,
                                            &mut load,
                                            &assign_tx,
                                            &matrix_pool,
                                            lane_width,
                                            inputs,
                                        );
                                    }
                                }
                            }
                            SessionOp::Reconfig(program) => {
                                dispatch_group(
                                    &mut pending,
                                    &senders,
                                    &mut alive,
                                    &mut load,
                                    &assign_tx,
                                    &matrix_pool,
                                    lane_width,
                                    inputs,
                                );
                                let (drained, epoch, program) =
                                    control.commit_in_band(program.clone());
                                for (e, p) in drained {
                                    broadcast_program(&senders, &mut alive, e, &p);
                                }
                                broadcast_program(&senders, &mut alive, epoch, &program);
                            }
                        }
                    }
                    dispatch_group(
                        &mut pending,
                        &senders,
                        &mut alive,
                        &mut load,
                        &assign_tx,
                        &matrix_pool,
                        lane_width,
                        inputs,
                    );
                    // `assign_tx` drops here, which is what ends the drainer's
                    // record iteration once every queued result is harvested.
                });

                // Drainer (this thread): follows the feeder's assignment
                // records in dispatch order. Units (groups or singles) pack
                // consecutive stream ids and each shard's pipeline is FIFO,
                // so the next `n` in-order results are always at the head
                // of the recorded shard's output queue — popping record by
                // record restores global order regardless of how the load
                // balancer scattered units across shards. A disconnected
                // output channel is the death cascade completing: the
                // record's remaining streams (and every later record on
                // that shard) were in the dying FIFO behind the fault, and
                // each settles as exactly one typed ShardLost outcome —
                // the surviving shards' records keep draining normally.
                // recv_timeout is a liveness bound, not a latency budget:
                // it only fires for a shard wedged for an hour, which is
                // then settled as lost rather than hanging the session.
                let mut outcomes: Vec<Result<StreamResult, ServingError>> =
                    Vec::with_capacity(n_samples);
                for (shard, n) in assign_rx.iter() {
                    for _ in 0..n {
                        match self.shards[shard]
                            .out_rx
                            .recv_timeout(std::time::Duration::from_secs(3600))
                        {
                            Ok(r) => {
                                debug_assert_eq!(
                                    r.stream_id,
                                    outcomes.len(),
                                    "shard FIFO order violated"
                                );
                                self.control.charge_spk_out(r.spikes_total);
                                outcomes.push(Ok(r));
                            }
                            Err(_) => {
                                outcomes
                                    .push(Err(ServingError::ShardLost { shard, resumable: true }));
                            }
                        }
                    }
                }
                // The feeder is infallible and joined explicitly (never
                // `expect`ed): a panic there must become a typed error, not
                // a process abort.
                if let Err(payload) = feeder.join() {
                    return Err(ServingError::WorkerPanicked {
                        worker: "session feeder".to_string(),
                        message: panic_message(payload),
                    }
                    .into());
                }
                // Backstop: the feeder emits exactly one record slot per
                // submitted sample and the drainer settles every slot, so
                // a shortfall here is a scheduler bug, not a shard failure.
                anyhow::ensure!(
                    outcomes.len() == n_samples,
                    "serving session settled {} of {n_samples} streams",
                    outcomes.len()
                );
                Ok(outcomes)
            },
        );

        self.submitted += n_samples as u64;
        match outcomes {
            Ok(outcomes) => {
                let mut suspects = chaos_suspects;
                let mut lost_any = false;
                for outcome in &outcomes {
                    match outcome {
                        Ok(r) => {
                            self.completed += 1;
                            self.activity.add(&r.stats);
                        }
                        Err(ServingError::ShardLost { shard, .. }) => {
                            lost_any = true;
                            suspects.push(*shard);
                        }
                        Err(_) => {}
                    }
                }
                if !lost_any {
                    // Zero-alloc invariant: the pre-filled pool covers the
                    // engine's maximum in-flight footprint, so steady-state
                    // streaming must not have allocated a single plane.
                    // (A dying shard drops its in-flight buffers, so the
                    // invariant is only asserted on loss-free sessions;
                    // recovery refills the pools to the construction bound
                    // before the next session is admitted.)
                    debug_assert_eq!(
                        self.plane_pool.misses(),
                        pool_misses_before,
                        "steady-state streaming allocated spike planes (pool underprovisioned)"
                    );
                    debug_assert_eq!(
                        self.matrix_pool.misses(),
                        mat_misses_before,
                        "steady-state lane streaming allocated spike matrices (pool underprovisioned)"
                    );
                }
                // Supervised recovery: every shard that died this session —
                // whether it lost streams or its fault landed after its
                // last assigned one — is rebuilt before this returns, so
                // the engine hands back all-Healthy (or poisons itself if
                // a rebuild is impossible).
                suspects.sort_unstable();
                suspects.dedup();
                suspects.retain(|&d| self.shards[d].in_tx.is_some());
                if !suspects.is_empty() {
                    self.recover_or_poison(&suspects)?;
                }
                Ok(outcomes)
            }
            Err(e) => {
                // Whole-engine failure (feeder panic or scheduler bug):
                // in-flight state is indeterminate, so poison and shut
                // down — but stay droppable (Drop re-runs the idempotent
                // shutdown).
                self.poisoned = true;
                self.shutdown();
                Err(e)
            }
        }
    }

    // ---- supervision ----------------------------------------------------

    /// Install a deterministic fault schedule (see [`chaos`]). Event
    /// sample indices are engine-lifetime (`submitted`-relative), so a
    /// schedule installed on a fresh engine addresses global sample
    /// counts regardless of how traffic is split into sessions.
    pub fn install_chaos(&mut self, schedule: ChaosSchedule) {
        self.chaos = Some(schedule);
    }

    /// Per-shard supervision state. All `Healthy` between sessions unless
    /// the engine is poisoned; the transient states are observable from
    /// telemetry mirrors taken inside a recovery pass.
    pub fn shard_health(&self) -> Vec<ShardHealth> {
        self.health.clone()
    }

    /// Shards rebuilt from a checkpoint over the engine's lifetime.
    pub fn recoveries(&self) -> u64 {
        self.recoveries
    }

    /// Shards quarantined over the engine's lifetime. Equals
    /// [`ServingEngine::recoveries`] unless a rebuild failed (which
    /// poisons the engine with the quarantine still counted).
    pub fn quarantines(&self) -> u64 {
        self.quarantines
    }

    /// The SEU-integrity level every stage runs under
    /// ([`ServingOptions::integrity`]).
    pub fn integrity_mode(&self) -> IntegrityMode {
        self.integrity
    }

    /// Lifetime integrity tally across every stage of every shard:
    /// `(scrubbed_blocks, corrected, detected)` — synaptic-memory blocks
    /// verified by the background scrub, single-bit flips repaired in
    /// place (SECDED, `Correct` mode), and detected-uncorrectable
    /// corruptions (each of which quarantined its shard for a checkpoint
    /// rebuild).
    pub fn integrity_counters(&self) -> (u64, u64, u64) {
        (
            self.scrub_ledger.scrubbed_blocks(),
            self.scrub_ledger.corrected(),
            self.scrub_ledger.detected(),
        )
    }

    /// Samples completed since the live recovery point was fenced — the
    /// work a shard rebuild would discard right now (its lost-stream bound
    /// is the in-flight window, but its *replay* distance is this).
    pub fn checkpoint_age_samples(&self) -> u64 {
        self.checkpoint.as_ref().map_or(0, |c| self.completed.saturating_sub(c.completed))
    }

    /// Cumulative wall-clock the engine has spent in degraded mode (one or
    /// more shards not `Healthy`, i.e. inside recovery passes).
    pub fn degraded_duration(&self) -> Duration {
        self.degraded
    }

    /// Detection→re-admission latency of every completed shard recovery,
    /// in milliseconds — the distribution `repro chaos-soak` reports as
    /// p50/p99.
    pub fn recovery_latencies_ms(&self) -> &[f64] {
        &self.recovery_ms
    }

    /// Shards whose pipeline has died (still admitting, but some stage or
    /// collector thread has exited) — the supervisor's detection
    /// predicate. A dying shard's threads cascade out within microseconds
    /// of the fault, so one finished thread is a reliable death signal.
    fn dead_shards(&self) -> Vec<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.in_tx.is_some() && s.threads.iter().any(|t| t.is_finished()))
            .map(|(i, _)| i)
            .collect()
    }

    /// Detect and rebuild every dead shard; returns how many were
    /// recovered (0 when all shards are healthy). Runs automatically
    /// before and after every session
    /// ([`ServingEngine::run_session_outcomes`]); exposed for callers that
    /// want to heal eagerly between sessions. On a failed rebuild the
    /// engine poisons itself, shuts down, and returns the error.
    pub fn heal(&mut self) -> Result<usize> {
        if self.poisoned {
            return Ok(0);
        }
        let dead = self.dead_shards();
        if dead.is_empty() {
            return Ok(0);
        }
        self.recover_or_poison(&dead)
    }

    fn recover_or_poison(&mut self, dead: &[usize]) -> Result<usize> {
        match self.recover(dead) {
            Ok(n) => Ok(n),
            Err(e) => {
                self.poisoned = true;
                self.shutdown();
                Err(e.context("shard recovery failed; engine poisoned"))
            }
        }
    }

    /// Quarantine → teardown → rebuild-from-checkpoint → replay →
    /// re-admit, for each listed shard.
    ///
    /// The rebuild is bit-exact by construction: checkpoints are fenced at
    /// sample-group boundaries where every membrane is settled to rest, so
    /// registers + packed weights + epoch are the *complete* state; the
    /// import fence restores those, and the committed-program history
    /// replays every epoch after the checkpoint (idempotently — cfg
    /// writes are absolute, wt swaps are whole payloads). A rebuilt shard
    /// is indistinguishable from one that never died.
    fn recover(&mut self, dead: &[usize]) -> Result<usize> {
        let window = Instant::now();
        let ckpt_epoch = match &self.checkpoint {
            Some(c) => c.epoch,
            None => anyhow::bail!("no recovery point (construction checkpoint missing)"),
        };
        let mut recovered = 0usize;
        for &d in dead {
            if self.shards[d].in_tx.is_none() {
                continue; // shut down, not supervised
            }
            self.health[d] = ShardHealth::Quarantined;
            self.quarantines += 1;
            let t0 = Instant::now();
            // Teardown: close the chain, keep the output side drained so a
            // collector blocked on a full channel can always exit, and
            // reap every thread. Bounded — a shard that stays wedged past
            // the deadline (a stall far beyond the chaos harness's scales)
            // fails recovery instead of hanging the supervisor.
            self.shards[d].in_tx = None;
            let deadline = Instant::now() + Duration::from_secs(30);
            loop {
                while self.shards[d].out_rx.try_recv().is_ok() {}
                if self.shards[d].threads.iter().all(|t| t.is_finished()) {
                    break;
                }
                anyhow::ensure!(Instant::now() < deadline, "shard {d} wedged during teardown");
                std::thread::sleep(Duration::from_millis(2));
            }
            for t in self.shards[d].threads.drain(..) {
                // Panic payloads were already settled as typed ShardLost
                // outcomes; joining here only releases the threads.
                let _ = t.join();
            }
            while self.shards[d].out_rx.try_recv().is_ok() {}
            // Rebuild: respawn the stage chain under the checkpoint's
            // register file, restore its packed weights and neuron banks
            // through the import fence, seed the collector's epoch tag,
            // then replay every committed program after the checkpoint
            // epoch (chaos injections in the history are skipped — they
            // are faults, not configuration).
            self.health[d] = ShardHealth::Rebuilding;
            let ckpt = self.checkpoint.as_ref().expect("checked above");
            let states = Arc::new(ckpt.layers[d].clone());
            let regs = states[0].register_file(self.config.qspec)?;
            let zeros: Vec<Vec<i32>> =
                self.config.layers().iter().map(|l| vec![0i32; l.fan_in * l.neurons]).collect();
            let mut layers = build_layers(&self.config, &zeros)?;
            for layer in &mut layers {
                layer.set_integrity(self.integrity);
            }
            let scrub = ScrubPlan { stride: self.scrub_stride, ledger: self.scrub_ledger.clone() };
            let shard = spawn_shard(
                layers,
                &regs,
                self.queue_depth,
                self.lane_width,
                self.wants_planes,
                self.max_width,
                self.outputs,
                &self.plane_pool,
                &self.matrix_pool,
                &scrub,
            );
            let tx = shard.in_tx.as_ref().expect("freshly spawned shard").clone();
            let n_states = states.len();
            let (ack_tx, ack_rx) = std::sync::mpsc::channel();
            tx.send(StageMsg::Import { states, reply: ack_tx })
                .map_err(|_| anyhow::anyhow!("rebuilt shard {d} died before import"))?;
            for k in 0..n_states {
                ack_rx.recv_timeout(Duration::from_secs(60)).map_err(|_| {
                    anyhow::anyhow!("rebuilt shard {d} stage {k} never acked its import")
                })?;
            }
            for (e, p) in self.control.programs_since(ckpt_epoch) {
                if p.chaos_panic_stage.is_some() {
                    // Faults in the history are injections, not config.
                    continue;
                }
                tx.send(StageMsg::Reconfig { epoch: e, program: p })
                    .map_err(|_| anyhow::anyhow!("rebuilt shard {d} died during replay"))?;
            }
            // Epoch-tag sync: collectors tag results with the last Reconfig
            // epoch they saw, and the fresh collector saw none of the
            // pre-checkpoint (pruned) or chaos (skipped) epochs. Close the
            // replay with an empty program carrying the committed epoch, so
            // the rebuilt shard tags results identically to one that never
            // died. (If programs are admitted-but-pending right now, every
            // shard — rebuilt or not — re-syncs at the next session's
            // broadcast; replayed programs re-applying then is sound
            // because application is idempotent.)
            tx.send(StageMsg::Reconfig {
                epoch: self.control.epoch(),
                program: Arc::new(ReconfigProgram::new()),
            })
            .map_err(|_| anyhow::anyhow!("rebuilt shard {d} died during epoch sync"))?;
            self.shards[d] = shard;
            self.health[d] = ShardHealth::Healthy;
            self.recoveries += 1;
            recovered += 1;
            self.recovery_ms.push(t0.elapsed().as_secs_f64() * 1000.0);
        }
        // The dead shards took their in-flight pool buffers down with them
        // (queued planes/matrices drop with the channels). Top the shared
        // pools back up to the construction prefill bound so the zero-miss
        // invariant holds for traffic admitted after re-admission.
        if self.wants_planes {
            for _ in self.plane_pool.available()..self.pool_target {
                self.plane_pool.put(SpikePlane::with_line_capacity(self.max_width));
            }
        }
        if self.lane_width > 1 {
            for _ in self.matrix_pool.available()..self.pool_target {
                self.matrix_pool.put(SpikeMatrix::with_line_capacity(self.max_width));
            }
        }
        self.degraded += window.elapsed();
        Ok(recovered)
    }

    /// Fence the complete per-shard layer state through the per-shard
    /// FIFOs (shared by [`ServingEngine::snapshot`] and the supervisor's
    /// in-memory checkpoints). Bounded-poll per stage: a shard dying
    /// *under the fence* is detected within milliseconds (one of its
    /// threads has finished) instead of stalling for the liveness budget.
    fn export_shards(&self) -> Result<Vec<Vec<LayerExport>>> {
        let num_layers = self.config.num_layers();
        let mut layers = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            let tx = match &shard.in_tx {
                Some(tx) => tx.clone(),
                None => return Err(ServingError::ShutDown.into()),
            };
            let (reply_tx, reply_rx) = std::sync::mpsc::channel();
            tx.send(StageMsg::Export { reply: reply_tx })
                .map_err(|_| anyhow::anyhow!("serving shard died"))?;
            // Stage order is the FIFO order: layer k's export arrives k-th.
            let mut states = Vec::with_capacity(num_layers);
            for k in 0..num_layers {
                let deadline = Instant::now() + Duration::from_secs(60);
                let state = loop {
                    match reply_rx.recv_timeout(Duration::from_millis(20)) {
                        Ok(s) => break s,
                        Err(_) => {
                            anyhow::ensure!(
                                !shard.threads.iter().any(|t| t.is_finished()),
                                "shard died under the export fence at stage {k}"
                            );
                            anyhow::ensure!(
                                Instant::now() < deadline,
                                "stage {k} never exported its state"
                            );
                        }
                    }
                };
                states.push(state);
            }
            layers.push(states);
        }
        Ok(layers)
    }

    /// Fence a fresh in-memory recovery point and prune the control
    /// plane's program history up to its epoch (no rebuild can ever
    /// replay past a newer checkpoint, so older programs are dead weight).
    pub fn take_checkpoint(&mut self) -> Result<()> {
        let layers = self.export_shards()?;
        let epoch = self.control.epoch();
        self.checkpoint = Some(Checkpoint { epoch, completed: self.completed, layers });
        self.control.prune_history(epoch);
        Ok(())
    }

    /// Refresh the recovery point if the checkpoint cadence is due. A
    /// shard dying *under the export fence* is handled here: the failed
    /// fence names no usable state, so the supervisor waits out the death
    /// cascade, heals from the previous checkpoint, and re-fences.
    fn maybe_checkpoint(&mut self) -> Result<()> {
        let due = match &self.checkpoint {
            None => true,
            Some(c) => self.completed.saturating_sub(c.completed) >= self.checkpoint_interval,
        };
        if !due {
            return Ok(());
        }
        if self.take_checkpoint().is_ok() {
            return Ok(());
        }
        for _ in 0..400 {
            if !self.dead_shards().is_empty() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        anyhow::ensure!(self.heal()? > 0, "checkpoint fence failed with no dead shard to heal");
        self.take_checkpoint()
    }

    /// Capture the complete engine state as a versioned
    /// [`Connectome`](super::connectome::Connectome).
    ///
    /// The snapshot fence rides the same per-shard FIFO as the data
    /// ([`StageMsg`] `Export`), so it is taken at a **sample-group
    /// boundary**: every admitted stream has fully drained, none is
    /// queued behind it, and nothing is discarded. Callers that interleave
    /// snapshots with traffic (the network pump) serialize them between
    /// [`ServingEngine::run_session`] calls, which is exactly that
    /// boundary. `submitted == completed` in the result is the in-flight
    /// ledger's quiesce-point invariant.
    pub fn snapshot(&mut self) -> Result<super::connectome::Connectome> {
        anyhow::ensure!(
            !self.poisoned,
            "serving engine poisoned by an earlier failed batch; nothing coherent to snapshot"
        );
        let num_layers = self.config.num_layers();
        let layers = self.export_shards()?;
        Ok(super::connectome::Connectome {
            qspec: self.config.qspec,
            mem: self.config.mem,
            cores: self.shards.len() as u16,
            lane_width: self.lane_width as u16,
            sizes: self.config.sizes().iter().map(|&s| s as u32).collect(),
            topologies: (0..num_layers).map(|k| self.config.layer(k).topology).collect(),
            epoch: self.control.epoch(),
            bus: self.control.bus(),
            activity: self.activity,
            submitted: self.submitted,
            completed: self.completed,
            layers,
        })
    }

    /// Revive a snapshot as a fresh, live engine — bit-exact: geometry,
    /// registers, packed weights, neuron banks (single-sample and
    /// lane-major), config epoch, and all ledgers continue exactly where
    /// [`ServingEngine::snapshot`] fenced them. The differential gate in
    /// `tests/connectome.rs` proves run-k-then-restore ≡ uninterrupted.
    ///
    /// Everything is validated *before* any stage applies anything (the
    /// decoded geometry rebuilds the [`ModelConfig`]; weight payloads are
    /// checked against the topology stores' packed sizes and the
    /// quantization range), so a bad snapshot is a typed error with no
    /// partially-restored engine left behind.
    pub fn from_connectome(c: &super::connectome::Connectome) -> Result<ServingEngine> {
        let sizes: Vec<usize> = c.sizes.iter().map(|&s| s as usize).collect();
        let config = ModelConfig::with_topologies(&sizes, &c.topologies, c.qspec)?.with_mem(c.mem);
        let mut regs = RegisterFile::new(c.qspec);
        let vector = c.register_vector()?;
        let program: Vec<(usize, i32)> = vector.iter().copied().enumerate().collect();
        regs.apply_program(&program)?;
        // Zero dense weights satisfy every topology mask; the real packed
        // payloads land through the Import fence below.
        let zeros: Vec<Vec<i32>> =
            config.layers().iter().map(|l| vec![0i32; l.fan_in * l.neurons]).collect();
        let options = ServingOptions::with_lanes(c.cores as usize, c.lane_width as usize);
        let mut engine = ServingEngine::new(&config, &zeros, &regs, options)?;
        anyhow::ensure!(
            c.layers.len() == engine.shards.len(),
            "snapshot has {} shard sections for a {}-shard engine",
            c.layers.len(),
            engine.shards.len()
        );
        let packed_sizes = engine.control.packed_sizes().to_vec();
        for states in &c.layers {
            // The decoder checked neuron-bank arity against the snapshot's
            // own geometry; weight payloads are validated here against the
            // rebuilt topology stores, reusing the control plane's wt_in
            // contract so Import cannot fail stage-side.
            let mut probe = ReconfigProgram::new();
            for (k, st) in states.iter().enumerate() {
                probe = probe.swap_weights(k, st.weights.clone());
            }
            probe.validate_weights(config.qspec, &packed_sizes)?;
        }
        for (shard, states) in engine.shards.iter().zip(&c.layers) {
            let tx = shard.in_tx.as_ref().expect("freshly built engine").clone();
            let (ack_tx, ack_rx) = std::sync::mpsc::channel();
            tx.send(StageMsg::Import { states: Arc::new(states.clone()), reply: ack_tx })
                .map_err(|_| anyhow::anyhow!("serving shard died"))?;
            for k in 0..packed_sizes.len() {
                ack_rx
                    .recv_timeout(std::time::Duration::from_secs(60))
                    .map_err(|_| anyhow::anyhow!("stage {k} never acked its import"))?;
            }
        }
        engine.control.seed(c.epoch, c.bus);
        engine.submitted = c.submitted;
        engine.completed = c.completed;
        engine.activity = c.activity;
        // The construction checkpoint fenced the zero-weight scaffold;
        // re-fence so the supervisor's recovery point reflects the
        // restored weights, neuron banks, epoch, and completion ledger.
        engine.take_checkpoint()?;
        Ok(engine)
    }

    /// Drop the admission side and join all stage threads. Keeps draining
    /// the output channels while waiting so a collector blocked on a full
    /// channel (possible after a poisoned batch) can always make progress.
    pub fn shutdown(&mut self) {
        for shard in &mut self.shards {
            shard.in_tx = None; // closes the chain; stages drain and exit
        }
        loop {
            let mut all_done = true;
            for shard in &self.shards {
                while shard.out_rx.try_recv().is_ok() {}
                if shard.threads.iter().any(|t| !t.is_finished()) {
                    all_done = false;
                }
            }
            if all_done {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        for shard in &mut self.shards {
            for t in shard.threads.drain(..) {
                let _ = t.join();
            }
        }
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::registers::REG_VTH;
    use crate::datasets::{Dataset, Split};
    use crate::fixed::Q5_3;
    use crate::hdl::Core;

    fn setup() -> (ModelConfig, Vec<Vec<i32>>, RegisterFile, Vec<Sample>) {
        let cfg = ModelConfig::parse_arch("256x24x10", Q5_3).unwrap();
        let mut rng = crate::datasets::rng::XorShift64Star::new(0x5E21);
        let weights: Vec<Vec<i32>> = cfg
            .layers()
            .iter()
            .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(15) as i32 - 7).collect())
            .collect();
        let regs = RegisterFile::new(Q5_3);
        let samples: Vec<Sample> =
            (0..9).map(|i| Dataset::Smnist.sample(i, Split::Test, 6)).collect();
        (cfg, weights, regs, samples)
    }

    #[test]
    fn engine_matches_sequential_core_bitexact() {
        let (cfg, weights, regs, samples) = setup();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        for cores in [1usize, 2, 3] {
            let mut engine =
                ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(cores))
                    .unwrap();
            let out = engine.run_batch(&samples).unwrap();
            assert_eq!(out.len(), samples.len());
            for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
                let seq = core.run(s);
                assert_eq!(r.counts, seq.counts, "cores={cores} sample {i}");
                assert_eq!(r.prediction, seq.prediction, "cores={cores} sample {i}");
                assert_eq!(r.stats, seq.stats, "cores={cores} sample {i} activity ledger");
                assert_eq!(r.stream_id, i);
                assert_eq!(r.epoch, 0);
            }
        }
    }

    #[test]
    fn engine_is_reusable_across_batches() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let a = engine.run_batch(&samples).unwrap();
        let b = engine.run_batch(&samples).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.counts, y.counts, "state leaked across batches");
        }
        assert_eq!(engine.stats(), (2 * samples.len() as u64, 2 * samples.len() as u64));
    }

    #[test]
    fn small_queue_depth_still_completes() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions { cores: 2, queue_depth: 1, ..Default::default() },
        )
        .unwrap();
        let out = engine.run_batch(&samples).unwrap();
        assert_eq!(out.len(), samples.len());
    }

    #[test]
    fn empty_batch_and_bad_options() {
        let (cfg, weights, regs, _) = setup();
        assert!(ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(0)).is_err());
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::default()).unwrap();
        assert!(engine.run_batch(&[]).unwrap().is_empty());
        let bad = Sample { spikes: vec![0; 4], t_steps: 1, inputs: 4, label: 0 };
        assert!(engine.run_batch(&[bad]).is_err());
    }

    #[test]
    fn sparse_topology_engine_is_bitexact_and_reports_footprint() {
        use crate::config::Topology;
        let cfg = ModelConfig::with_topologies(
            &[40, 40, 10],
            &[Topology::Gaussian { radius: 1 }, Topology::AllToAll],
            Q5_3,
        )
        .unwrap();
        let mut rng = crate::datasets::rng::XorShift64Star::new(0x5EAC);
        let weights: Vec<Vec<i32>> = cfg
            .layers()
            .iter()
            .map(|l| {
                let mask = l.topology.mask(l.fan_in, l.neurons).unwrap();
                mask.iter()
                    .map(|&a| if a == 0 { 0 } else { rng.below(15) as i32 - 7 })
                    .collect()
            })
            .collect();
        let regs = RegisterFile::new(Q5_3);
        let samples: Vec<Sample> = (0..6)
            .map(|_| {
                let t_steps = 8;
                let spikes = (0..t_steps * 40).map(|_| (rng.uniform() < 0.3) as u8).collect();
                Sample { spikes, t_steps, inputs: 40, label: 0 }
            })
            .collect();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        // Banded first layer: 3*40 - 2 words, not the dense 1600.
        assert_eq!(engine.synapse_words_per_shard(), (3 * 40 - 2) + 40 * 10);
        assert_eq!(engine.synapse_words_per_shard(), cfg.total_synapses());
        let out = engine.run_batch(&samples).unwrap();
        for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
            assert_eq!(r.counts, core.run(s).counts, "sample {i}");
        }
    }

    #[test]
    fn streaming_is_zero_alloc_after_construction() {
        // The recycled-plane pool is pre-filled at construction, so no
        // batch — first or later, even at queue_depth 1 — may allocate a
        // single spike plane on the streaming path.
        let (cfg, weights, regs, samples) = setup();
        for depth in [1usize, 4, 64] {
            let mut engine = ServingEngine::new(
                &cfg,
                &weights,
                &regs,
                ServingOptions { cores: 2, queue_depth: depth, ..Default::default() },
            )
            .unwrap();
            for _ in 0..3 {
                engine.run_batch(&samples).unwrap();
            }
            assert_eq!(
                engine.plane_pool_misses(),
                0,
                "queue_depth {depth}: streaming path allocated planes"
            );
        }
    }

    /// Ragged samples (unequal stream lengths) for the lane-batched gates.
    fn ragged_samples(count: usize) -> Vec<Sample> {
        (0..count as u64)
            .map(|i| {
                let mut s = Dataset::Smnist.sample(i, Split::Test, 3 + (i % 5) as usize);
                s.label = i as usize % 10;
                s
            })
            .collect()
    }

    #[test]
    fn lane_batched_engine_matches_single_sample_engine_bitexact() {
        // Lane widths 2 / 7 / 64 on ragged batches (count not a multiple of
        // the width, unequal stream lengths) must be bit-identical — counts,
        // prediction, stream order, epoch, and the full per-stream activity
        // ledger — to the single-sample engine and the sequential core.
        let (cfg, weights, regs, _) = setup();
        let samples = ragged_samples(13);
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        for cores in [1usize, 2] {
            for lane_width in [2usize, 7, 64] {
                let mut engine = ServingEngine::new(
                    &cfg,
                    &weights,
                    &regs,
                    ServingOptions::with_lanes(cores, lane_width),
                )
                .unwrap();
                assert_eq!(engine.lane_width(), lane_width);
                let out = engine.run_batch(&samples).unwrap();
                assert_eq!(out.len(), samples.len());
                for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
                    let seq = core.run(s);
                    let ctx = format!("cores={cores} lanes={lane_width} sample {i}");
                    assert_eq!(r.stream_id, i, "{ctx}");
                    assert_eq!(r.counts, seq.counts, "{ctx}");
                    assert_eq!(r.prediction, seq.prediction, "{ctx}");
                    assert_eq!(r.stats, seq.stats, "{ctx} activity ledger");
                    assert_eq!(r.epoch, 0, "{ctx}");
                }
            }
        }
    }

    #[test]
    fn lane_batched_engine_is_reusable_and_zero_alloc() {
        let (cfg, weights, regs, _) = setup();
        let samples = ragged_samples(10);
        for depth in [1usize, 4] {
            let mut engine = ServingEngine::new(
                &cfg,
                &weights,
                &regs,
                ServingOptions {
                    cores: 2,
                    queue_depth: depth,
                    lane_width: 4,
                    ..Default::default()
                },
            )
            .unwrap();
            let a = engine.run_batch(&samples).unwrap();
            let b = engine.run_batch(&samples).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.counts, y.counts, "lane state leaked across batches");
            }
            assert_eq!(
                engine.matrix_pool_misses(),
                0,
                "queue_depth {depth}: lane streaming allocated matrices"
            );
            assert_eq!(engine.plane_pool_misses(), 0, "queue_depth {depth}");
        }
    }

    #[test]
    fn least_loaded_lane_dispatch_is_bitexact_and_deterministic() {
        // Heavily skewed stream lengths create hot and idle shards; the
        // least-dispatched-work balancer must still return bit-exact,
        // in-order results — and because the schedule is a pure function
        // of the op stream (never of thread timing), two identical
        // engines must agree on every result and on their final
        // connectome images (per-shard lane-bank shapes included).
        let (cfg, weights, regs, _) = setup();
        let samples: Vec<Sample> = (0..17u64)
            .map(|i| Dataset::Smnist.sample(i, Split::Test, 1 + ((i * i * 7) % 23) as usize))
            .collect();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        for cores in [2usize, 3] {
            for lane_width in [3usize, 8] {
                let opts = ServingOptions::with_lanes(cores, lane_width);
                let mut engine = ServingEngine::new(&cfg, &weights, &regs, opts).unwrap();
                let mut twin = ServingEngine::new(&cfg, &weights, &regs, opts).unwrap();
                let out = engine.run_batch(&samples).unwrap();
                let out_twin = twin.run_batch(&samples).unwrap();
                assert_eq!(out.len(), samples.len());
                for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
                    let seq = core.run(s);
                    let ctx = format!("cores={cores} lanes={lane_width} sample {i}");
                    assert_eq!(r.stream_id, i, "{ctx}");
                    assert_eq!(r.counts, seq.counts, "{ctx}");
                    assert_eq!(r.stats, seq.stats, "{ctx} activity ledger");
                    let t = &out_twin[i];
                    assert_eq!(r.counts, t.counts, "{ctx}: twin diverged");
                    assert_eq!(r.stats, t.stats, "{ctx}: twin ledger diverged");
                }
                assert_eq!(
                    engine.snapshot().unwrap(),
                    twin.snapshot().unwrap(),
                    "cores={cores} lanes={lane_width}: shard schedule diverged between twins"
                );
            }
        }
    }

    #[test]
    fn sparse_cutoff_fallback_is_bitexact_and_zero_alloc() {
        // A lane engine with a firing-density cutoff routes near-silent
        // samples down the single-sample quiescence path; results must be
        // bit-identical to the sequential core and to a cutoff-less lane
        // engine, in order, with both recycled-buffer pools staying warm.
        let (cfg, weights, regs, _) = setup();
        let mut rng = crate::datasets::rng::XorShift64Star::new(0x51AB);
        let samples: Vec<Sample> = (0..12u64)
            .map(|i| {
                if i % 3 == 0 {
                    // Near-silent: a handful of spikes over 9 timesteps
                    // (density « 5%), below the routing cutoff.
                    let t_steps = 9;
                    let mut spikes = vec![0u8; t_steps * 256];
                    for _ in 0..4 {
                        let slot = rng.below((t_steps * 256) as u64) as usize;
                        spikes[slot] = 1;
                    }
                    Sample { spikes, t_steps, inputs: 256, label: 0 }
                } else {
                    Dataset::Smnist.sample(i, Split::Test, 6)
                }
            })
            .collect();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        let mut dense =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_lanes(2, 4)).unwrap();
        let mut routed = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions::with_lanes(2, 4).sparse_cutoff(0.05),
        )
        .unwrap();
        assert_eq!(routed.sparse_cutoff(), Some(0.05));
        let base = dense.run_batch(&samples).unwrap();
        let out = routed.run_batch(&samples).unwrap();
        assert_eq!(out.len(), samples.len());
        for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
            let seq = core.run(s);
            assert_eq!(r.stream_id, i, "sample {i}");
            assert_eq!(r.counts, seq.counts, "sample {i} vs sequential core");
            assert_eq!(r.stats, seq.stats, "sample {i} activity ledger");
            assert_eq!(r.counts, base[i].counts, "sample {i} vs cutoff-less lane engine");
        }
        assert_eq!(routed.plane_pool_misses(), 0, "sparse fallback allocated planes");
        assert_eq!(routed.matrix_pool_misses(), 0, "lane path allocated matrices");
    }

    #[test]
    fn lane_batched_in_band_reconfig_splits_epochs_deterministically() {
        // A reconfiguration mid-session on a lane-batched engine must land
        // exactly between samples 3 and 4 even though 3 is mid-group (the
        // feeder flushes partial groups before broadcasting).
        let (cfg, weights, regs, _) = setup();
        let samples = ragged_samples(8);
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_lanes(2, 64)).unwrap();
        let mut raised = regs.clone();
        raised.set_vth(4.0).unwrap();
        let ops: Vec<SessionOp> = samples[..3]
            .iter()
            .map(SessionOp::Submit)
            .chain(std::iter::once(SessionOp::Reconfig(ReconfigProgram::from_registers(
                &raised,
            ))))
            .chain(samples[3..].iter().map(SessionOp::Submit))
            .collect();
        let out = engine.run_session(&ops).unwrap();
        assert_eq!(out.len(), 8);
        assert!(out[..3].iter().all(|r| r.epoch == 0), "pre-reconfig samples at epoch 0");
        assert!(out[3..].iter().all(|r| r.epoch == 1), "post-reconfig samples at epoch 1");
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        for (i, s) in samples[..3].iter().enumerate() {
            assert_eq!(out[i].counts, core.run(s).counts, "epoch 0 sample {i}");
        }
        core.registers = raised;
        for (i, s) in samples[3..].iter().enumerate() {
            assert_eq!(out[3 + i].counts, core.run(s).counts, "epoch 1 sample {i}");
        }
    }

    #[test]
    fn lane_width_validated() {
        let (cfg, weights, regs, _) = setup();
        for lane_width in [0usize, 65] {
            assert!(
                ServingEngine::new(
                    &cfg,
                    &weights,
                    &regs,
                    ServingOptions { cores: 2, queue_depth: 8, lane_width, ..Default::default() },
                )
                .is_err(),
                "lane width {lane_width} must be rejected"
            );
        }
    }

    #[test]
    fn shutdown_is_idempotent() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let _ = engine.run_batch(&samples[..2]).unwrap();
        engine.shutdown();
        engine.shutdown();
    }

    #[test]
    fn in_band_reconfig_splits_epochs_deterministically() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(3)).unwrap();
        let mut raised = regs.clone();
        raised.set_vth(4.0).unwrap();
        let ops: Vec<SessionOp> = samples[..3]
            .iter()
            .map(SessionOp::Submit)
            .chain(std::iter::once(SessionOp::Reconfig(ReconfigProgram::from_registers(
                &raised,
            ))))
            .chain(samples[3..6].iter().map(SessionOp::Submit))
            .collect();
        let out = engine.run_session(&ops).unwrap();
        assert_eq!(out.len(), 6);
        assert!(out[..3].iter().all(|r| r.epoch == 0), "pre-reconfig samples at epoch 0");
        assert!(out[3..].iter().all(|r| r.epoch == 1), "post-reconfig samples at epoch 1");

        // Per epoch, bit-identical to a sequential core with that config.
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        for (i, s) in samples[..3].iter().enumerate() {
            assert_eq!(out[i].counts, core.run(s).counts, "epoch 0 sample {i}");
        }
        core.registers = raised;
        for (i, s) in samples[3..6].iter().enumerate() {
            assert_eq!(out[3 + i].counts, core.run(s).counts, "epoch 1 sample {i}");
        }
        assert_eq!(engine.epoch(), 1);
    }

    #[test]
    fn async_apply_lands_at_batch_boundary() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let control = engine.control_plane();
        let a = engine.run_batch(&samples[..4]).unwrap();
        assert!(a.iter().all(|r| r.epoch == 0));
        let epoch = control
            .apply(ReconfigProgram::new().write(REG_VTH, Q5_3.from_float(4.0)))
            .unwrap();
        assert_eq!(epoch, 1);
        let b = engine.run_batch(&samples[..4]).unwrap();
        assert!(b.iter().all(|r| r.epoch == 1), "pending program must land before the batch");
        // Raising the threshold can only reduce (or keep) spiking.
        let spikes_a: u64 = a.iter().map(|r| r.stats.spikes).sum();
        let spikes_b: u64 = b.iter().map(|r| r.stats.spikes).sum();
        assert!(spikes_b <= spikes_a, "vth raise increased spiking ({spikes_a} -> {spikes_b})");
    }

    #[test]
    fn weight_swap_on_live_engine_is_bitexact() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        // New last-layer weights, delivered packed (all-to-all: packed ==
        // dense row-major).
        let mut rng = crate::datasets::rng::XorShift64Star::new(0xBEEF);
        let new_last: Vec<i32> =
            (0..weights[1].len()).map(|_| rng.below(15) as i32 - 7).collect();
        let ops = [
            SessionOp::Submit(&samples[0]),
            SessionOp::Reconfig(ReconfigProgram::new().swap_weights(1, new_last.clone())),
            SessionOp::Submit(&samples[1]),
        ];
        let out = engine.run_session(&ops).unwrap();
        assert_eq!((out[0].epoch, out[1].epoch), (0, 1));

        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        assert_eq!(out[0].counts, core.run(&samples[0]).counts);
        core.load_weights(&[weights[0].clone(), new_last]).unwrap();
        assert_eq!(out[1].counts, core.run(&samples[1]).counts, "swapped weights diverged");
        // wt beats charged per shard on the same ledger as data traffic.
        let bus = engine.bus();
        assert_eq!(bus.wt_writes, 2 * weights[1].len() as u64);
        assert!(bus.spk_in_events > 0 && bus.beats() > bus.wt_writes);
    }

    #[test]
    fn panicked_worker_yields_typed_error_then_heals() {
        // PR 6 turned a stage panic from a process abort into a typed
        // error; the supervisor upgrades it again: the panic costs exactly
        // the streams behind it, surfaces as ShardLost, and the engine
        // rebuilds itself from the last checkpoint instead of dying. Here
        // the chaos program is broadcast, so *every* shard dies — the
        // worst case — and the engine must still come back bit-exact.
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let ops = [
            SessionOp::Submit(&samples[0]),
            SessionOp::Reconfig(ReconfigProgram::new().chaos_panic(1)),
            SessionOp::Submit(&samples[1]),
        ];
        let outcomes = engine.run_session_outcomes(&ops).unwrap();
        assert_eq!(outcomes.len(), 2);
        // Sample 0 fully preceded the fault in its shard's FIFO; sample 1
        // rode behind the panic broadcast on the other shard.
        assert!(outcomes[0].is_ok(), "pre-fault stream must survive");
        assert!(
            matches!(outcomes[1], Err(ServingError::ShardLost { resumable: true, .. })),
            "stream behind the fault settles as typed ShardLost"
        );
        // Self-healing: all shards Healthy again, recoveries counted, and
        // the next batch is bit-identical to a sequential core — tagged
        // with the chaos program's epoch, exactly like a never-died engine.
        assert!(engine.shard_health().iter().all(|h| *h == ShardHealth::Healthy));
        assert!(engine.recoveries() >= 1, "at least the lossy shard was rebuilt");
        assert_eq!(engine.recoveries(), engine.quarantines());
        let out = engine.run_batch(&samples[..4]).unwrap();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        for (i, (r, s)) in out.iter().zip(&samples[..4]).enumerate() {
            let seq = core.run(s);
            assert_eq!(r.counts, seq.counts, "healed engine diverged on sample {i}");
            assert_eq!(r.stats, seq.stats, "healed activity ledger diverged on sample {i}");
            assert_eq!(r.epoch, 1, "healed engine must tag the committed epoch");
        }
        drop(engine);
    }

    #[test]
    fn fail_fast_wrapper_reports_shard_lost_without_poisoning() {
        // run_session (the fail-fast view over run_session_outcomes)
        // returns the first ShardLost as its error — but the engine was
        // already healed by the outcomes pass and keeps serving.
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let ops = [
            SessionOp::Reconfig(ReconfigProgram::new().chaos_panic(0)),
            SessionOp::Submit(&samples[0]),
        ];
        let err = engine.run_session(&ops).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServingError>(), Some(ServingError::ShardLost { .. })),
            "expected ShardLost, got: {err:#}"
        );
        let out = engine.run_batch(&samples[..2]).unwrap();
        assert_eq!(out.len(), 2, "engine serves after the fail-fast error");
    }

    #[test]
    fn seeded_chaos_deaths_recover_bitexact_under_live_traffic() {
        // In-module twin of the tests/chaos_recovery.rs gate: a seeded
        // schedule of shard deaths across both shards, live traffic
        // throughout — every surviving stream bit-identical to the
        // sequential core, every lost stream exactly one typed ShardLost,
        // all shards Healthy at the end.
        let (cfg, weights, regs, samples) = setup();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        let mut engine = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions::with_cores(2).checkpoints_every(4),
        )
        .unwrap();
        engine.install_chaos(ChaosSchedule::seeded(0xFA11, 4, 24, 2, cfg.num_layers()));
        let mut losses = 0usize;
        for round in 0..3 {
            let outcomes = engine.run_batch_outcomes(&samples).unwrap();
            assert_eq!(outcomes.len(), samples.len(), "round {round}: every stream settles");
            for (i, (outcome, s)) in outcomes.iter().zip(&samples).enumerate() {
                match outcome {
                    Ok(r) => {
                        let seq = core.run(s);
                        assert_eq!(r.counts, seq.counts, "round {round} sample {i}");
                        assert_eq!(r.stats, seq.stats, "round {round} sample {i} ledger");
                    }
                    Err(ServingError::ShardLost { .. }) => losses += 1,
                    Err(e) => panic!("round {round} sample {i}: unexpected error {e}"),
                }
            }
            assert!(
                engine.shard_health().iter().all(|h| *h == ShardHealth::Healthy),
                "round {round}: engine must end all-Healthy"
            );
        }
        assert!(engine.recoveries() >= 2, "schedule must have killed shards");
        assert!(losses > 0, "deaths with live traffic must cost some streams");
        assert!(!engine.recovery_latencies_ms().is_empty());
        assert!(engine.degraded_duration() > Duration::ZERO);
    }

    #[test]
    fn rebuilt_shard_respects_pool_invariant() {
        // Satellite: the PlanePool/MatrixPool prefill bound assumed K
        // static shards; a re-admitted rebuilt shard must not trip the
        // zero-miss debug assertion. Exercised at queue_depth 1 and 8, in
        // both datapaths (loss-free rounds after recovery debug-assert
        // the zero-miss invariant internally on every batch).
        let (cfg, weights, regs, samples) = setup();
        for depth in [1usize, 8] {
            for lane_width in [1usize, 4] {
                let mut engine = ServingEngine::new(
                    &cfg,
                    &weights,
                    &regs,
                    ServingOptions {
                        cores: 2,
                        queue_depth: depth,
                        lane_width,
                        ..Default::default()
                    },
                )
                .unwrap();
                engine.install_chaos(ChaosSchedule::new(vec![chaos::ChaosEvent {
                    at_sample: 2,
                    shard: 0,
                    kind: ChaosKind::StagePanic { stage: 1 },
                }]));
                let _ = engine.run_batch_outcomes(&samples).unwrap();
                assert!(engine.recoveries() >= 1, "depth {depth} lanes {lane_width}");
                let before_planes = engine.plane_pool_misses();
                let before_mats = engine.matrix_pool_misses();
                for _ in 0..2 {
                    let out = engine.run_batch(&samples).unwrap();
                    assert_eq!(out.len(), samples.len());
                }
                assert_eq!(
                    engine.plane_pool_misses(),
                    before_planes,
                    "depth {depth} lanes {lane_width}: rebuild under-provisioned the plane pool"
                );
                assert_eq!(
                    engine.matrix_pool_misses(),
                    before_mats,
                    "depth {depth} lanes {lane_width}: rebuild under-provisioned the matrix pool"
                );
            }
        }
    }

    #[test]
    fn chaos_at_sample_zero_recovers_cleanly() {
        // Satellite edge case: the schedule fires before the very first
        // sample is admitted. The construction checkpoint must cover it —
        // every stream settles (no hang), survivors are bit-exact, and
        // the engine heals.
        let (cfg, weights, regs, samples) = setup();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        engine.install_chaos(ChaosSchedule::new(vec![chaos::ChaosEvent {
            at_sample: 0,
            shard: 0,
            kind: ChaosKind::StagePanic { stage: 0 },
        }]));
        let outcomes = engine.run_batch_outcomes(&samples).unwrap();
        assert_eq!(outcomes.len(), samples.len());
        assert!(
            matches!(outcomes[0], Err(ServingError::ShardLost { shard: 0, .. })),
            "stream 0 was admitted behind the sample-0 fault"
        );
        for (i, (outcome, s)) in outcomes.iter().zip(&samples).enumerate() {
            if let Ok(r) = outcome {
                assert_eq!(r.counts, core.run(s).counts, "survivor {i} diverged");
            }
        }
        assert!(engine.shard_health().iter().all(|h| *h == ShardHealth::Healthy));
        let out = engine.run_batch(&samples).unwrap();
        for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
            assert_eq!(r.counts, core.run(s).counts, "post-heal sample {i} diverged");
        }
    }

    #[test]
    fn slow_stage_chaos_delays_but_loses_nothing() {
        // A stalled stage is backpressure, not death: no quarantine, no
        // losses, results bit-exact.
        let (cfg, weights, regs, samples) = setup();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        engine.install_chaos(ChaosSchedule::new(vec![chaos::ChaosEvent {
            at_sample: 1,
            shard: 1,
            kind: ChaosKind::SlowStage { stage: 1, millis: 60 },
        }]));
        let outcomes = engine.run_batch_outcomes(&samples[..5]).unwrap();
        for (i, (outcome, s)) in outcomes.iter().zip(&samples[..5]).enumerate() {
            let r = outcome.as_ref().expect("stalls must not lose streams");
            assert_eq!(r.counts, core.run(s).counts, "sample {i} diverged under stall");
        }
        assert_eq!(engine.quarantines(), 0, "a stall must not quarantine the shard");
        assert_eq!(engine.recoveries(), 0);
    }

    #[test]
    fn shard_death_during_export_fence_is_typed_then_healed() {
        // Satellite edge case: a shard dies *under* the checkpoint export
        // fence. The fence must fail with a typed error (bounded poll, no
        // 60 s stall, no hang), and healing from the *previous* checkpoint
        // must restore service bit-exactly.
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let _ = engine.run_batch(&samples[..4]).unwrap();
        // Kill stage 0 of shard 1 directly, then fence before the
        // supervisor has seen the death: the Export rides the FIFO right
        // behind the panic.
        let t0 = Instant::now();
        engine.shards[1]
            .in_tx
            .as_ref()
            .unwrap()
            .send(StageMsg::Chaos { kind: ChaosKind::StagePanic { stage: 0 } })
            .unwrap();
        let err = engine.take_checkpoint().unwrap_err();
        assert!(
            err.to_string().contains("export fence") || err.to_string().contains("shard died"),
            "fence failure must be typed: {err:#}"
        );
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "fence death must be detected by the bounded poll, not the 60 s budget"
        );
        assert!(engine.heal().unwrap() >= 1, "the dead shard must be rebuilt");
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        let out = engine.run_batch(&samples).unwrap();
        for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
            assert_eq!(r.counts, core.run(s).counts, "post-fence-death sample {i} diverged");
        }
        engine.take_checkpoint().unwrap();
    }

    #[test]
    fn checkpoint_on_reconfig_epoch_boundary_replays_exactly() {
        // Satellite edge case: the checkpoint fence lands exactly at a
        // reconfig epoch boundary (fenced immediately after the program
        // committed). A shard killed right after must rebuild from that
        // checkpoint and still serve the *new* epoch bit-exactly — the
        // boundary program must be captured by exactly one of
        // {checkpoint state, replay}, never zero, never twice unsoundly.
        let (cfg, weights, regs, samples) = setup();
        let mut engine = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions::with_cores(2).checkpoints_every(1),
        )
        .unwrap();
        let mut raised = regs.clone();
        raised.set_vth(4.0).unwrap();
        let ops = [
            SessionOp::Submit(&samples[0]),
            SessionOp::Reconfig(ReconfigProgram::from_registers(&raised)),
            SessionOp::Submit(&samples[1]),
        ];
        let out = engine.run_session(&ops).unwrap();
        assert_eq!((out[0].epoch, out[1].epoch), (0, 1));
        // Cadence of 1 ⇒ the next session's pre-pass fences a checkpoint
        // at epoch 1 (the boundary). Kill a shard mid-session right after.
        engine.install_chaos(ChaosSchedule::new(vec![chaos::ChaosEvent {
            at_sample: 4,
            shard: 1,
            kind: ChaosKind::ChannelDrop { stage: 1 },
        }]));
        let _ = engine.run_batch_outcomes(&samples[..4]).unwrap();
        assert!(engine.recoveries() >= 1);
        assert!(engine.shard_health().iter().all(|h| *h == ShardHealth::Healthy));
        // The healed engine serves epoch 1 bit-exactly.
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = raised;
        let out = engine.run_batch(&samples).unwrap();
        for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
            assert_eq!(r.counts, core.run(s).counts, "epoch-boundary heal diverged at {i}");
            assert_eq!(r.epoch, 1, "healed engine must stay on the committed epoch");
        }
    }

    #[test]
    fn checkpoint_age_and_interval_accounting() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions::with_cores(2).checkpoints_every(4),
        )
        .unwrap();
        assert_eq!(engine.checkpoint_age_samples(), 0, "construction checkpoint is fresh");
        let _ = engine.run_batch(&samples[..3]).unwrap();
        assert_eq!(engine.checkpoint_age_samples(), 3, "below cadence: no re-fence yet");
        let _ = engine.run_batch(&samples[..2]).unwrap();
        // The pre-pass of that session saw age 3 < 4, so it did not
        // re-fence; afterwards age is 5 and the *next* session re-fences.
        assert_eq!(engine.checkpoint_age_samples(), 5);
        let _ = engine.run_batch(&samples[..1]).unwrap();
        assert_eq!(engine.checkpoint_age_samples(), 1, "cadence hit: re-fenced at 5 completed");
    }

    #[test]
    fn checkpoint_interval_zero_is_rejected() {
        // Satellite: a zero cadence used to be silently clamped to 1;
        // misconfiguration must surface as a typed construction error.
        let (cfg, weights, regs, _) = setup();
        let err = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions::with_cores(2).checkpoints_every(0),
        )
        .unwrap_err();
        assert!(err.to_string().contains("checkpoint interval"), "typed validation: {err:#}");
    }

    #[test]
    fn checkpoint_fenced_on_final_sample_recovers_with_empty_replay() {
        // Satellite edge case: checkpoints_every(1) with a fence taken
        // right at the last completed sample (age 0). That recovery point
        // must still be complete — a shard killed immediately after
        // rebuilds with an empty replay tail and serves bit-exactly.
        let (cfg, weights, regs, samples) = setup();
        let mut engine = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions::with_cores(2).checkpoints_every(1),
        )
        .unwrap();
        let _ = engine.run_batch(&samples[..4]).unwrap();
        engine.take_checkpoint().unwrap();
        assert_eq!(engine.checkpoint_age_samples(), 0, "fence sits on the final sample");
        engine.install_chaos(ChaosSchedule::new(vec![chaos::ChaosEvent {
            at_sample: 4,
            shard: 0,
            kind: ChaosKind::StagePanic { stage: 0 },
        }]));
        let outcomes = engine.run_batch_outcomes(&samples[..4]).unwrap();
        assert!(outcomes.iter().any(|o| o.is_err()), "the kill must cost its shard's streams");
        assert!(engine.shard_health().iter().all(|h| *h == ShardHealth::Healthy));
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        let out = engine.run_batch(&samples).unwrap();
        for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
            assert_eq!(r.counts, core.run(s).counts, "sample {i} after the age-0 rebuild");
        }
    }

    #[test]
    fn correct_mode_repairs_boundary_flips_bitexact_without_quarantine() {
        // SECDED mode: single-bit upsets injected between samples are
        // repaired by the boundary scrub before any datapath work uses
        // them — results bit-exact, no quarantine, every repair counted.
        use crate::hdl::integrity::FlipTarget;
        let (cfg, weights, regs, samples) = setup();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        let mut engine = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions::with_cores(2).with_integrity(IntegrityMode::Correct),
        )
        .unwrap();
        assert_eq!(engine.integrity_mode(), IntegrityMode::Correct);
        let flip = |at_sample, shard, layer, target, word| chaos::ChaosEvent {
            at_sample,
            shard,
            kind: ChaosKind::BitFlip { layer, target, word, bit: 7 },
        };
        engine.install_chaos(ChaosSchedule::new(vec![
            flip(1, 0, 0, FlipTarget::Weights, 123),
            flip(3, 1, 1, FlipTarget::Vmem, 5),
            flip(5, 0, 1, FlipTarget::Refcnt, 2),
        ]));
        for round in 0..2 {
            let out = engine.run_batch(&samples).unwrap();
            for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
                let seq = core.run(s);
                assert_eq!(r.counts, seq.counts, "round {round} sample {i}");
                assert_eq!(r.stats, seq.stats, "round {round} sample {i} ledger");
            }
        }
        let (scrubbed, corrected, detected) = engine.integrity_counters();
        assert!(scrubbed > 0, "background scrub must have swept blocks");
        assert_eq!(corrected, 3, "every injected flip repaired in place exactly once");
        assert_eq!(detected, 0, "single-bit flips are correctable under SECDED");
        assert_eq!(engine.quarantines(), 0, "correctable flips must not quarantine");
    }

    #[test]
    fn detect_mode_flip_quarantines_and_rebuilds_bitexact() {
        // Parity mode can only flag corruption: the boundary scrub panics
        // the stage, the streams behind it settle as typed ShardLost, and
        // the supervisor rebuilds the shard from the last checkpoint —
        // the same path as any other shard death, with the detection
        // counted in the integrity ledger.
        use crate::hdl::integrity::FlipTarget;
        let (cfg, weights, regs, samples) = setup();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        let mut engine = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions::with_cores(2).with_integrity(IntegrityMode::Detect),
        )
        .unwrap();
        engine.install_chaos(ChaosSchedule::new(vec![chaos::ChaosEvent {
            at_sample: 2,
            shard: 0,
            kind: ChaosKind::BitFlip { layer: 0, target: FlipTarget::Weights, word: 40, bit: 3 },
        }]));
        let outcomes = engine.run_batch_outcomes(&samples).unwrap();
        assert!(
            matches!(outcomes[2], Err(ServingError::ShardLost { shard: 0, resumable: true })),
            "the stream right behind the flip settles as typed ShardLost"
        );
        for (i, (outcome, s)) in outcomes.iter().zip(&samples).enumerate() {
            if let Ok(r) = outcome {
                assert_eq!(r.counts, core.run(s).counts, "survivor {i} diverged");
            }
        }
        assert!(engine.shard_health().iter().all(|h| *h == ShardHealth::Healthy));
        assert_eq!(engine.quarantines(), 1, "detected corruption is a quarantine cause");
        assert_eq!(engine.recoveries(), 1);
        let (_, corrected, detected) = engine.integrity_counters();
        assert_eq!((corrected, detected), (0, 1), "parity detects but cannot locate the bit");
        let out = engine.run_batch(&samples).unwrap();
        for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
            assert_eq!(r.counts, core.run(s).counts, "post-rebuild sample {i} diverged");
        }
    }

    #[test]
    fn panicked_pipeline_stage_yields_typed_error() {
        // Same contract for the one-shot scoped executor: a worker panic
        // must become ServingError::WorkerPanicked, never a scope-exit
        // abort. Drive the shared stage_loop directly with a chaos program.
        let chain = std::thread::scope(|scope| {
            let (tx_in, rx_in) = sync_channel::<StageMsg>(4);
            let (tx_out, rx_out) = sync_channel::<StageMsg>(4);
            let cfg = ModelConfig::parse_arch("4x3", Q5_3).unwrap();
            let layer = build_layers(&cfg, &[vec![0; 12]]).unwrap().remove(0);
            let handle = scope.spawn(move || {
                stage_loop(
                    0,
                    layer,
                    RegisterFile::new(Q5_3),
                    rx_in,
                    tx_out,
                    Vec::new(),
                    Vec::new(),
                    ScrubPlan::default(),
                )
            });
            let program = Arc::new(ReconfigProgram::new().chaos_panic(0));
            tx_in.send(StageMsg::Reconfig { epoch: 1, program }).unwrap();
            drop(tx_in);
            drop(rx_out);
            handle.join()
        });
        let payload = chain.expect_err("stage must have panicked");
        assert!(panic_message(payload).contains("chaos"));
    }

    #[test]
    fn invalid_in_band_program_fails_before_admission() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let ops = [
            SessionOp::Submit(&samples[0]),
            SessionOp::Reconfig(ReconfigProgram::new().write(99, 0)),
        ];
        assert!(engine.run_session(&ops).is_err());
        // The engine is not poisoned: validation failed up front, nothing
        // was admitted.
        let out = engine.run_batch(&samples[..2]).unwrap();
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn submit_after_shutdown_is_typed_error_not_panic() {
        // Regression: submitting to a shut-down engine used to hit
        // `.expect("engine not shut down")` on the closed admission
        // channel and panic the caller. It must be a typed ShutDown error.
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        engine.shutdown();
        let err = engine.run_batch(&samples[..2]).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServingError>(), Some(ServingError::ShutDown)),
            "expected ServingError::ShutDown, got: {err:#}"
        );
        // Snapshot after shutdown takes the same typed path.
        let err = engine.snapshot().unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServingError>(), Some(ServingError::ShutDown)),
            "expected ServingError::ShutDown from snapshot, got: {err:#}"
        );
    }

    #[test]
    fn snapshot_restore_roundtrips_bitexact() {
        // Unit-level differential check (the cross-topology × lane-width
        // gate lives in tests/connectome.rs): run a prefix, snapshot,
        // revive, and require the remainder — and the final snapshot — to
        // be bit-identical to the uninterrupted engine.
        let (cfg, weights, regs, samples) = setup();
        let opts = ServingOptions::with_cores(2);
        let mut uninterrupted = ServingEngine::new(&cfg, &weights, &regs, opts).unwrap();
        let mut donor = ServingEngine::new(&cfg, &weights, &regs, opts).unwrap();
        let _ = uninterrupted.run_batch(&samples[..4]).unwrap();
        let _ = donor.run_batch(&samples[..4]).unwrap();
        let snap = donor.snapshot().unwrap();
        assert_eq!((snap.submitted, snap.completed), (4, 4), "quiesce-point invariant");
        let bytes = snap.encode();
        let decoded = super::super::connectome::Connectome::decode(&bytes).unwrap();
        assert_eq!(decoded, snap, "wire roundtrip must be identity");
        let mut revived = ServingEngine::from_connectome(&decoded).unwrap();
        let a = uninterrupted.run_batch(&samples[4..]).unwrap();
        let b = revived.run_batch(&samples[4..]).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.counts, y.counts, "restored engine diverged");
            assert_eq!(x.stats, y.stats, "restored activity ledger diverged");
            assert_eq!(x.epoch, y.epoch);
        }
        // Whole-state equivalence: the two engines snapshot identically.
        assert_eq!(revived.snapshot().unwrap(), uninterrupted.snapshot().unwrap());
    }

    #[test]
    fn migrate_applies_snapshot_as_one_epoch() {
        let (cfg, weights, regs, samples) = setup();
        // Donor carries different weights and a raised threshold.
        let mut rng = crate::datasets::rng::XorShift64Star::new(0xD02);
        let donor_weights: Vec<Vec<i32>> = cfg
            .layers()
            .iter()
            .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(15) as i32 - 7).collect())
            .collect();
        let mut donor_regs = regs.clone();
        donor_regs.set_vth(4.0).unwrap();
        let mut donor = ServingEngine::new(
            &cfg,
            &donor_weights,
            &donor_regs,
            ServingOptions::with_cores(1),
        )
        .unwrap();
        let snap = donor.snapshot().unwrap();

        let mut live =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let _ = live.run_batch(&samples[..2]).unwrap();
        let control = live.control_plane();
        let before = control.epoch();
        let epoch = control.migrate(&snap).unwrap();
        assert_eq!(epoch, before + 1, "migration must be exactly one config epoch");
        // Post-migration results are bit-identical to a sequential core
        // built with the donor's weights and registers.
        let out = live.run_batch(&samples[..3]).unwrap();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&donor_weights).unwrap();
        core.registers = donor_regs;
        for (r, s) in out.iter().zip(&samples[..3]) {
            assert_eq!(r.counts, core.run(s).counts, "migrated engine diverged from donor");
            assert_eq!(r.epoch, epoch);
        }
        // Geometry mismatch is rejected with a typed error, atomically.
        let narrow = ModelConfig::parse_arch("4x3", Q5_3).unwrap();
        let narrow_engine = ServingEngine::new(
            &narrow,
            &[vec![0; 12]],
            &RegisterFile::new(Q5_3),
            ServingOptions::with_cores(1),
        )
        .unwrap();
        let err = narrow_engine.control_plane().migrate(&snap).unwrap_err();
        assert!(
            matches!(
                err,
                super::super::control::ControlError::SnapshotMismatch { .. }
                    | super::super::control::ControlError::PayloadSize { .. }
            ),
            "mismatched migrate must be typed: {err}"
        );
        assert_eq!(narrow_engine.control_plane().epoch(), 0, "nothing applied");
    }
}
